"""Auto-generated serverless application wordcount (clean-3)."""
import fakelib_wordlib

def count(event=None):
    _out = 0
    _out += fakelib_wordlib.tokens.work(12)
    return {"handler": "count", "ok": True, "out": _out}


HANDLERS = {"count": count}
WEIGHTS = {"count": 1.0}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "count"
    return HANDLERS[op](event)
