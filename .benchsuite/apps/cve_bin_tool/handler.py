"""Auto-generated serverless application cve_bin_tool (CVE-bin-tool)."""
import fakelib_cvecore

def scan(event=None):
    _out = 0
    _out += fakelib_cvecore.checkers.work(16)
    _out += fakelib_cvecore.scanner.work(10)
    return {"handler": "scan", "ok": True, "out": _out}


def sbom_scan(event=None):
    _out = 0
    _out += fakelib_cvecore.sbom.work(4)
    return {"handler": "sbom_scan", "ok": True, "out": _out}


HANDLERS = {"scan": scan, "sbom_scan": sbom_scan}
WEIGHTS = {"scan": 0.97, "sbom_scan": 0.03}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "scan"
    return HANDLERS[op](event)
