"""Auto-generated serverless application dna_visualisation (R-DV)."""
import fakelib_numpy

def visualise(event=None):
    _out = 0
    _out += fakelib_numpy.core.work(22)
    _out += fakelib_numpy.linalg.work(5)
    return {"handler": "visualise", "ok": True, "out": _out}


def spectrum(event=None):
    _out = 0
    _out += fakelib_numpy.fft.work(4)
    return {"handler": "spectrum", "ok": True, "out": _out}


HANDLERS = {"visualise": visualise, "spectrum": spectrum}
WEIGHTS = {"visualise": 0.96, "spectrum": 0.04}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "visualise"
    return HANDLERS[op](event)
