"""Auto-generated serverless application graph_pagerank (R-GPR)."""
import fakelib_igraph

def pagerank(event=None):
    _out = 0
    _out += fakelib_igraph.core.work(18)
    _out += fakelib_igraph.community.work(6)
    return {"handler": "pagerank", "ok": True, "out": _out}


def render(event=None):
    _out = 0
    _out += fakelib_igraph.drawing.matplotlib.work(4)
    return {"handler": "render", "ok": True, "out": _out}


HANDLERS = {"pagerank": pagerank, "render": render}
WEIGHTS = {"pagerank": 0.9, "render": 0.1}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "pagerank"
    return HANDLERS[op](event)
