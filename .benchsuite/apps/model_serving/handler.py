"""Auto-generated serverless application model_serving (FWB-MS)."""
import fakelib_scipy
import fakelib_sklearn
import fakelib_numpy

def serve(event=None):
    _out = 0
    _out += fakelib_sklearn.linear_model.work(14)
    _out += fakelib_numpy.core.work(8)
    _out += fakelib_scipy.stats.work(6)
    return {"handler": "serve", "ok": True, "out": _out}


def batch_score(event=None):
    _out = 0
    _out += fakelib_sklearn.metrics.work(4)
    return {"handler": "batch_score", "ok": True, "out": _out}


HANDLERS = {"serve": serve, "batch_score": batch_score}
WEIGHTS = {"serve": 0.97, "batch_score": 0.03}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "serve"
    return HANDLERS[op](event)
