"""Auto-generated serverless application chameleon (FWB-CML)."""
import fakelib_pkgres

def render_template(event=None):
    _out = 0
    _out += fakelib_pkgres.working_set.work(18)
    return {"handler": "render_template", "ok": True, "out": _out}


def list_plugins(event=None):
    _out = 0
    _out += fakelib_pkgres.extern.work(4)
    return {"handler": "list_plugins", "ok": True, "out": _out}


HANDLERS = {"render_template": render_template, "list_plugins": list_plugins}
WEIGHTS = {"render_template": 0.97, "list_plugins": 0.03}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "render_template"
    return HANDLERS[op](event)
