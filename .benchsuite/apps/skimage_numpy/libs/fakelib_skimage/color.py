"""Auto-generated module fakelib_skimage.color (SLIMSTART benchsuite; not a real library)."""
import time as _time

# -- calibrated import-time cost ------------------------------------------
_end = _time.perf_counter() + 10 / 1000.0
while _time.perf_counter() < _end:
    pass
_BALLAST = bytearray(int(2 * 1048576)) or bytearray(1)
_BALLAST[::4096] = b"\x01" * len(_BALLAST[::4096])


def work(ms):
    """Busy loop attributed to this module by the sampling profiler."""
    end = _time.perf_counter() + ms / 1000.0
    x = 0
    while _time.perf_counter() < end:
        x += 1
    return x


def compute(n):
    s = 0
    for i in range(int(n)):
        s += (i * i) % 97
    return s
