"""Auto-generated serverless application thumbnail (clean-5)."""
import fakelib_imgsmall

def resize(event=None):
    _out = 0
    _out += fakelib_imgsmall.resize.work(14)
    return {"handler": "resize", "ok": True, "out": _out}


HANDLERS = {"resize": resize}
WEIGHTS = {"resize": 1.0}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "resize"
    return HANDLERS[op](event)
