"""Auto-generated serverless application matrix_small (clean-4)."""
import fakelib_mathcore

def multiply(event=None):
    _out = 0
    _out += fakelib_mathcore.ops.work(14)
    return {"handler": "multiply", "ok": True, "out": _out}


HANDLERS = {"multiply": multiply}
WEIGHTS = {"multiply": 1.0}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "multiply"
    return HANDLERS[op](event)
