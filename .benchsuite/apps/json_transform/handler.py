"""Auto-generated serverless application json_transform (clean-2)."""
import fakelib_jsonlib

def transform(event=None):
    _out = 0
    _out += fakelib_jsonlib.codec.work(12)
    return {"handler": "transform", "ok": True, "out": _out}


HANDLERS = {"transform": transform}
WEIGHTS = {"transform": 1.0}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "transform"
    return HANDLERS[op](event)
