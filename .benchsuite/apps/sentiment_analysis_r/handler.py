"""Auto-generated serverless application sentiment_analysis_r (R-SA)."""
import fakelib_nltk
import fakelib_textblob

def analyze(event=None):
    _out = 0
    _out += fakelib_nltk.tokenize.work(14)
    _out += fakelib_textblob.blob.work(6)
    _out += fakelib_textblob.sentiments.work(5)
    return {"handler": "analyze", "ok": True, "out": _out}


def corpus_stats(event=None):
    _out = 0
    _out += fakelib_nltk.corpus.work(6)
    _out += fakelib_nltk.data.work(4)
    return {"handler": "corpus_stats", "ok": True, "out": _out}


def tag_text(event=None):
    _out = 0
    _out += fakelib_nltk.tag.work(3)
    return {"handler": "tag_text", "ok": True, "out": _out}


HANDLERS = {"analyze": analyze, "corpus_stats": corpus_stats, "tag_text": tag_text}
WEIGHTS = {"analyze": 0.92, "corpus_stats": 0.06, "tag_text": 0.02}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "analyze"
    return HANDLERS[op](event)
