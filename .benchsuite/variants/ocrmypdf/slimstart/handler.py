"""Auto-generated serverless application ocrmypdf (OCRmyPDF)."""
import fakelib_pdfminer

def ocr(event=None):
    _out = 0
    _out += fakelib_pdfminer.layout.work(14)
    _out += fakelib_pdfminer.converter.work(8)
    _out += fakelib_pdfminer.psparser.work(6)
    return {"handler": "ocr", "ok": True, "out": _out}


def extract_images(event=None):
    _out = 0
    _out += fakelib_pdfminer.image.work(5)
    return {"handler": "extract_images", "ok": True, "out": _out}


HANDLERS = {"ocr": ocr, "extract_images": extract_images}
WEIGHTS = {"ocr": 0.94, "extract_images": 0.06}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "ocr"
    return HANDLERS[op](event)
