"""Auto-generated serverless application graph_mst (R-GM)."""
import fakelib_igraph

def mst(event=None):
    _out = 0
    _out += fakelib_igraph.core.work(22)
    return {"handler": "mst", "ok": True, "out": _out}


def render(event=None):
    _out = 0
    _out += fakelib_igraph.drawing.cairo.work(5)
    return {"handler": "render", "ok": True, "out": _out}


HANDLERS = {"mst": mst, "render": render}
WEIGHTS = {"mst": 0.95, "render": 0.05}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "mst"
    return HANDLERS[op](event)
