"""Auto-generated serverless application model_training (FWB-MT)."""
import fakelib_scipy
import fakelib_sklearn

def train(event=None):
    _out = 0
    _out += fakelib_sklearn.linear_model.work(16)
    _out += fakelib_scipy.optimize.work(10)
    _out += fakelib_sklearn.preprocessing.work(5)
    return {"handler": "train", "ok": True, "out": _out}


def score(event=None):
    _out = 0
    _out += fakelib_sklearn.metrics.work(4)
    return {"handler": "score", "ok": True, "out": _out}


HANDLERS = {"train": train, "score": score}
WEIGHTS = {"train": 0.95, "score": 0.05}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "train"
    return HANDLERS[op](event)
