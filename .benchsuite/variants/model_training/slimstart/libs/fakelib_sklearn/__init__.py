"""Auto-generated module fakelib_sklearn (SLIMSTART benchsuite; not a real library)."""
import time as _time

# -- calibrated import-time cost ------------------------------------------
_end = _time.perf_counter() + 5 / 1000.0
while _time.perf_counter() < _end:
    pass
_BALLAST = bytearray(int(2 * 1048576)) or bytearray(1)
_BALLAST[::4096] = b"\x01" * len(_BALLAST[::4096])

# from fakelib_sklearn import base  # SLIMSTART: deferred
from fakelib_sklearn import linear_model
# from fakelib_sklearn import ensemble  # SLIMSTART: deferred
# from fakelib_sklearn import svm  # SLIMSTART: deferred
from fakelib_sklearn import preprocessing
# from fakelib_sklearn import metrics  # SLIMSTART: deferred

__all__ = ['linear_model', 'ensemble', 'svm', 'metrics']


def work(ms):
    """Busy loop attributed to this module by the sampling profiler."""
    end = _time.perf_counter() + ms / 1000.0
    x = 0
    while _time.perf_counter() < end:
        x += 1
    return x


def compute(n):
    s = 0
    for i in range(int(n)):
        s += (i * i) % 97
    return s


def _touch_static():
    """References kept so static reachability must retain these imports."""
    import fakelib_sklearn.base as base  # SLIMSTART: deferred
    return (base, linear_model, preprocessing)


# --- SLIMSTART deferred-import shim (auto-generated) ---
_SLIMSTART_DEFERRED = {
    'base': (('fakelib_sklearn.base',), None, None),
    'ensemble': (('fakelib_sklearn.ensemble',), None, None),
    'metrics': (('fakelib_sklearn.metrics',), None, None),
    'svm': (('fakelib_sklearn.svm',), None, None),
}


def __getattr__(_name):
    _spec = _SLIMSTART_DEFERRED.get(_name)
    if _spec is None:
        raise AttributeError(_name)
    import importlib as _il
    import sys as _sys
    for _m in _spec[0]:
        _mod = _il.import_module(_m)
    if _spec[1] is not None:
        try:
            # __dict__ lookup: must not re-enter this __getattr__ when the
            # attribute is really a submodule of *this* package.
            _val = _mod.__dict__[_spec[1]]
        except KeyError:
            _val = _il.import_module(_spec[0][-1] + "." + _spec[1])
    elif _spec[2] is not None:
        _val = _sys.modules[_spec[2]]
    else:
        _val = _mod
    globals()[_name] = _val
    return _val
# --- end SLIMSTART shim ---
