"""Auto-generated serverless application sensor_telemetry (SensorTD)."""
import fakelib_prophet

def forecast(event=None):
    _out = 0
    _out += fakelib_prophet.forecaster.work(22)
    _out += fakelib_prophet.models.work(8)
    return {"handler": "forecast", "ok": True, "out": _out}


def backtest(event=None):
    _out = 0
    _out += fakelib_prophet.diagnostics.work(5)
    return {"handler": "backtest", "ok": True, "out": _out}


HANDLERS = {"forecast": forecast, "backtest": backtest}
WEIGHTS = {"forecast": 0.96, "backtest": 0.04}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "forecast"
    return HANDLERS[op](event)
