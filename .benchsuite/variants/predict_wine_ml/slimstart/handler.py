"""Auto-generated serverless application predict_wine_ml (FL-PWM)."""
import fakelib_pandas

def predict(event=None):
    _out = 0
    _out += fakelib_pandas.core.work(20)
    _out += fakelib_pandas.io.work(6)
    return {"handler": "predict", "ok": True, "out": _out}


def describe(event=None):
    _out = 0
    _out += fakelib_pandas.computation.work(4)
    return {"handler": "describe", "ok": True, "out": _out}


HANDLERS = {"predict": predict, "describe": describe}
WEIGHTS = {"predict": 0.97, "describe": 0.03}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "predict"
    return HANDLERS[op](event)
