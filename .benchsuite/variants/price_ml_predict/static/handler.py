"""Auto-generated serverless application price_ml_predict (FL-PMP)."""
import fakelib_scipy

def predict(event=None):
    _out = 0
    _out += fakelib_scipy.optimize.work(18)
    _out += fakelib_scipy.stats.work(8)
    return {"handler": "predict", "ok": True, "out": _out}


def integrate_curve(event=None):
    _out = 0
    _out += fakelib_scipy.integrate.work(4)
    return {"handler": "integrate_curve", "ok": True, "out": _out}


HANDLERS = {"predict": predict, "integrate_curve": integrate_curve}
WEIGHTS = {"predict": 0.95, "integrate_curve": 0.05}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "predict"
    return HANDLERS[op](event)
