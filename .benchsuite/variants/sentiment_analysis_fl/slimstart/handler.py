"""Auto-generated serverless application sentiment_analysis_fl (FL-SA)."""
import fakelib_pandas
import fakelib_scipy

def analyze(event=None):
    _out = 0
    _out += fakelib_pandas.core.work(16)
    _out += fakelib_scipy.stats.work(10)
    return {"handler": "analyze", "ok": True, "out": _out}


def aggregate(event=None):
    _out = 0
    _out += fakelib_pandas.io.work(4)
    return {"handler": "aggregate", "ok": True, "out": _out}


HANDLERS = {"analyze": analyze, "aggregate": aggregate}
WEIGHTS = {"analyze": 0.98, "aggregate": 0.02}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "analyze"
    return HANDLERS[op](event)
