"""Auto-generated module fakelib_numpy (SLIMSTART benchsuite; not a real library)."""
import time as _time

# -- calibrated import-time cost ------------------------------------------
_end = _time.perf_counter() + 4 / 1000.0
while _time.perf_counter() < _end:
    pass
_BALLAST = bytearray(int(2 * 1048576)) or bytearray(1)
_BALLAST[::4096] = b"\x01" * len(_BALLAST[::4096])

from fakelib_numpy import core
# from fakelib_numpy import linalg  # SLIMSTART: deferred
# from fakelib_numpy import fft  # SLIMSTART: deferred
# from fakelib_numpy import polynomial  # SLIMSTART: deferred
# from fakelib_numpy import random  # SLIMSTART: deferred
# from fakelib_numpy import ma  # SLIMSTART: deferred
# from fakelib_numpy import testing  # SLIMSTART: deferred

__all__ = ['core', 'linalg', 'fft', 'random', 'ma']


def work(ms):
    """Busy loop attributed to this module by the sampling profiler."""
    end = _time.perf_counter() + ms / 1000.0
    x = 0
    while _time.perf_counter() < end:
        x += 1
    return x


def compute(n):
    s = 0
    for i in range(int(n)):
        s += (i * i) % 97
    return s


def _touch_static():
    """References kept so static reachability must retain these imports."""
    import fakelib_numpy.linalg as linalg  # SLIMSTART: deferred
    return (core, linalg)


# --- SLIMSTART deferred-import shim (auto-generated) ---
_SLIMSTART_DEFERRED = {
    'fft': (('fakelib_numpy.fft',), None, None),
    'linalg': (('fakelib_numpy.linalg',), None, None),
    'ma': (('fakelib_numpy.ma',), None, None),
    'polynomial': (('fakelib_numpy.polynomial',), None, None),
    'random': (('fakelib_numpy.random',), None, None),
    'testing': (('fakelib_numpy.testing',), None, None),
}


def __getattr__(_name):
    _spec = _SLIMSTART_DEFERRED.get(_name)
    if _spec is None:
        raise AttributeError(_name)
    import importlib as _il
    import sys as _sys
    for _m in _spec[0]:
        _mod = _il.import_module(_m)
    if _spec[1] is not None:
        try:
            # __dict__ lookup: must not re-enter this __getattr__ when the
            # attribute is really a submodule of *this* package.
            _val = _mod.__dict__[_spec[1]]
        except KeyError:
            _val = _il.import_module(_spec[0][-1] + "." + _spec[1])
    elif _spec[2] is not None:
        _val = _sys.modules[_spec[2]]
    else:
        _val = _mod
    globals()[_name] = _val
    return _val
# --- end SLIMSTART shim ---
