"""Auto-generated serverless application train_wine_ml (FL-TWM)."""
import fakelib_pandas

def train(event=None):
    _out = 0
    _out += fakelib_pandas.core.work(26)
    _out += fakelib_pandas.io.work(8)
    return {"handler": "train", "ok": True, "out": _out}


def profile_data(event=None):
    _out = 0
    _out += fakelib_pandas.computation.work(5)
    return {"handler": "profile_data", "ok": True, "out": _out}


HANDLERS = {"train": train, "profile_data": profile_data}
WEIGHTS = {"train": 0.96, "profile_data": 0.04}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "train"
    return HANDLERS[op](event)
