"""Auto-generated serverless application heart_failure (HFP)."""
import fakelib_scipy
import fakelib_sklearn

def predict_risk(event=None):
    _out = 0
    _out += fakelib_sklearn.linear_model.work(14)
    _out += fakelib_scipy.stats.work(10)
    return {"handler": "predict_risk", "ok": True, "out": _out}


def cohort_stats(event=None):
    _out = 0
    _out += fakelib_scipy.stats.work(6)
    return {"handler": "cohort_stats", "ok": True, "out": _out}


HANDLERS = {"predict_risk": predict_risk, "cohort_stats": cohort_stats}
WEIGHTS = {"predict_risk": 0.96, "cohort_stats": 0.04}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "predict_risk"
    return HANDLERS[op](event)
