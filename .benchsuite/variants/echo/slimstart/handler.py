"""Auto-generated serverless application echo (clean-1)."""


def echo(event=None):
    _out = 0
    _out += len(str(event)) if event else 0
    return {"handler": "echo", "ok": True, "out": _out}


HANDLERS = {"echo": echo}
WEIGHTS = {"echo": 1.0}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "echo"
    return HANDLERS[op](event)
