"""Auto-generated serverless application skimage_numpy (FL-SN)."""
import fakelib_skimage
import fakelib_numpy

def filter_image(event=None):
    _out = 0
    _out += fakelib_skimage.filters.work(16)
    _out += fakelib_numpy.core.work(8)
    return {"handler": "filter_image", "ok": True, "out": _out}


def recolor(event=None):
    _out = 0
    _out += fakelib_skimage.color.work(5)
    return {"handler": "recolor", "ok": True, "out": _out}


HANDLERS = {"filter_image": filter_image, "recolor": recolor}
WEIGHTS = {"filter_image": 0.94, "recolor": 0.06}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "filter_image"
    return HANDLERS[op](event)
