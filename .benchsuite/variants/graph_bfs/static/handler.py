"""Auto-generated serverless application graph_bfs (R-GB)."""
import fakelib_igraph

def bfs(event=None):
    _out = 0
    _out += fakelib_igraph.core.work(20)
    return {"handler": "bfs", "ok": True, "out": _out}


def stats(event=None):
    _out = 0
    _out += fakelib_igraph.core.work(8)
    return {"handler": "stats", "ok": True, "out": _out}


def render(event=None):
    _out = 0
    _out += fakelib_igraph.drawing.matplotlib.work(6)
    return {"handler": "render", "ok": True, "out": _out}


HANDLERS = {"bfs": bfs, "stats": stats, "render": render}
WEIGHTS = {"bfs": 0.94, "stats": 0.03, "render": 0.03}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {}).get("op") or "bfs"
    return HANDLERS[op](event)
