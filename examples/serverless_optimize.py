"""Case studies (paper §VI, Tables IV-V): actionable SLIMSTART reports.

Reproduces the report format for the two featured applications —
Sentiment Analysis (R-SA: nltk at ~70% of init with 5.33% utilization;
sem/stem/parse/tag unused) and the CVE Binary Analyzer (xmlschema only
needed for SBOM inputs) — on the synthetic suite, then applies the
optimization and prints before/after.

    PYTHONPATH=src python examples/serverless_optimize.py [app ...]
"""

import os
import sys

from repro.api import SlimStart
from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import measure_cold_starts

CASES = ["sentiment_analysis_r", "cve_bin_tool"]


def show_report(app: str, root: str):
    print("=" * 72)
    print(f"SLIMSTART Summary — {app}")
    print("=" * 72)
    res = SlimStart.profile_guided(app, root, instances=2,
                                   invocations=80).run()
    rep = res.report

    print(f"{'':2s}{'Package':34s}{'Util.%':>8s}{'Init%':>8s}  File")
    for f in rep.findings[:10]:
        mark = "+" if f.package in rep.defer_targets else "-"
        print(f"{mark:2s}{f.package:34s}{100 * f.utilization:8.2f}"
              f"{100 * f.init_share:8.2f}  {f.file or ''}")

    print("\nImport call paths (per flagged package):")
    for f in rep.findings[:4]:
        if not f.import_chain:
            continue
        print(f"  {f.package}:")
        for r in f.import_chain[:4]:
            print(f"    -> {r.importer_file}:{r.importer_lineno}")

    base = measure_cold_starts(os.path.join(root, "apps", app), n=3)
    opt = measure_cold_starts(res.variant_dir, n=3)
    print(f"\nOptimization: {res.apply_summary['deferred']} imports "
          f"deferred across {res.apply_summary['files_changed']} files")
    print(f"init {base.init_mean:7.1f} -> {opt.init_mean:7.1f} ms "
          f"({base.init_mean / opt.init_mean:.2f}x)   "
          f"e2e {base.e2e_mean:7.1f} -> {opt.e2e_mean:7.1f} ms "
          f"({base.e2e_mean / opt.e2e_mean:.2f}x)   "
          f"rss {base.rss_mean_mb:.0f} -> {opt.rss_mean_mb:.0f} MB\n")


if __name__ == "__main__":
    apps = sys.argv[1:] or CASES
    root = build_suite()
    for app in apps:
        show_report(app, root)
