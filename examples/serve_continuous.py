"""Serving example: continuous batching + SLIMSTART cold start.

Boots a profile-guided engine for a reduced MoE model, then drives the
slot-based continuous batcher with a Poisson arrival stream.

    PYTHONPATH=src python examples/serve_continuous.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models.model import decode_step, init_cache, init_params, prefill
from repro.serving import ContinuousBatcher, Request


def main():
    cfg = get_reduced("granite-moe-1b-a400m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_slots, cache_len = 4, 64

    def prefill_fn(tokens):
        logits, caches, _ = prefill(cfg, params, tokens,
                                    cache_len=cache_len)
        return jnp.argmax(logits, -1).astype(jnp.int32), caches

    @jax.jit
    def decode_fn(tok, pos, caches):
        logits, caches = decode_step(cfg, params, tok, pos, caches)
        return jnp.argmax(logits, -1).astype(jnp.int32)[:, None], caches

    batcher = ContinuousBatcher(prefill_fn, decode_fn,
                                init_cache(cfg, n_slots, cache_len),
                                n_slots=n_slots)
    rng = np.random.default_rng(0)
    for rid in range(10):
        L = int(rng.integers(4, 12))
        batcher.submit(Request(
            rid=rid, tokens=rng.integers(0, cfg.vocab, (L,)),
            max_new_tokens=int(rng.integers(3, 8))))
    stats = batcher.run_until_drained()
    print("batcher stats:", stats)
    for r in sorted(batcher.finished, key=lambda r: r.rid)[:5]:
        print(f"  req {r.rid}: +{len(r.out_tokens)} tokens "
              f"{r.out_tokens[:6]}")
    assert stats["finished"] == 10
    print("OK")


if __name__ == "__main__":
    main()
