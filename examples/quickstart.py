"""Quickstart: the SLIMSTART loop end to end, in one minute.

1. build the synthetic serverless suite,
2. measure a baseline cold start,
3. profile -> analyze (CCT + utilization) -> AST-rewrite,
4. measure the optimized cold start and print the speedup,
5. show the same loop at Level B (model-serving cold start).

    PYTHONPATH=src python examples/quickstart.py
"""

import os

from repro.api import SlimStart
from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import measure_cold_starts

APP = "graph_bfs"  # the paper's motivating example (igraph, Table I)


def level_a():
    print("=" * 64)
    print("Level A: Python-module cold starts (the paper, verbatim)")
    print("=" * 64)
    root = build_suite()
    app_dir = os.path.join(root, "apps", APP)

    base = measure_cold_starts(app_dir, n=3)
    print(f"baseline   : init {base.init_mean:7.1f} ms   "
          f"e2e {base.e2e_mean:7.1f} ms   rss {base.rss_mean_mb:.0f} MB")

    res = SlimStart.profile_guided(APP, root, instances=2,
                                   invocations=60).run()
    print(f"profiled   : {res.apply_summary['deferred']} imports deferred"
          f" (report: {res.report_path})")

    opt = measure_cold_starts(res.variant_dir, n=3)
    print(f"optimized  : init {opt.init_mean:7.1f} ms   "
          f"e2e {opt.e2e_mean:7.1f} ms   rss {opt.rss_mean_mb:.0f} MB")
    print(f"speedup    : init {base.init_mean / opt.init_mean:.2f}x   "
          f"e2e {base.e2e_mean / opt.e2e_mean:.2f}x")


def level_b():
    print()
    print("=" * 64)
    print("Level B: model-serving cold starts (TPU-native adaptation)")
    print("=" * 64)
    import numpy as np
    from repro.configs import get_reduced
    from repro.serving import LoadPolicy, ServingEngine

    cfg = get_reduced("granite-moe-1b-a400m")
    eager = ServingEngine(cfg, prefill_len=8)
    cold_eager = eager.cold_start()
    toks = np.random.default_rng(0).integers(0, cfg.vocab, (1, 8))
    eager.serve("generate", toks, max_new_tokens=4)
    policy = LoadPolicy.from_report(eager.report())

    slim = ServingEngine(cfg, policy=policy, prefill_len=8)
    cold_slim = slim.cold_start()
    out, lat = slim.serve("generate", toks, max_new_tokens=4)
    print(f"eager cold start     : {cold_eager:.3f} s")
    print(f"slimstart cold start : {cold_slim:.3f} s "
          f"({cold_eager / max(cold_slim, 1e-9):.2f}x)")
    print(f"first request        : {lat:.3f} s -> tokens {out[0].tolist()}")
    print(f"deferred components  : {sorted(policy.lazy_names)[:6]} ...")


if __name__ == "__main__":
    level_a()
    level_b()
