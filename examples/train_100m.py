"""End-to-end training driver: a ~100M-parameter granite-family model
for a few hundred steps on the synthetic pipeline, with checkpointing
and straggler accounting.  (CPU-sized by default; pass --full-width for
the real ~100M config if you have the cycles.)

    PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-width", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    base = get_config("granite-8b")
    if args.full_width:
        # ~100M: 12L x 768 with the granite block structure
        cfg = base.with_(n_layers=12, d_model=768, n_heads=12,
                         n_kv_heads=4, head_dim=64, d_ff=2048,
                         vocab=32768, dtype="float32", loss_chunk=0)
    else:
        # CPU-friendly stand-in with the same code paths
        cfg = base.with_(n_layers=4, d_model=256, n_heads=8,
                         n_kv_heads=4, head_dim=32, d_ff=688,
                         vocab=8192, dtype="float32", loss_chunk=0)

    _, _, summary = train(cfg, steps=args.steps, batch=args.batch,
                          seq=args.seq, lr=1e-3, ckpt_dir=args.ckpt_dir,
                          ckpt_every=50, log_every=20)
    losses = summary["losses"]
    print(f"\nloss: first10 {np.mean(losses[:10]):.3f} -> "
          f"last10 {np.mean(losses[-10:]):.3f}")
    print(f"straggler stats: {summary['straggler']}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), \
        "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
