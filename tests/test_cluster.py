"""Cluster subsystem tests: frame protocol, percentile merging, the
cluster simulator (placement comparison, topology churn, chaos
node_loss), the cluster_summary artifact, and a real socket round-trip
through NodeAgent + ClusterRouter.

The conservation invariant ``requests == served + sheds + flushed +
errors + abandoned`` is the thread through every test here: it must
hold per node, globally, and against the router's own ledger — across
migrations, node loss, and drain.
"""

import json
import os
import socket
import struct
import subprocess
import sys
import threading

import pytest

from repro.api import (load_cluster_summary, save_cluster_summary)
from repro.cluster import (MAX_FRAME, ClusterRouter, ClusterSimulator,
                           FrameClosed, FrameError, NodeAgent,
                           NodeClient, compare_strategies, encode_frame,
                           node_conserves, recv_frame, send_frame,
                           synthetic_cluster_workload)
from repro.pool import (AppProfile, FleetDaemon, FleetManager,
                        IdleTimeoutPolicy, QueueConfig, SimFleetBackend)
from repro.pool.chaos import FaultEvent, FaultInjector, FaultPlan
from repro.pool.simulator import PercentilePool


# ---------------------------------------------------------------------------
# frame protocol
# ---------------------------------------------------------------------------

def _pair():
    a, b = socket.socketpair()
    a.settimeout(5.0)
    b.settimeout(5.0)
    return a, b


def test_frame_round_trip():
    a, b = _pair()
    try:
        msg = {"cmd": "hello", "payload": "newlines\nembedded\nfine",
               "n": 42}
        send_frame(a, msg)
        send_frame(a, {"second": True})
        assert recv_frame(b) == msg
        assert recv_frame(b) == {"second": True}
    finally:
        a.close()
        b.close()


def test_frame_clean_eof_vs_truncation():
    a, b = _pair()
    try:
        a.close()  # clean close between frames
        with pytest.raises(FrameClosed):
            recv_frame(b)
    finally:
        b.close()
    a, b = _pair()
    try:
        a.sendall(encode_frame({"x": 1})[:3])  # cut mid-prefix
        a.close()
        with pytest.raises(FrameError):
            recv_frame(b)
    finally:
        b.close()


def test_frame_rejects_oversize_and_non_dict():
    a, b = _pair()
    try:
        a.sendall(struct.pack(">I", MAX_FRAME + 1))
        with pytest.raises(FrameError):
            recv_frame(b)
    finally:
        a.close()
        b.close()
    a, b = _pair()
    try:
        body = b"[1,2,3]"  # valid JSON, but not an object
        a.sendall(struct.pack(">I", len(body)) + body)
        with pytest.raises(FrameError):
            recv_frame(b)
    finally:
        a.close()
        b.close()
    with pytest.raises(FrameError):
        encode_frame({"x": "y" * (MAX_FRAME + 1)})


# ---------------------------------------------------------------------------
# percentile merging: true global quantiles, not averaged per-node ones
# ---------------------------------------------------------------------------

def test_percentile_pool_merge_matches_concatenation():
    node_a = [float(x) for x in range(1, 100)]      # fast node
    node_b = [float(x) for x in range(500, 1000)]   # slow node
    merged = PercentilePool.merge([
        PercentilePool.of_lists([node_a]),
        PercentilePool.of_lists([node_b]),
    ])
    truth = PercentilePool.of_lists([node_a + node_b])
    assert len(merged) == len(node_a) + len(node_b)
    for q in (0.5, 0.9, 0.99):
        assert merged.percentile(q) == pytest.approx(
            truth.percentile(q))
    assert merged.mean == pytest.approx(truth.mean)
    # averaging the two p99s would be badly wrong — the merge is not
    # doing that
    avg_p99 = (PercentilePool.of_lists([node_a]).percentile(0.99)
               + PercentilePool.of_lists([node_b]).percentile(0.99)) / 2
    assert abs(merged.percentile(0.99) - avg_p99) > 100


def test_percentile_pool_merge_sees_later_growth():
    samples = [1.0, 2.0]
    merged = PercentilePool.merge([PercentilePool.of_lists([samples])])
    assert len(merged) == 2
    samples.append(1000.0)
    assert len(merged) == 3
    assert merged.percentile(0.99) == pytest.approx(1000.0, rel=0.05)


# ---------------------------------------------------------------------------
# cluster simulator: placement quality, conservation, topology churn
# ---------------------------------------------------------------------------

def _wl(n_apps=8, families=2, seed=3, minutes=4, peak_rpm=60.0):
    return synthetic_cluster_workload(n_apps, n_families=families,
                                      seed=seed, minutes=minutes,
                                      peak_rpm=peak_rpm)


def test_sim_replay_conserves_and_sharing_beats_hash():
    wl = synthetic_cluster_workload(16, n_families=4, seed=7,
                                    minutes=10, peak_rpm=80.0)
    results = compare_strategies(wl, n_nodes=4, node_budget_mb=512.0,
                                 strategies=("sharing", "hash"), seed=7)
    for strategy, payload in results.items():
        assert payload["conservation"]["holds"], strategy
        assert payload["requests"] > 0
        assert payload["requests"] == sum(
            r["requests"] for r in payload["per_node"])
        assert all(r["conservation_holds"] for r in payload["per_node"])
    # the acceptance claim: same total memory, fewer cold starts
    assert (results["sharing"]["cold_start_ratio"]
            <= results["hash"]["cold_start_ratio"])
    assert results["sharing"]["percentiles_merged"]


def test_sim_lose_node_mid_replay_conserves():
    wl = _wl()
    sim = ClusterSimulator(wl, n_nodes=3, node_budget_mb=512.0,
                           strategy="sharing", seed=3)
    sim.begin(wl.trace.name)
    arrivals = list(wl.trace)[:300]
    victim = sim.ring.nodes[0]
    for i, req in enumerate(arrivals):
        if i == 150:
            sim.lose_node(victim, req.t)
        sim.route(req)
    payload = sim.finish(arrivals[-1].t + 120.0)
    assert payload["conservation"]["holds"]
    assert payload["lost_nodes"] == [victim]
    # the victim's ledger survives the loss as a per_node row
    row = next(r for r in payload["per_node"] if r["node"] == victim)
    assert row["lost"] and row["conservation_holds"]
    # its apps all migrated to survivors
    assert victim not in set(payload["placement"].values())
    assert all(m["reason"] == "node_loss" for m in payload["migrations"])


def test_sim_join_node_mid_replay_conserves():
    wl = _wl()
    sim = ClusterSimulator(wl, n_nodes=2, node_budget_mb=512.0,
                           strategy="hash", seed=3)
    sim.begin(wl.trace.name)
    arrivals = list(wl.trace)[:300]
    for i, req in enumerate(arrivals):
        if i == 100:
            joined = sim.join_node("node-late", req.t)
            assert joined["moved"] >= 0
        sim.route(req)
    payload = sim.finish(arrivals[-1].t + 120.0)
    assert payload["conservation"]["holds"]
    assert payload["nodes"] == 3
    moves = [m for m in payload["migrations"]
             if m["reason"] == "node_join"]
    # rendezvous hashing: join moves apps only ONTO the newcomer
    assert all(m["to"] == "node-late" for m in moves)


def test_sim_chaos_node_loss_conserves():
    wl = _wl()
    plan = FaultPlan(events=[FaultEvent("node_loss", at=40)],
                     seed=3, name="one-node-down")
    inject = FaultInjector(plan, simulate=True)
    sim = ClusterSimulator(wl, n_nodes=3, node_budget_mb=512.0,
                           strategy="sharing", seed=3,
                           fault_hook=inject)
    payload = sim.replay(limit=400)
    assert inject.counts().get("node_loss") == 1
    assert len(payload["lost_nodes"]) == 1
    assert payload["conservation"]["holds"]
    # the request whose routing tripped the fault was NOT lost: the
    # router ledger still matches the node ledgers exactly
    assert payload["conservation"]["routed"] == payload["requests"]


# ---------------------------------------------------------------------------
# cluster_summary artifact
# ---------------------------------------------------------------------------

def test_cluster_summary_artifact_round_trip(tmp_path):
    wl = _wl()
    sim = ClusterSimulator(wl, n_nodes=2, node_budget_mb=512.0,
                           strategy="sharing", seed=3)
    payload = sim.replay(limit=200)
    path = tmp_path / "cluster_summary.json"
    save_cluster_summary(payload, str(path), meta={"test": True})
    loaded = load_cluster_summary(str(path))
    assert loaded["strategy"] == "sharing"
    assert loaded["requests"] == payload["requests"]
    assert loaded["conservation"]["holds"]
    with open(path) as fh:
        envelope = json.load(fh)
    assert envelope["kind"] == "cluster_summary"
    assert envelope["schema_version"] == 1


def test_cluster_summary_artifact_rejects_missing_keys(tmp_path):
    with pytest.raises(ValueError, match="missing"):
        save_cluster_summary({"source": "x", "strategy": "sharing"},
                             str(tmp_path / "bad.json"))


def test_node_conserves_helper():
    assert node_conserves({"requests": 5, "served": 3, "sheds": 1,
                           "flushed": 1})
    assert not node_conserves({"requests": 5, "served": 3})
    assert node_conserves({})  # vacuous: 0 == 0


# ---------------------------------------------------------------------------
# socket round-trip: real NodeAgents + ClusterRouter, in-process
# ---------------------------------------------------------------------------

def _agent_for(wl, apps, node_id, **kw):
    profiles = {a: wl.profiles[a] for a in apps}
    manager = FleetManager(profiles, IdleTimeoutPolicy(timeout_s=120.0),
                           budget_mb=2048.0,
                           queue=QueueConfig(depth=32,
                                             max_concurrency=4))
    agent = NodeAgent(SimFleetBackend(manager), node_id=node_id,
                      port=0, **kw)
    agent.start()
    return agent


def _clients_for(agents):
    return {a.node_id: NodeClient(a.node_id, a.host, a.port)
            for a in agents}


def test_node_agent_socket_round_trip():
    wl = _wl(n_apps=4, families=2)
    half = len(wl.apps) // 2
    agents = [_agent_for(wl, wl.apps[:half], "nodeA"),
              _agent_for(wl, wl.apps[half:], "nodeB")]
    try:
        router = ClusterRouter(_clients_for(agents),
                               strategy="sharing",
                               hot_sets=wl.hot_sets, seed=3)
        placement = router.connect()
        assert set(placement) == set(wl.apps)
        # each app landed on the one node that deploys it
        assert all(placement[a] == "nodeA" for a in wl.apps[:half])
        assert all(placement[a] == "nodeB" for a in wl.apps[half:])
        n = 120
        for i in range(n):
            reply = router.route(wl.apps[i % len(wl.apps)])
            assert reply["outcome"] not in ("error",), reply
        payload = router.shutdown()
    finally:
        for agent in agents:
            agent.result()
    assert payload["requests"] == n
    assert payload["conservation"]["holds"]
    assert payload["conservation"]["routed"] == n
    assert payload["nodes"] == 2
    assert payload["percentiles_merged"]
    assert payload["p99_ms"] > 0.0


def test_node_agent_stats_and_unknown_cmd():
    wl = _wl(n_apps=2, families=1)
    agent = _agent_for(wl, wl.apps, "solo")
    try:
        with NodeClient("solo", agent.host, agent.port) as client:
            hello = client.call({"cmd": "hello"})
            assert hello["ok"] and hello["node"] == "solo"
            assert sorted(hello["apps"]) == sorted(wl.apps)
            client.call({"app": wl.apps[0]})
            stats = client.call({"cmd": "stats"})
            assert stats["ok"] and stats["stats"]["requests"] == 1
            bad = client.call({"cmd": "launch-missiles"})
            assert not bad["ok"] and "unknown" in bad["error"]
            missing = client.call({"oops": True})
            assert not missing["ok"]
            unknown_app = client.call({"app": "ghost-app"})
            assert not unknown_app["ok"]
    finally:
        agent.result()


def test_node_agent_concurrent_feeders():
    wl = _wl(n_apps=2, families=1)
    agent = _agent_for(wl, wl.apps, "multi")
    errors = []

    def feeder(app, n):
        try:
            with NodeClient("multi", agent.host, agent.port) as c:
                for _ in range(n):
                    c.call({"app": app})
        except Exception as exc:  # surfaced below
            errors.append(exc)

    try:
        threads = [threading.Thread(target=feeder, args=(app, 25))
                   for app in wl.apps for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30.0)
        assert not errors
        payload = agent.result()
    finally:
        agent.result()
    assert payload["requests"] == 4 * 25
    assert node_conserves(payload)


def test_router_replaces_lost_nodes_apps_with_conservation():
    """The ISSUE satellite: chaos node_loss at the router's route site
    — the lost node's apps re-place onto a surviving advertiser and
    the global ledger still balances."""
    wl = _wl(n_apps=4, families=2)
    # both nodes deploy every app, so the survivor can absorb them all
    agents = [_agent_for(wl, wl.apps, "nodeA"),
              _agent_for(wl, wl.apps, "nodeB")]
    plan = FaultPlan(events=[FaultEvent("node_loss", at=30)],
                     seed=3, name="router-node-down")
    inject = FaultInjector(plan, simulate=True)
    try:
        router = ClusterRouter(_clients_for(agents),
                               strategy="sharing",
                               hot_sets=wl.hot_sets, seed=3,
                               fault_hook=inject)
        router.connect()
        before = dict(router.placement)
        assert len(set(before.values())) == 2  # both nodes own apps
        n = 90
        for i in range(n):
            reply = router.route(wl.apps[i % len(wl.apps)])
            assert reply["ok"], reply
        assert inject.counts().get("node_loss") == 1
        assert len(router.lost_nodes) == 1
        lost = router.lost_nodes[0]
        survivor = ({"nodeA", "nodeB"} - {lost}).pop()
        # every app the dead node owned now lives on the survivor
        assert set(router.placement.values()) == {survivor}
        assert all(m["reason"] == "node_loss"
                   for m in router.migrations)
        assert {m["app"] for m in router.migrations} == {
            a for a, node in before.items() if node == lost}
        payload = router.shutdown()
    finally:
        for agent in agents:
            agent.result()
    # nothing was lost: the faulted request was re-routed, not dropped
    assert payload["requests"] == n
    assert payload["conservation"]["holds"]
    assert payload["lost_nodes"] == [lost]
    lost_row = next(r for r in payload["per_node"]
                    if r["node"] == lost)
    assert lost_row["lost"] and lost_row["conservation_holds"]


def test_node_agent_drain_on_disconnect():
    wl = _wl(n_apps=2, families=1)
    agent = _agent_for(wl, wl.apps, "eof",
                       drain_on_disconnect=True)
    client = NodeClient("eof", agent.host, agent.port)
    client.connect()
    client.call({"app": wl.apps[0]})
    client.close()  # last feeder gone -> stdin-EOF semantics
    payload = agent.serve_forever()
    assert payload["requests"] == 1
    assert node_conserves(payload)


# ---------------------------------------------------------------------------
# the real two-node smoke (subprocess tier)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_two_node_cluster_smoke_subprocess():
    smoke = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "tools", "cluster_smoke.py")
    proc = subprocess.run(
        [sys.executable, smoke, "--n-apps", "4", "--families", "2",
         "--minutes", "2", "--limit", "120"],
        capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "cluster-smoke: OK" in proc.stdout
