"""Two-tier shared-base zygote tests: the cross-app shared hot set
(repro.pool.sharing), its artifact kind, shared/private fleet
accounting in FleetManager, the cached percentile pools, and (slow
tier) real base-zygote spawn / crash recovery / rewarm hot-swap."""

import json
import math
import os
import signal
import statistics
import threading
import time

import pytest

from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import LibraryStats
from repro.pool import (
    AppProfile,
    FleetManager,
    PercentilePool,
    ProfileGuidedPolicy,
    Request,
    Trace,
    ZygoteFleet,
    compute_shared_hot_set,
    intersect_hot_sets,
)


def _report(app: str, libs, *, e2e_s: float = 0.2,
            init_s: float = 0.15) -> OptimizationReport:
    stats = [LibraryStats(name=lib, utilization=0.9,
                          init_s=init_s / max(len(libs), 1),
                          init_share=init_s / e2e_s, runtime_samples=50,
                          file="<x>") for lib in libs]
    return OptimizationReport(application=app, e2e_s=e2e_s,
                              total_init_s=init_s, qualifies=True,
                              stats=stats, defer_targets=[])


def _trace(reqs, duration):
    return Trace("manual", [Request(t, app) for t, app in reqs], duration)


# ---------------------------------------------------------------------------
# intersect_hot_sets / compute_shared_hot_set
# ---------------------------------------------------------------------------

def test_intersect_threshold_and_prefix_widening():
    hot = {"a": ["libx", "liby.core"],
           "b": ["libx.sub", "libz"],
           "c": ["libq"]}
    # libx is hot for a (whole package) and b (a submodule): the widest
    # common prefix joins the shared set; singletons do not
    assert intersect_hot_sets(hot, min_members=2) == ["libx"]
    assert intersect_hot_sets(hot, min_members=3) == []
    assert sorted(intersect_hot_sets(hot, min_members=1)) == [
        "libq", "libx", "liby.core", "libz"]
    assert intersect_hot_sets({}, min_members=1) == []


def test_intersect_flat_namespace_never_synthesizes_prefixes():
    # component-style names: "expert.1"/"expert.2" share no loadable
    # parent, so prefixes=False must not invent "expert"
    hot = {"m1": ["expert.1", "weights.core"],
           "m2": ["expert.2", "weights.core"]}
    assert intersect_hot_sets(hot, min_members=2,
                              prefixes=False) == ["weights.core"]
    assert intersect_hot_sets(hot, min_members=2) == ["expert",
                                                      "weights.core"]


def test_compute_shared_hot_set_deltas_and_counts():
    reports = {"a": _report("a", ["libx", "liby.core"]),
               "b": _report("b", ["libx.sub", "libz"]),
               "c": _report("c", ["libq"])}
    sh = compute_shared_hot_set(reports, min_apps=2)
    assert sh.modules == ["libx"]
    assert sh.counts == {"libx": 2}
    # each app's delta excludes anything the base already covers
    assert sh.per_app_delta == {"a": ["liby.core"], "b": ["libz"],
                                "c": ["libq"]}
    # delta() for an unknown app filters the given hot set
    assert sh.delta("zzz", ["libx.other", "libnew"]) == ["libnew"]
    # min_fraction overrides min_apps: 100% of 3 apps = strict
    assert compute_shared_hot_set(reports,
                                  min_fraction=1.0).modules == []


def test_shared_hot_set_artifact_round_trip(tmp_path):
    from repro.api import load_shared_hot_set, save_shared_hot_set
    from repro.api.artifact import load_any
    reports = {"a": _report("a", ["libx"]),
               "b": _report("b", ["libx", "libz"])}
    sh = compute_shared_hot_set(reports, min_apps=2)
    path = str(tmp_path / "shared.json")
    save_shared_hot_set(sh, path, meta={"source": "test"})
    back = load_shared_hot_set(path)
    assert back.modules == sh.modules
    assert back.per_app_delta == sh.per_app_delta
    assert back.apps == sh.apps and back.counts == sh.counts
    # the envelope dispatches through load_any too
    art = load_any(path)
    assert art.kind == "shared_hot_set" and art.meta == {"source": "test"}


def test_shared_hot_set_artifact_corruption(tmp_path):
    from repro.api import ArtifactError, load_shared_hot_set
    from repro.api.artifacts import SharedHotSetArtifact
    path = str(tmp_path / "bad.json")
    with open(path, "w") as fh:
        fh.write('{"kind": "shared_hot_set", "schema_version": 1, '
                 '"modules": ["x"]')  # truncated JSON
    with pytest.raises(ArtifactError, match="bad.json"):
        load_shared_hot_set(path)
    with open(path, "w") as fh:
        json.dump({"kind": "shared_hot_set", "schema_version": 1,
                   "modules": ["x"]}, fh)  # missing required keys
    with pytest.raises(ArtifactError, match="missing keys"):
        load_shared_hot_set(path)
    with open(path, "w") as fh:
        json.dump({"kind": "trace", "schema_version": 1}, fh)
    with pytest.raises(ArtifactError, match="kind mismatch"):
        SharedHotSetArtifact.load(path)


# ---------------------------------------------------------------------------
# FleetManager: shared vs private accounting
# ---------------------------------------------------------------------------

def _profiles(private_mb):
    return {
        app: AppProfile(app=app, cold_init_ms=200.0, invoke_ms=10.0,
                        warm_init_ms=5.0, rss_mb=100.0,
                        zygote_rss_mb=80.0, zygote_private_mb=priv)
        for app, priv in private_mb.items()
    }


def _pg_policy(apps):
    pol = ProfileGuidedPolicy(rate_hint_per_s=0.5)
    for app in apps:
        pol.add_report(_report(app, ["libhot"]))
    return pol


def test_shared_base_lowers_memory_at_equal_cold_ratio():
    profiles = _profiles({"a": 10.0, "b": 10.0, "c": 10.0})
    reqs = [(0.5 * i, "abc"[i % 3]) for i in range(120)]
    trace = _trace(reqs, 80.0)
    one = FleetManager(profiles, _pg_policy("abc"),
                       budget_mb=600.0).replay(trace)
    two = FleetManager(profiles, _pg_policy("abc"), budget_mb=600.0,
                       shared_base_mb=60.0).replay(trace)
    assert two.cold_start_ratio <= one.cold_start_ratio
    assert two.memory_mb_s < one.memory_mb_s
    assert two.shared_base_mb == 60.0 and two.base_mb_s > 0
    assert one.shared_base_mb == 0.0 and one.base_mb_s == 0.0
    # the base lands in the artifact payload
    payload = two.artifact_payload()
    assert payload["shared_base_mb"] == 60.0
    assert payload["base_gb_s"] == pytest.approx(
        two.base_mb_s / 1024.0, rel=1e-3)


def test_zygote_eviction_ranks_on_incremental_memory():
    """A big-RSS zygote that is mostly shared pages must survive budget
    pressure that evicts a smaller-RSS but mostly-private zygote —
    the inversion the two-tier accounting exists to produce."""
    profiles = {
        # x: 80 MB RSS but only 5 MB above the base (shared-heavy)
        "x": AppProfile(app="x", cold_init_ms=200.0, invoke_ms=10.0,
                        warm_init_ms=5.0, rss_mb=40.0,
                        zygote_rss_mb=80.0, zygote_private_mb=5.0),
        # y: 60 MB RSS, 55 MB private (private-heavy)
        "y": AppProfile(app="y", cold_init_ms=200.0, invoke_ms=10.0,
                        warm_init_ms=5.0, rss_mb=40.0,
                        zygote_rss_mb=60.0, zygote_private_mb=55.0),
    }
    reqs = [(0.4 * i, "xy"[i % 2]) for i in range(40)]

    def run(shared_base_mb, budget):
        mgr = FleetManager(profiles, _pg_policy("xy"), budget_mb=budget,
                           shared_base_mb=shared_base_mb)
        mgr.replay(_trace(reqs, 20.0))
        return mgr

    # one-per-app accounting: x (80 MB) is the costlier zygote
    mgr = run(0.0, 1000.0)
    assert mgr.zygote_evict_cost("x", 16.0) \
        < mgr.zygote_evict_cost("y", 16.0)
    # two-tier accounting inverts the ranking: evicting x frees 5 MB,
    # evicting y frees 55 MB
    mgr = run(75.0, 1000.0)
    assert mgr.zygote_evict_cost("y", 16.0) \
        < mgr.zygote_evict_cost("x", 16.0)


def test_shared_base_headroom_admits_more_zygotes():
    """The budget that fits only one full-RSS zygote fits both apps'
    incremental deltas once the base is shared."""
    profiles = _profiles({"a": 8.0, "b": 8.0, "c": 8.0})
    reqs = [(0.5 * i, "abc"[i % 3]) for i in range(60)]
    trace = _trace(reqs, 40.0)
    # 230 MB: zygote (80) + instance (100) fits once; three would
    # need 3*80 + instances
    one = FleetManager(profiles, _pg_policy("abc"),
                       budget_mb=300.0).replay(trace)
    two = FleetManager(profiles, _pg_policy("abc"), budget_mb=300.0,
                       shared_base_mb=70.0).replay(trace)
    assert len(two.zygote_apps) > len(one.zygote_apps)
    assert set(two.zygote_apps) >= set(one.zygote_apps)
    # zygote-less apps in the one-per-app fleet paid full cold starts
    # that the two-tier fleet turns into forks or warm hits
    assert two.cold_starts <= one.cold_starts


# ---------------------------------------------------------------------------
# PercentilePool: the cached fleet-level percentile fix
# ---------------------------------------------------------------------------

def test_percentile_pool_matches_quantiles_and_invalidates():
    lists = [[5.0, 1.0], [9.0, 3.0, 7.0]]
    pool = PercentilePool(lambda: lists)
    merged = sorted(x for xs in lists for x in xs)
    grid = statistics.quantiles(merged, n=100, method="inclusive")
    assert pool.percentile(0.50) == grid[49]
    assert pool.percentile(0.99) == grid[98]
    assert pool.mean == pytest.approx(statistics.fmean(merged))
    assert len(pool) == 5
    # growth invalidates the cache
    lists[0].append(100.0)
    assert pool.percentile(0.99) == statistics.quantiles(
        sorted(merged + [100.0]), n=100, method="inclusive")[98]
    # so does a same-length replacement (the tail changes)
    lists[1] = [1000.0, 1000.0, 1001.0]
    assert pool.percentile(0.99) > 500.0
    # empty and single-element pools stay NaN-safe / flat
    empty = PercentilePool(lambda: [[]])
    assert math.isnan(empty.percentile(0.5)) and math.isnan(empty.mean)
    single = PercentilePool(lambda: [[42.0]])
    assert single.percentile(0.5) == 42.0
    assert single.percentile(0.99) == 42.0


def test_fleet_summary_percentiles_use_cached_pools():
    profiles = _profiles({"a": 0.0})
    mgr = FleetManager(profiles, _pg_policy("a"), budget_mb=1000.0)
    s = mgr.replay(_trace([(0.1 * i, "a") for i in range(50)], 10.0))
    lats = sorted(x for r in s.per_app.values() for x in r.latencies_ms)
    grid = statistics.quantiles(lats, n=100, method="inclusive")
    assert s.p50_ms == grid[49] and s.p99_ms == grid[98]
    assert s.mean_ms == pytest.approx(statistics.fmean(lats))
    # repeated access is stable (served from the cache)
    assert s.p99_ms == s.p99_ms and s.summary()["p99_ms"] is not None


# ---------------------------------------------------------------------------
# Real two-tier fork hierarchy (slow tier: subprocesses)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def suite_root_dir():
    from repro.benchsuite.genlibs import build_suite
    return build_suite()


def _igraph_report(app: str) -> OptimizationReport:
    return _report(app, ["fakelib_igraph"])


@pytest.mark.slow
def test_base_zygote_spawn_exec_and_fast_path(suite_root_dir):
    from repro.pool.forkserver import BaseZygote, ForkServer
    app_dir = os.path.join(suite_root_dir, "apps", "graph_bfs")
    with BaseZygote(preload=["fakelib_igraph"],
                    search_paths=[os.path.join(app_dir, "libs")]) as base:
        assert base.ready["mode"] == "base"
        assert "fakelib_igraph" in base.ready["preloaded"]
        with ForkServer(app_dir, preload=[], base=base) as fs:
            assert fs.ready.get("from_base") is True
            assert "fakelib_igraph" in fs.ready["preloaded"]
            m = fs.exec(invocations=1, seed=1)
            assert m["init_ms"] > 0
            # fast path: batched preload + exec in one roundtrip
            m2 = fs.exec(invocations=1, seed=2,
                         preload=["fakelib_igraph", "json"])
            assert m2["init_ms"] > 0
            assert "json" in fs.preload_modules
            # a failing fast-path preload still serves the exec but is
            # recorded and never re-sent
            m3 = fs.exec(invocations=1, seed=3,
                         preload=["definitely_missing_mod"])
            assert m3["init_ms"] > 0
            assert any(e.startswith("definitely_missing_mod")
                       for e in fs.preload_errors)
            fs.exec(invocations=1, seed=4,
                    preload=["definitely_missing_mod"])
            assert len(fs.preload_errors) == 1
            # memory helpers see the spawned pid
            mem = fs.memory_kb()
            assert mem["rss_kb"] > 0
    # base down: its spawn channel refuses
    from repro.pool.forkserver import ForkServerError
    with pytest.raises(ForkServerError):
        base.spawn_app(app_dir)


@pytest.mark.slow
def test_zygote_fleet_shared_base_dispatch_and_accounting(
        suite_root_dir):
    apps = {name: os.path.join(suite_root_dir, "apps", name)
            for name in ["graph_bfs", "graph_mst"]}
    reports = {a: _igraph_report(a) for a in apps}
    with ZygoteFleet(apps, reports=reports, shared_base=True) as fleet:
        assert fleet.base is not None and fleet.base.alive
        assert fleet.shared.modules == ["fakelib_igraph"]
        boot = fleet._base_info()["shared_base"]
        assert boot["rss_mb"] > 0 and boot["swaps"] == 0
        # both zygotes came from the base with an empty delta
        for fs in fleet.servers.values():
            assert fs.base is fleet.base
            assert fs.ready.get("from_base") is True
        m = fleet.dispatch("graph_bfs", handler="bfs", seed=1)
        assert m["path"] == "pool"
        # incremental accounting: fleet-resident memory is base + deltas,
        # strictly below the sum of full per-zygote RSS
        full = sum(fs.rss_kb() for fs in fleet.servers.values()) / 1024.0
        assert 0 < fleet.used_mb() < full + fleet.base_rss_mb()
        for app in fleet.servers:
            assert fleet.incremental_mb(app) <= \
                fleet.servers[app].rss_kb() / 1024.0


@pytest.mark.slow
def test_base_zygote_crash_recovery_reforks_apps(suite_root_dir,
                                                 tmp_path):
    """Kill the base *and* an app zygote: the rewarm tick reboots the
    base and re-forks the app from it, and queued dispatches issued
    after the crash are served (pool path), not lost."""
    from repro.api import save_report
    apps = {name: os.path.join(suite_root_dir, "apps", name)
            for name in ["graph_bfs", "graph_mst"]}
    reports_dir = str(tmp_path / "reports")
    os.makedirs(reports_dir)
    for a in apps:
        save_report(_igraph_report(a),
                    os.path.join(reports_dir, f"{a}.json"))
    with ZygoteFleet(apps, reports={a: _igraph_report(a) for a in apps},
                     shared_base=True) as fleet:
        base_pid = fleet.base.pid
        bfs_pid = fleet.servers["graph_bfs"].pid
        os.kill(base_pid, signal.SIGKILL)
        os.kill(bfs_pid, signal.SIGKILL)
        deadline = time.time() + 10
        while (fleet.base.alive
               or fleet.servers["graph_bfs"].alive) \
                and time.time() < deadline:
            time.sleep(0.05)
        assert not fleet.base.alive
        # rewarm tick: reboots base, re-forks the dead app zygote
        out = fleet.rewarm_from_dir(reports_dir)
        assert out["graph_bfs"].get("restarted") or \
            out["graph_bfs"]["ok"]
        assert fleet.base.alive and fleet.base.pid != base_pid
        assert fleet.servers["graph_bfs"].alive
        assert fleet.servers["graph_bfs"].pid != bfs_pid
        # queued work after recovery lands on the pool path
        m = fleet.dispatch("graph_bfs", handler="bfs", seed=9)
        assert m["path"] == "pool" and not m["fallback"]


@pytest.mark.slow
def test_rewarm_hot_swap_mid_stream_drops_nothing(suite_root_dir,
                                                  tmp_path):
    """Grow the shared hot set while a dispatch thread hammers the
    fleet: the base hot-swap must not shed or fail a single request."""
    from repro.api import save_report
    apps = {name: os.path.join(suite_root_dir, "apps", name)
            for name in ["graph_bfs", "graph_mst"]}
    reports_dir = str(tmp_path / "reports")
    os.makedirs(reports_dir)
    # boot with per-app reports whose intersection is empty...
    first = {"graph_bfs": _report("graph_bfs", ["fakelib_igraph"]),
             "graph_mst": _report("graph_mst", [])}
    with ZygoteFleet(apps, reports=first, shared_base=True) as fleet:
        assert fleet.shared.modules == []
        results = []
        stop = threading.Event()

        def hammer():
            i = 0
            while not stop.is_set():
                results.append(
                    fleet.dispatch("graph_bfs", handler="bfs",
                                   seed=100 + i))
                i += 1

        t = threading.Thread(target=hammer)
        t.start()
        try:
            time.sleep(0.3)
            # ...then deploy reports that put igraph in both hot sets
            for a in apps:
                save_report(_igraph_report(a),
                            os.path.join(reports_dir, f"{a}.json"))
            out = fleet.rewarm_from_dir(reports_dir)
            time.sleep(0.3)
        finally:
            stop.set()
            t.join(timeout=30)
        assert out["_base"]["swapped"] is True
        assert out["_base"]["errors"] == {}
        assert fleet.shared.modules == ["fakelib_igraph"]
        assert fleet.base_swaps == 1
        # every dispatch during the swap succeeded on a zygote fork
        assert results and all(r["path"] == "pool" for r in results)
        assert all(not r["fallback"] for r in results)


@pytest.mark.slow
def test_daemon_rewarm_tick_hot_swaps_base_without_sheds(
        suite_root_dir, tmp_path):
    """The acceptance criterion end-to-end: the serve daemon's rewarm
    tick swaps the base under live traffic and the summary shows every
    request served — zero sheds, zero errors, zero flushes."""
    from repro.api import save_report
    from repro.pool import QueueConfig
    from repro.pool.daemon import FleetDaemon, RealFleetBackend

    apps = {name: os.path.join(suite_root_dir, "apps", name)
            for name in ["graph_bfs", "graph_mst"]}
    reports_dir = str(tmp_path / "reports")
    os.makedirs(reports_dir)
    first = {"graph_bfs": _report("graph_bfs", ["fakelib_igraph"]),
             "graph_mst": _report("graph_mst", [])}
    fleet = ZygoteFleet(apps, reports=first, shared_base=True)
    backend = RealFleetBackend(
        fleet, queue=QueueConfig(depth=64, max_concurrency=2),
        reports_dir=reports_dir)
    daemon = FleetDaemon(backend)
    daemon.start("hot-swap")
    try:
        n = 0
        for i in range(6):
            for app in apps:
                assert daemon.submit(Request(t=float(n), app=app,
                                             handler=None)) == "queued"
                n += 1
            if i == 2:
                # deploy reports that change the shared set mid-stream
                for a in apps:
                    save_report(_report(a, ["fakelib_igraph"]),
                                os.path.join(reports_dir, f"{a}.json"))
                tick = daemon.rewarm_now()
                assert tick["_base"]["swapped"] is True
            time.sleep(0.05)
    finally:
        payload = daemon.shutdown(flush=False)
    assert payload["requests"] == n
    assert payload["served"] == n
    assert payload["sheds"] == 0 and payload["flushed"] == 0
    assert payload.get("errors", 0) == 0
    assert payload["shared_base"]["swaps"] == 1
    assert payload["shared_base"]["modules"] == ["fakelib_igraph"]
    assert payload["rewarm_ticks"] == 1
    # everything that ran went down the fork path, before and after
    assert payload["cold_starts"] == 0
