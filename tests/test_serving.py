"""Serving engine + SLIMSTART Level-B behaviour tests (reduced configs)."""

import numpy as np
import pytest

from repro.configs import get_reduced
from repro.serving import ContinuousBatcher, LoadPolicy, Request, ServingEngine


@pytest.fixture(scope="module")
def moe_engine():
    cfg = get_reduced("granite-moe-1b-a400m")
    eng = ServingEngine(cfg, batch_size=1, prefill_len=8, max_len=32)
    eng.cold_start()
    return eng


def test_eager_cold_start_builds_everything():
    cfg = get_reduced("qwen2.5-32b")
    eng = ServingEngine(cfg, batch_size=1, prefill_len=8, max_len=24)
    dt = eng.cold_start()
    assert dt > 0
    rep = eng.report()
    assert rep["total_init_s"] > 0
    # every compile component materialized under the eager policy
    for row in rep["components"]:
        if row["group"] == "compile":
            assert row["ready"], row


def test_lazy_policy_defers_and_first_use_pays():
    cfg = get_reduced("whisper-large-v3")
    lazy = LoadPolicy(lazy_groups=frozenset({"compile", "frontend"}))
    eng = ServingEngine(cfg, policy=lazy, batch_size=1, prefill_len=8,
                        max_len=24)
    cold_lazy = eng.cold_start()

    eager = ServingEngine(cfg, batch_size=1, prefill_len=8, max_len=24)
    cold_eager = eager.cold_start()
    assert cold_lazy < cold_eager, \
        "deferring compilation must shrink the cold start"

    # the deferred entry still works — first use materializes it
    toks = np.random.default_rng(0).integers(0, cfg.vocab, (1, 8))
    out, lat = eng.serve("transcribe", toks, max_new_tokens=3)
    assert out.shape == (1, 3)
    assert eng.registry["compile.transcribe"].ready


def test_moe_lazy_experts_materialize_on_route(moe_engine):
    eng = moe_engine
    cfg = eng.cfg
    toks = np.random.default_rng(1).integers(0, cfg.vocab, (1, 8))
    out, _ = eng.serve("generate", toks, max_new_tokens=4)
    assert out.shape == (1, 4)
    rep = eng.report()
    assert "expert_utilization" in rep
    util = rep["expert_utilization"]
    assert abs(sum(util.values()) - 1.0) < 1e-2
    routed = [e for e, m in enumerate(eng.expert_mass) if m > 0]
    for e in routed:
        assert eng.registry[f"expert.{e}"].ready


def test_report_feeds_policy(moe_engine):
    rep = moe_engine.report()
    pol = LoadPolicy.from_report(rep)
    # at least something is deferred and something prewarmed
    assert isinstance(pol.lazy_names, frozenset)
    # components below the 2% utilization threshold are lazy
    for row in rep["components"]:
        if row["utilization"] < 0.02 and row["init_s"] > 0:
            assert row["component"] in pol.lazy_names


def test_continuous_batcher_matches_sequential():
    """Batched continuous decoding must produce the same tokens as
    serving each request alone (greedy decoding is deterministic)."""
    import jax
    import jax.numpy as jnp
    from repro.models.model import decode_step, init_cache, init_params, \
        prefill

    cfg = get_reduced("granite-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_slots, cache_len = 2, 48

    def prefill_fn(tokens):
        logits, caches, _ = prefill(cfg, params, tokens,
                                    cache_len=cache_len)
        return jnp.argmax(logits, -1).astype(jnp.int32), caches

    @jax.jit
    def decode_fn(tok, pos, caches):
        logits, caches = decode_step(cfg, params, tok, pos, caches)
        return jnp.argmax(logits, -1).astype(jnp.int32)[:, None], caches

    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, (L,)) for L in (5, 7, 6)]

    # sequential reference
    ref_outs = []
    for p in prompts:
        first, caches = prefill_fn(jnp.asarray(p[None], jnp.int32))
        toks = [int(np.asarray(first)[0])]
        cur = first[:, None]
        for i in range(3):
            pos = jnp.full((1,), len(p) + i, jnp.int32)
            cur, caches = decode_fn(cur, pos, caches)
            toks.append(int(np.asarray(cur)[0, 0]))
        ref_outs.append(toks)

    batcher = ContinuousBatcher(
        prefill_fn, decode_fn, init_cache(cfg, n_slots, cache_len),
        n_slots=n_slots)
    for i, p in enumerate(prompts):
        batcher.submit(Request(rid=i, tokens=p, max_new_tokens=4))
    stats = batcher.run_until_drained()
    assert stats["finished"] == 3
    got = {r.rid: r.out_tokens for r in batcher.finished}
    for i, ref in enumerate(ref_outs):
        assert got[i] == ref, f"request {i}: {got[i]} != {ref}"
