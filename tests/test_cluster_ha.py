"""Cluster HA tests: the retry/timeout/backoff policy, lease-witness
election with epoch fencing, ledger replication to a warm standby,
leader failover (chaos ``router_loss``), warm-state handoff on planned
decommission (and its ``handoff_stall`` cold fallback), and the
double-failure shed path.

The same conservation invariant as test_cluster.py — ``requests ==
served + sheds + flushed + errors + abandoned`` per node, globally,
and against the router ledger — must survive every failure injected
here: that is the point of the HA tier.
"""

import argparse
import socket
import threading
import time

import pytest

from repro.cluster import (ClusterRouter, ElectionLost, FrameClosed,
                           FrameError, LeaseWitness, LedgerReplicator,
                           NodeAgent, NodeClient, ReplicatedRouter,
                           RetryExhausted, RetryPolicy, StandbyRouter,
                           elect, synthetic_cluster_workload)
from repro.cluster.ha import (add_retry_flags, apply_ledger_entry,
                              empty_ledger)
from repro.pool import (FleetManager, IdleTimeoutPolicy, QueueConfig,
                        SimFleetBackend)
from repro.pool.chaos import FaultEvent, FaultInjector, FaultPlan


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------

def test_retry_policy_classing():
    assert RetryPolicy.retryable(ConnectionRefusedError())
    assert RetryPolicy.retryable(socket.timeout())
    assert RetryPolicy.retryable(FrameClosed("eof"))
    # a protocol desync means resending would desync further
    assert not RetryPolicy.retryable(FrameError("bad prefix"))
    assert not RetryPolicy.retryable(ValueError("logic bug"))


def test_retry_policy_backoff_exponential_capped_and_seeded():
    p = RetryPolicy(backoff_base_s=0.1, backoff_cap_s=0.4, jitter=0.0)
    assert [p.backoff_s(a) for a in range(4)] == [0.1, 0.2, 0.4, 0.4]
    j = RetryPolicy(backoff_base_s=0.1, backoff_cap_s=0.4, jitter=0.5,
                    seed=7)
    a, b = j.backoff_s(1, j.rng()), j.backoff_s(1, j.rng())
    assert a == b  # seeded: deterministic
    # jitter stays within ±jitter/2 of the exponential base
    assert 0.2 * 0.75 <= a <= 0.2 * 1.25


def test_retry_policy_run_retries_transient_then_succeeds():
    calls, slept = [], []
    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise ConnectionResetError("transient")
        return {"ok": True}
    p = RetryPolicy(attempts=3, backoff_base_s=0.01, jitter=0.0)
    assert p.run(flaky, sleep=slept.append) == {"ok": True}
    assert len(calls) == 3
    assert slept == [0.01, 0.02]


def test_retry_policy_run_exhausts_as_connection_error():
    def dead():
        raise ConnectionRefusedError("nope")
    p = RetryPolicy(attempts=2, backoff_base_s=0.0)
    with pytest.raises(RetryExhausted) as ei:
        p.run(dead, what="test call", sleep=lambda _s: None)
    # failover paths catch ConnectionError; the cause is chained
    assert isinstance(ei.value, ConnectionError)
    assert isinstance(ei.value.__cause__, ConnectionRefusedError)


def test_retry_policy_terminal_error_not_retried():
    calls = []
    def desync():
        calls.append(1)
        raise FrameError("oversize frame")
    with pytest.raises(FrameError):
        RetryPolicy(attempts=5).run(desync, sleep=lambda _s: None)
    assert len(calls) == 1


def test_retry_policy_validates():
    with pytest.raises(ValueError):
        RetryPolicy(attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.5)
    with pytest.raises(ValueError):
        RetryPolicy(deadline_s=-1.0)


def test_retry_policy_from_cli_flags():
    parser = argparse.ArgumentParser()
    add_retry_flags(parser)
    args = parser.parse_args(["--retry-attempts", "5",
                              "--retry-backoff-s", "0.01",
                              "--retry-deadline-s", "7.5"])
    p = RetryPolicy.from_args(args)
    assert p.attempts == 5
    assert p.backoff_base_s == 0.01
    assert p.deadline_s == 7.5
    # unspecified flags keep the dataclass defaults
    assert p.call_timeout_s == RetryPolicy().call_timeout_s
    assert p.to_dict()["attempts"] == 5


# ---------------------------------------------------------------------------
# Lease witness + election
# ---------------------------------------------------------------------------

def test_lease_witness_grant_renew_and_epoch_fence():
    t = [0.0]
    w = LeaseWitness("nodeA", clock=lambda: t[0])
    assert w.handle({"op": "acquire", "router": "ra", "epoch": 1,
                     "ttl_s": 5.0})["granted"]
    t[0] = 2.0  # inside the ttl: another router cannot steal it
    assert not w.handle({"op": "acquire", "router": "rb", "epoch": 1,
                         "ttl_s": 5.0})["granted"]
    assert w.handle({"op": "renew", "router": "ra", "epoch": 1,
                     "ttl_s": 5.0})["granted"]
    # a higher epoch fences the old leader out immediately...
    assert w.handle({"op": "acquire", "router": "rb", "epoch": 2,
                     "ttl_s": 5.0})["granted"]
    # ...and the deposed leader can never renew its stale epoch,
    # even after the new lease expires
    t[0] = 100.0
    assert not w.handle({"op": "renew", "router": "ra", "epoch": 1,
                         "ttl_s": 5.0})["granted"]
    assert not w.handle({"op": "acquire", "router": "ra", "epoch": 1,
                         "ttl_s": 5.0})["granted"]
    assert w.epoch == 2
    assert w.state()["rejections"] >= 3


def test_lease_witness_expiry_frees_the_lease():
    t = [0.0]
    w = LeaseWitness("nodeA", clock=lambda: t[0])
    assert w.handle({"op": "acquire", "router": "ra", "epoch": 1,
                     "ttl_s": 5.0})["granted"]
    t[0] = 6.0  # past the ttl: same epoch, new holder is fine
    assert w.handle({"op": "acquire", "router": "rb", "epoch": 1,
                     "ttl_s": 5.0})["granted"]
    # an expired renew is a rejection, not a silent re-grant
    t[0] = 20.0
    assert not w.handle({"op": "renew", "router": "rb", "epoch": 1,
                         "ttl_s": 5.0})["granted"]


class _FakeWitness:
    def __init__(self, granted=True):
        self.granted = granted
    def call(self, obj, *, idempotent=False):
        assert obj["cmd"] == "lease" and idempotent
        return {"granted": self.granted, "epoch": obj["epoch"]}


class _DeadWitness:
    def call(self, obj, *, idempotent=False):
        raise ConnectionRefusedError("witness unreachable")


def test_elect_needs_strict_majority_of_configured_set():
    win = elect({"a": _FakeWitness(), "b": _FakeWitness(),
                 "c": _FakeWitness(False)}, router_id="ra", epoch=1)
    assert win["won"] and win["granted"] == 2 and win["witnesses"] == 3

    lose = elect({"a": _FakeWitness(), "b": _FakeWitness(False),
                  "c": _FakeWitness(False)}, router_id="ra", epoch=1)
    assert not lose["won"]

    # unreachable witnesses count AGAINST: 1 grant of 2 configured is
    # not a strict majority — a partitioned minority cannot elect
    # itself just because it can only see agreeable voters
    part = elect({"a": _FakeWitness(), "b": _DeadWitness()},
                 router_id="ra", epoch=1)
    assert not part["won"]
    assert part["granted"] == 1 and part["witnesses"] == 2
    assert "error" in part["replies"]["b"]


# ---------------------------------------------------------------------------
# Ledger replication
# ---------------------------------------------------------------------------

def test_apply_ledger_entry_folds_every_kind():
    led = empty_ledger(epoch=1)
    apply_ledger_entry(led, {"k": "route", "node": "n1"})
    apply_ledger_entry(led, {"k": "route", "node": "n1"})
    apply_ledger_entry(led, {"k": "shed"})
    apply_ledger_entry(led, {"k": "place", "app": "a", "node": "n1"})
    apply_ledger_entry(led, {"k": "migration",
                             "m": {"app": "a", "from": "n1",
                                   "to": "n2", "reason": "node_loss"}})
    apply_ledger_entry(led, {"k": "unplace", "app": "a"})
    apply_ledger_entry(led, {"k": "lost", "node": "n1"})
    apply_ledger_entry(led, {"k": "departed", "node": "n3"})
    apply_ledger_entry(led, {"k": "harvest", "node": "n1",
                             "summary": {"requests": 2},
                             "samples": [1.0, 2.0]})
    apply_ledger_entry(led, {"k": "epoch", "epoch": 4})
    apply_ledger_entry(led, {"k": "from_the_future", "x": 1})  # ignored
    assert led["routed_by_node"] == {"n1": 2}
    assert led["router_sheds"] == 1
    assert led["placement"] == {}  # placed, migrated, then unplaced
    assert led["migrations"][0]["to"] == "n2"
    assert led["lost_nodes"] == ["n1"]
    assert led["departed"] == ["n3"]
    assert led["node_payloads"]["n1"] == {"requests": 2}
    assert led["node_samples"]["n1"] == [1.0, 2.0]
    assert led["epoch"] == 4


def test_standby_tails_snapshot_then_stream_and_sees_leader_loss():
    led = empty_ledger(epoch=3)
    led["placement"] = {"a": "n1"}
    rep = LedgerReplicator(lambda: dict(led))
    sb = StandbyRouter("rb", (rep.host, rep.port), {})
    sb.start()
    try:
        assert sb.wait_synced(5.0)
        assert rep.standbys == 1
        rep.publish({"k": "route", "node": "n1"})
        rep.publish({"k": "shed"})
        rep.publish({"k": "departed", "node": "n2"})
        deadline = time.monotonic() + 5.0
        while sb.seq < 3 and time.monotonic() < deadline:
            time.sleep(0.01)
        copy = sb.ledger_copy()
        assert copy["epoch"] == 3  # from the snapshot
        assert copy["placement"] == {"a": "n1"}
        assert copy["routed_by_node"] == {"n1": 1}
        assert copy["router_sheds"] == 1
        assert copy["departed"] == ["n2"]
        assert sb.gaps == 0
    finally:
        rep.stop(abrupt=True)  # leader death: no goodbye frame
    assert sb.leader_lost.wait(5.0)
    # zero configured witnesses can never yield a strict majority
    with pytest.raises(ElectionLost):
        sb.promote()


# ---------------------------------------------------------------------------
# socket-fed integration (sim node agents)
# ---------------------------------------------------------------------------

def _wl(n_apps=4, families=2, seed=3, minutes=4, peak_rpm=60.0):
    return synthetic_cluster_workload(n_apps, n_families=families,
                                      seed=seed, minutes=minutes,
                                      peak_rpm=peak_rpm)


def _agent_for(wl, apps, node_id, port=0):
    profiles = {a: wl.profiles[a] for a in apps}
    manager = FleetManager(profiles, IdleTimeoutPolicy(timeout_s=120.0),
                           budget_mb=2048.0,
                           queue=QueueConfig(depth=32,
                                             max_concurrency=4))
    agent = NodeAgent(SimFleetBackend(manager), node_id=node_id,
                      port=port)
    agent.start()
    return agent


def _retry(seed=3):
    return RetryPolicy(backoff_base_s=0.01, backoff_cap_s=0.05,
                       seed=seed)


def test_node_client_connect_retries_until_agent_binds():
    """The satellite: a router brought up a beat before its node agent
    no longer fails — connect() backs off and retries under the
    policy instead of dying on the first ECONNREFUSED."""
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()

    # nothing listening and no retries left: fails as ConnectionError
    fast = NodeClient("late", "127.0.0.1", port,
                      retry=RetryPolicy(attempts=1))
    with pytest.raises(ConnectionError):
        fast.connect()

    wl = _wl(n_apps=2, families=1)
    holder: dict = {}
    def _bind_late():
        time.sleep(0.3)
        holder["agent"] = _agent_for(wl, wl.apps, "late", port=port)
    t = threading.Thread(target=_bind_late)
    t.start()
    client = NodeClient("late", "127.0.0.1", port,
                        retry=RetryPolicy(attempts=20,
                                          backoff_base_s=0.05,
                                          backoff_cap_s=0.2, seed=1))
    try:
        hello = client.connect()
        assert hello.get("node") == "late"
        assert "counts" in hello  # the extended reconciliation reply
        client.call({"cmd": "shutdown", "flush": True},
                    idempotent=True)
    finally:
        t.join()
        client.close()
        holder["agent"].result()


def test_replicated_router_survives_leader_loss_with_conservation():
    """The tentpole end-to-end: chaos ``router_loss`` halts the leader
    abruptly mid-replay; the standby wins the epoch-2 election,
    reconciles its replica against the nodes' own admission counters,
    and finishes the replay with the global ledger intact."""
    wl = _wl(n_apps=4, families=2)
    agents = [_agent_for(wl, wl.apps, "nodeA"),
              _agent_for(wl, wl.apps, "nodeB")]
    plan = FaultPlan(events=[FaultEvent("router_loss", at=25)],
                     seed=7, name="leader-kill")
    inject = FaultInjector(plan, simulate=True)
    try:
        router = ReplicatedRouter(
            {a.node_id: (a.host, a.port) for a in agents},
            strategy="sharing", hot_sets=wl.hot_sets, seed=3,
            retry=_retry(), fault_hook=inject)
        router.connect()
        assert router.leader.router_id == "router-a"
        n = 80
        for i in range(n):
            reply = router.route(wl.apps[i % len(wl.apps)])
            assert reply.get("outcome") != "error", reply
        assert inject.counts().get("router_loss") == 1
        assert router.failovers == 1
        assert router.leader.router_id == "router-b"
        assert router.leader.epoch == 2
        payload = router.shutdown()
    finally:
        for agent in agents:
            agent.result()
    assert payload["requests"] == n
    assert payload["conservation"]["holds"]
    assert payload["conservation"]["routed"] == n
    ha = payload["ha"]
    assert ha["leader"] == "router-b" and ha["failovers"] == 1
    assert any(e["won"] and e["epoch"] == 2 for e in ha["elections"])
    assert payload["router"]["id"] == "router-b"
    assert payload["router"]["epoch"] == 2


def test_plan_leave_hands_off_warm_and_requeues():
    """Planned decommission: the successor pre-warms from the shipped
    report BEFORE placement flips, the departing node's queue flushes
    back for re-admission, and no ghost advertisement survives."""
    wl = _wl(n_apps=4, families=2)
    agents = [_agent_for(wl, wl.apps, "nodeA"),
              _agent_for(wl, wl.apps, "nodeB")]
    try:
        router = ClusterRouter(
            {a.node_id: NodeClient(a.node_id, a.host, a.port,
                                   retry=_retry())
             for a in agents},
            strategy="sharing", hot_sets=wl.hot_sets, seed=3,
            retry=_retry())
        router.connect()
        departing = router.placement[wl.apps[0]]
        survivor = ({"nodeA", "nodeB"} - {departing}).pop()
        n = 60
        for i in range(n):
            reply = router.route(wl.apps[i % len(wl.apps)])
            assert reply.get("outcome") != "error", reply
        out = router.plan_leave(departing)
        assert out["handoffs"], out
        assert all(h["mode"] == "warm" for h in out["handoffs"]), out
        assert router.handoffs["warm"] == len(out["handoffs"])
        assert set(router.placement.values()) == {survivor}
        # the satellite: no ghost advertiser after a clean exit
        assert departing not in router.node_apps
        assert departing not in router.clients
        for i in range(20):
            reply = router.route(wl.apps[i % len(wl.apps)])
            assert reply.get("outcome") != "error", reply
        payload = router.shutdown()
    finally:
        for agent in agents:
            agent.result()
    assert payload["conservation"]["holds"]
    assert payload["conservation"]["routed"] == payload["requests"]
    # departed vs lost are distinguishable in the rollup
    assert payload["router"]["departed"] == [departing]
    assert payload["lost_nodes"] == []
    assert set(payload["router"]["nodes"]) == {"nodeA", "nodeB"}
    assert all(m["reason"] == "handoff_warm"
               for m in payload["migrations"])
    assert payload["handoffs"]["warm"] >= 1
    row = next(r for r in payload["per_node"] if r["node"] == departing)
    assert row["conservation_holds"] and not row["lost"]


def test_handoff_stall_falls_back_cold_and_conserves():
    """Chaos ``handoff_stall`` at the handoff site: the stalled app
    downgrades to a cold re-place — placement still flips and the
    ledger still balances."""
    wl = _wl(n_apps=4, families=2)
    agents = [_agent_for(wl, wl.apps, "nodeA"),
              _agent_for(wl, wl.apps, "nodeB")]
    plan = FaultPlan(events=[FaultEvent("handoff_stall", at=0)],
                     seed=3, name="stalled-handoff")
    inject = FaultInjector(plan, simulate=True)
    try:
        router = ClusterRouter(
            {a.node_id: NodeClient(a.node_id, a.host, a.port,
                                   retry=_retry())
             for a in agents},
            strategy="sharing", hot_sets=wl.hot_sets, seed=3,
            retry=_retry(), fault_hook=inject)
        router.connect()
        departing = router.placement[wl.apps[0]]
        for i in range(40):
            router.route(wl.apps[i % len(wl.apps)])
        out = router.plan_leave(departing)
        assert inject.counts().get("handoff_stall") == 1
        modes = [h["mode"] for h in out["handoffs"]]
        assert modes[0] == "cold"  # the stalled one degraded
        assert router.handoffs["stalled"] == 1
        assert router.handoffs["cold"] >= 1
        payload = router.shutdown()
    finally:
        for agent in agents:
            agent.result()
    assert payload["conservation"]["holds"]
    assert payload["handoffs"]["stalled"] == 1


def test_double_failure_sheds_without_breaking_conservation():
    """The satellite: the owner dies, then the failover target dies on
    the very next placement — the router sheds (it never double-feeds
    an admitted invocation) and the global ledger still balances."""
    wl = _wl(n_apps=4, families=2)
    agents = [_agent_for(wl, wl.apps, "nodeA"),
              _agent_for(wl, wl.apps, "nodeB")]
    kill_at = 20
    plan = FaultPlan(events=[FaultEvent("node_loss", at=kill_at,
                                        count=2)],
                     seed=3, name="double-failure")
    inject = FaultInjector(plan, simulate=True)
    try:
        router = ClusterRouter(
            {a.node_id: NodeClient(a.node_id, a.host, a.port,
                                   retry=_retry())
             for a in agents},
            strategy="sharing", hot_sets=wl.hot_sets, seed=3,
            retry=_retry(), fault_hook=inject)
        router.connect()
        n = 60
        shed = 0
        for i in range(n):
            reply = router.route(wl.apps[i % len(wl.apps)])
            if reply.get("outcome") == "no-node":
                shed += 1
            else:
                assert reply.get("outcome") != "error", reply
        assert inject.counts().get("node_loss") == 2
        assert sorted(router.lost_nodes) == ["nodeA", "nodeB"]
        assert router.placement == {}  # nobody left deploys anything
        assert shed == n - kill_at
        assert router.router_sheds == shed
        payload = router.shutdown()
    finally:
        for agent in agents:
            agent.result()
    # the nodes admitted exactly what the router fed them before the
    # crashes; the rest were shed at the router, not lost in flight
    assert payload["requests"] == kill_at
    assert payload["conservation"]["holds"]
    assert payload["router"]["sheds"] == n - kill_at
    assert sorted(payload["lost_nodes"]) == ["nodeA", "nodeB"]
    assert all(r["lost"] and r["conservation_holds"]
               for r in payload["per_node"])
