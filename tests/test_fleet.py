"""Multi-app fleet manager tests: Azure-style traces, budget-arbitrated
prewarm/evict decisions, zygote residency, the pool-aware serving
dispatch (EnginePool), and the real ZygoteFleet (slow tier)."""

import copy
import csv
import math
import os

import pytest

from repro.core.adaptive.controller import SlimStartController
from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import LibraryStats
from repro.pool import (
    AppProfile,
    AzureRow,
    FixedSizePolicy,
    FleetManager,
    IdleTimeoutPolicy,
    ProfileGuidedPolicy,
    Request,
    Trace,
    ZygoteFleet,
    azure_synthetic_rows,
    azure_trace,
    default_policies,
    fleet_sweep,
    load_azure_csv,
    trace_from_azure_rows,
    write_azure_csv,
)


def _report(app: str, *, e2e_s: float = 0.2,
            init_s: float = 0.15) -> OptimizationReport:
    stat = LibraryStats(name="fakelib_hot", utilization=0.9, init_s=init_s,
                        init_share=init_s / e2e_s, runtime_samples=90,
                        file="<x>")
    return OptimizationReport(application=app, e2e_s=e2e_s,
                              total_init_s=init_s, qualifies=True,
                              stats=[stat], defer_targets=[])


PROF_A = AppProfile(app="a", cold_init_ms=100.0, invoke_ms=10.0,
                    warm_init_ms=5.0, rss_mb=100.0, zygote_rss_mb=80.0)
PROF_B = AppProfile(app="b", cold_init_ms=100.0, invoke_ms=10.0,
                    warm_init_ms=5.0, rss_mb=100.0, zygote_rss_mb=80.0)


def _trace(reqs, duration):
    return Trace("manual", [Request(t, app) for t, app in reqs], duration)


# ---------------------------------------------------------------------------
# Azure-style traces
# ---------------------------------------------------------------------------

def test_azure_rows_deterministic_and_shaped():
    rows1 = azure_synthetic_rows(["a", "b"], minutes=30, peak_rpm=20.0,
                                 seed=5)
    rows2 = azure_synthetic_rows(["a", "b"], minutes=30, peak_rpm=20.0,
                                 seed=5)
    assert rows1 == rows2
    assert all(len(r.counts) == 30 for r in rows1)
    assert rows1 != azure_synthetic_rows(["a", "b"], minutes=30,
                                         peak_rpm=20.0, seed=6)


def test_azure_popularity_is_heavy_tailed():
    rows = azure_synthetic_rows(["a", "b", "c"], minutes=120,
                                peak_rpm=60.0, popularity_s=1.5, seed=1)
    totals = {r.app: r.total for r in rows}
    assert totals["a"] > totals["b"] > totals["c"] > 0


def test_azure_trace_materialization():
    rows = azure_synthetic_rows(["a", "b"], minutes=10, peak_rpm=30.0,
                                seed=2)
    tr = trace_from_azure_rows(rows, seed=3)
    assert len(tr) == sum(r.total for r in rows)
    ts = [r.t for r in tr]
    assert ts == sorted(ts)
    assert tr.duration_s == 600.0
    assert all(0.0 <= t < 600.0 for t in ts)
    assert {r.app for r in tr} == {"a", "b"}


def test_azure_handler_rows_and_trace():
    rows = azure_synthetic_rows(
        ["a"], minutes=60, peak_rpm=60.0, seed=4,
        handlers={"a": ["h0", "h1"]})
    assert [r.func for r in rows] == ["h0", "h1"]
    assert rows[0].total > rows[1].total  # Zipf within the app
    tr = trace_from_azure_rows(rows, seed=5)
    assert {r.handler for r in tr} == {"h0", "h1"}


def test_azure_csv_round_trip(tmp_path):
    rows = azure_synthetic_rows(["app1", "app2"], minutes=15,
                                peak_rpm=10.0, seed=7)
    path = write_azure_csv(rows, str(tmp_path / "trace.csv"))
    loaded = load_azure_csv(path)
    assert [(r.app, r.func, r.counts) for r in loaded] == \
        [(r.app, r.func or r.app, r.counts) for r in rows]


def test_azure_csv_ignores_dataset_extra_columns(tmp_path):
    # the real dataset carries HashOwner / Trigger columns; loading must
    # key on the integer minute columns only
    path = tmp_path / "azure.csv"
    with open(path, "w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(["HashOwner", "HashApp", "HashFunction", "Trigger",
                    "1", "2", "3"])
        w.writerow(["own", "appX", "funcY", "http", "4", "0", "2"])
    rows = load_azure_csv(str(path))
    assert rows == [AzureRow(app="appX", func="funcY", counts=(4, 0, 2))]
    tr = trace_from_azure_rows(rows, seed=0)
    assert len(tr) == 6 and tr.duration_s == 180.0


def test_diurnal_modulation_changes_counts():
    flat = azure_synthetic_rows(["a"], minutes=60, peak_rpm=40.0, seed=9)
    mod = azure_synthetic_rows(["a"], minutes=60, peak_rpm=40.0, seed=9,
                               diurnal_period_min=60)
    assert flat != mod
    # troughs (minutes near 0 mod period) must be quieter than crests
    counts = mod[0].counts
    trough = sum(counts[:6]) + sum(counts[-6:])
    crest = sum(counts[24:36])
    assert crest > trough


# ---------------------------------------------------------------------------
# FleetManager (simulation)
# ---------------------------------------------------------------------------

def test_fleet_zygote_turns_cold_starts_into_pool_starts():
    pol = ProfileGuidedPolicy(rate_hint_per_s=1.0)
    pol.add_report(_report("a"))
    fleet = FleetManager({"a": PROF_A}, pol, budget_mb=1000.0)
    s = fleet.replay(_trace([(0.0, "a"), (5.0, "a")], 30.0))
    assert s.zygote_apps == ["a"]
    assert s.cold_starts == 0
    assert s.pool_starts >= 1  # the t=0 demand start forked the zygote
    assert s.per_app["a"].n_requests == 2
    assert s.budget_violations == 0


def test_fleet_no_zygote_without_preload():
    pol = IdleTimeoutPolicy(timeout_s=1000.0)
    fleet = FleetManager({"a": PROF_A}, pol, budget_mb=1000.0)
    s = fleet.replay(_trace([(0.0, "a"), (5.0, "a")], 30.0))
    assert s.zygote_apps == []
    assert s.pool_starts == 0
    assert s.per_app["a"].cold_starts == 1  # second request reuses warm


def test_fleet_evicts_worst_amortizer_under_budget_pressure():
    # budget fits one idle instance; app a is hot (4 arrivals), b is not
    pol = IdleTimeoutPolicy(timeout_s=1000.0)
    fleet = FleetManager({"a": PROF_A, "b": PROF_B}, pol, budget_mb=150.0)
    s = fleet.replay(_trace(
        [(0.0, "a"), (1.0, "a"), (2.0, "a"), (3.0, "a"),
         (10.0, "b"), (20.0, "a"), (30.0, "b")], 60.0))
    # b's idle instance was evicted to make room, so b cold-starts twice
    assert s.per_app["b"].cold_starts == 2
    assert s.per_app["a"].cold_starts == 1
    assert s.evictions >= 1
    assert s.budget_violations == 0


def test_fleet_prewarm_floor_clamped_to_budget():
    pol = FixedSizePolicy(size=4)
    fleet = FleetManager({"a": PROF_A}, pol, budget_mb=250.0)
    s = fleet.replay(_trace([(10.0, "a")], 30.0))
    # floor wants 4 x 100 MB; budget admits only 2
    assert s.prewarm_spawns == 2
    assert s.per_app["a"].cold_starts == 0  # floor served the request
    assert s.budget_violations == 0
    assert s.peak_mb <= 250.0


def test_fleet_summary_math_single_request():
    pol = IdleTimeoutPolicy(timeout_s=5.0)
    fleet = FleetManager({"a": PROF_A}, pol, budget_mb=1000.0)
    s = fleet.replay(_trace([(0.0, "a")], 100.0))
    rep = s.per_app["a"]
    assert rep.latencies_ms == [110.0]
    assert rep.cold_starts == 1 and s.cold_start_ratio == 1.0
    # instance lives 0.11 s busy + 5 s keep-alive
    assert rep.memory_mb_s == pytest.approx(100.0 * (0.11 + 5.0), rel=1e-6)
    assert s.budget_utilization == pytest.approx(
        (100.0 * 5.11) / 100.0 / 1000.0, rel=1e-6)
    assert not math.isnan(s.p99_ms)


def test_fleet_silent_app_rate_decays_and_loses_retention():
    """An app that bursts then goes silent must not pin warm state: its
    observed rate decays to zero once its arrivals age out, so budget
    pressure from a live app evicts the dead app's instance."""
    pol = IdleTimeoutPolicy(timeout_s=10_000.0)
    fleet = FleetManager({"a": PROF_A, "b": PROF_B}, pol, budget_mb=150.0,
                         rate_window_s=60.0)
    reqs = [(float(i), "b") for i in range(10)]       # b bursts early...
    reqs += [(200.0 + 5.0 * i, "a") for i in range(6)]  # ...then only a
    s = fleet.replay(_trace(reqs, 300.0))
    assert fleet.observed_rate_per_s("b", 300.0) == 0.0
    # a's warm instance survives the budget squeeze, b's was evicted
    assert s.per_app["a"].cold_starts == 1
    assert s.evictions >= 1


def test_fleet_unknown_app_raises():
    fleet = FleetManager({"a": PROF_A}, IdleTimeoutPolicy(),
                         budget_mb=100.0)
    with pytest.raises(KeyError, match="unknown app"):
        fleet.replay(_trace([(0.0, "zzz")], 10.0))


def test_fleet_rate_feedback_reaches_profile_guided_policy():
    pol = ProfileGuidedPolicy(rate_hint_per_s=0.01, max_prewarm=8)
    pol.add_report(_report("a", e2e_s=1.0))
    fleet = FleetManager({"a": PROF_A}, pol, budget_mb=5000.0,
                         rate_window_s=10.0)
    reqs = [(0.1 * i, "a") for i in range(200)]  # ~10 req/s for 20 s
    s = fleet.replay(_trace(reqs, 25.0))
    # Little's law with the learned (not hinted) rate: ceil(~10 * 1.0)
    assert pol.expected_rate_per_s("a") > 2.0
    assert pol.prewarm("a") > 1
    assert s.prewarm_spawns > 1


def test_fleet_sweep_profile_guided_beats_baselines_on_azure_trace():
    """The acceptance-criteria regression in miniature: equal budget,
    Azure-style multi-app trace, profile-guided fleet policy must beat
    fixed-size and idle-timeout on cold-start ratio."""
    profiles = {
        "a": AppProfile(app="a", cold_init_ms=200.0, invoke_ms=10.0,
                        warm_init_ms=8.0, rss_mb=256.0,
                        zygote_rss_mb=200.0),
        "b": AppProfile(app="b", cold_init_ms=50.0, invoke_ms=5.0,
                        warm_init_ms=4.0, rss_mb=64.0, zygote_rss_mb=48.0),
        "c": AppProfile(app="c", cold_init_ms=400.0, invoke_ms=20.0,
                        warm_init_ms=12.0, rss_mb=512.0,
                        zygote_rss_mb=400.0),
    }
    trace = azure_trace(list(profiles), minutes=20, peak_rpm=30.0, seed=3)
    reports = {a: _report(a, e2e_s=0.25, init_s=0.2) for a in profiles}
    panel = default_policies(reports, rate_hint_per_s=0.5)
    sums = {s.policy: s for s in fleet_sweep(
        profiles, panel, trace, budget_mb=1024.0,
        policy_factory=copy.deepcopy)}
    pg = sums["profile-guided"]
    assert pg.cold_start_ratio < sums["fixed"].cold_start_ratio
    assert pg.cold_start_ratio < sums["idle-timeout"].cold_start_ratio
    assert pg.p99_ms <= sums["fixed"].p99_ms
    assert all(s.budget_violations == 0 for s in sums.values())
    # per-app rows are reportable for every app in the fleet
    assert {r["app"] for r in pg.app_rows()} == set(profiles)


# ---------------------------------------------------------------------------
# EnginePool: pool-aware dispatch in the serving engine (Level B)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_pool():
    import numpy as np  # noqa: F401  (jax import cost paid once here)
    from repro.configs import get_reduced
    from repro.serving import EnginePool, ServingEngine

    def builder(name):
        def build():
            return ServingEngine(get_reduced(name), batch_size=1,
                                 prefill_len=8, max_len=24)
        return build

    return EnginePool({"qwen": builder("qwen2.5-32b"),
                       "granite": builder("granite-8b")}, max_warm=1)


def test_engine_pool_warm_vs_cold_dispatch(engine_pool):
    import numpy as np
    toks = np.ones((1, 8), dtype=np.int32)
    out, lat_cold, path = engine_pool.dispatch("qwen", "generate", toks,
                                               max_new_tokens=2)
    assert path == "cold" and out.shape == (1, 2)
    out, lat_warm, path = engine_pool.dispatch("qwen", "generate", toks,
                                               max_new_tokens=2)
    assert path == "warm"
    assert lat_warm < lat_cold  # warm dispatch skips the cold start
    assert engine_pool.stats()["hits"] == 1
    assert engine_pool.stats()["misses"] == 1


def test_engine_pool_evicts_over_budget_and_drops_components(engine_pool):
    import numpy as np
    toks = np.ones((1, 8), dtype=np.int32)
    assert "qwen" in engine_pool.warm
    qwen_engine = engine_pool.warm["qwen"]
    out, _, path = engine_pool.dispatch("granite", "generate", toks,
                                        max_new_tokens=2)
    assert path == "cold"
    # max_warm=1: qwen was evicted and its components actually dropped
    assert list(engine_pool.warm) == ["granite"]
    assert "qwen" in engine_pool.evictions
    assert all(not c.ready for c in qwen_engine.registry.values())


def test_engine_pool_rewarm_is_a_controller_hook(engine_pool):
    reports = iter([_report("whatever") for _ in range(3)])
    ctl = SlimStartController(profile_fn=lambda: next(reports),
                              optimize_fn=lambda rep: None,
                              rewarm_fn=engine_pool.rewarm)
    ctl.force_profile()
    assert ctl.rewarms == 1 and ctl.rewarm_errors == []
    # the warm engine's policy was re-derived from live utilization:
    # components every request touches (weights.core) are now prewarm
    for eng in engine_pool.warm.values():
        assert "weights.core" in eng.policy.prewarm


def test_engine_pool_unknown_model_raises(engine_pool):
    with pytest.raises(KeyError):
        engine_pool.dispatch("no-such-model", "generate", None)


# ---------------------------------------------------------------------------
# ZygoteFleet + controller hook (no real zygotes needed)
# ---------------------------------------------------------------------------

def test_zygote_fleet_rewarm_hook_without_zygotes():
    fleet = ZygoteFleet({"appx": "/nonexistent"})  # never started
    ctl = SlimStartController(profile_fn=lambda: _report("appx"),
                              optimize_fn=lambda rep: None,
                              rewarm_fn=fleet.rewarm)
    rep = ctl.force_profile()
    assert ctl.rewarms == 1 and ctl.rewarm_errors == []
    assert fleet.reports["appx"] is rep
    with pytest.raises(KeyError):
        fleet.rewarm(_report("unknown-app"))
    with pytest.raises(KeyError):
        fleet.dispatch("unknown-app")


# ---------------------------------------------------------------------------
# Real fork-server fleet (slow tier)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def suite_root_dir():
    from repro.benchsuite.genlibs import build_suite
    return build_suite()


@pytest.mark.slow
def test_zygote_fleet_real_dispatch_and_budget(suite_root_dir):
    apps = {name: os.path.join(suite_root_dir, "apps", name)
            for name in ["graph_bfs", "sentiment_analysis_r"]}
    with ZygoteFleet(apps, budget_mb=4096.0) as fleet:
        assert sorted(fleet.servers) == sorted(apps)
        assert fleet.used_mb() > 0
        m = fleet.dispatch("graph_bfs", handler="bfs", seed=1)
        assert m["path"] == "pool" and m["init_ms"] > 0
        rows = fleet.replay(
            trace_from_azure_rows(
                [AzureRow("graph_bfs", "bfs", (2,)),
                 AzureRow("sentiment_analysis_r", None, (1,))], seed=2),
            limit=3)
        assert sum(r["requests"] for r in rows) == 3
        assert all(r["pool_starts"] == r["requests"] for r in rows)

    # a zero budget boots no zygotes: everything falls back to cold
    fleet2 = ZygoteFleet({"graph_bfs": apps["graph_bfs"]}, budget_mb=1e-9)
    fleet2.start()
    assert fleet2.servers == {} and fleet2.skipped == ["graph_bfs"]
    m = fleet2.dispatch("graph_bfs", handler="bfs", seed=3)
    assert m["path"] == "cold" and m["init_ms"] > 0


# ---------------------------------------------------------------------------
# Bounded queues / backpressure (QueueConfig) in the simulated fleet
# ---------------------------------------------------------------------------

def test_queue_config_validation():
    from repro.pool import QueueConfig
    with pytest.raises(ValueError):
        QueueConfig(depth=-1)
    with pytest.raises(ValueError):
        QueueConfig(max_concurrency=0)
    with pytest.raises(ValueError):
        QueueConfig(shed_policy="lifo")
    assert QueueConfig().to_dict()["shed_policy"] == "reject-new"


def test_fleet_queue_bounds_concurrency_and_sheds():
    from repro.pool import QueueConfig
    pol = IdleTimeoutPolicy(timeout_s=1000.0)
    fleet = FleetManager({"a": PROF_A}, pol, budget_mb=5000.0,
                         queue=QueueConfig(depth=2, max_concurrency=1))
    # 10 arrivals in 0.1 s; service is 115 ms, capacity ~1 instance
    s = fleet.replay(_trace([(0.01 * i, "a") for i in range(10)], 30.0))
    rep = s.per_app["a"]
    assert rep.max_instances == 1           # cap held, no demand spawns
    assert s.sheds > 0                      # overload was shed
    assert s.n_requests == s.served + s.sheds + s.flushed
    assert rep.queue_waits_ms              # queued requests waited
    # queue wait is part of the served latency, so p99 >> warm latency
    assert s.p99_ms > PROF_A.warm_init_ms + PROF_A.invoke_ms
    assert s.budget_violations == 0


def test_fleet_queue_drains_in_arrival_gaps():
    """Queued requests start the moment an instance frees, not at the
    next arrival: a short burst then silence still serves everyone."""
    from repro.pool import QueueConfig
    pol = IdleTimeoutPolicy(timeout_s=1000.0)
    fleet = FleetManager({"a": PROF_A}, pol, budget_mb=5000.0,
                         queue=QueueConfig(depth=8, max_concurrency=1))
    s = fleet.replay(_trace([(0.0, "a"), (0.01, "a"), (0.02, "a")], 30.0))
    assert s.sheds == 0 and s.flushed == 0
    assert s.served == 3
    waits = s.per_app["a"].queue_waits_ms
    assert len(waits) == 2 and waits[0] < waits[1]  # FIFO chaining


def test_fleet_queue_flushes_tail_at_finish():
    from repro.pool import QueueConfig
    pol = IdleTimeoutPolicy(timeout_s=1000.0)
    fleet = FleetManager({"a": PROF_A}, pol, budget_mb=5000.0,
                         queue=QueueConfig(depth=8, max_concurrency=1))
    # burst right at the horizon: nothing frees before duration_s
    s = fleet.replay(_trace([(9.99, "a"), (9.995, "a"), (9.999, "a")],
                            10.0))
    assert s.flushed > 0
    assert s.n_requests == s.served + s.sheds + s.flushed


def test_fleet_incremental_offer_matches_replay():
    """begin/offer/finish (the daemon path) and one-shot replay are the
    same machinery: identical summaries for the same trace."""
    pol = IdleTimeoutPolicy(timeout_s=30.0)
    trace = azure_trace(["a", "b"], minutes=10, peak_rpm=30.0, seed=5)
    fleet1 = FleetManager({"a": PROF_A, "b": PROF_B}, pol,
                          budget_mb=350.0)
    s1 = fleet1.replay(trace)
    fleet2 = FleetManager({"a": PROF_A, "b": PROF_B},
                          copy.deepcopy(pol), budget_mb=350.0)
    fleet2.begin(trace.name)
    for req in trace:
        fleet2.offer(req)
    s2 = fleet2.finish(trace.duration_s)
    assert s1.summary() == s2.summary()
    assert s1.app_rows() == s2.app_rows()


def test_fleet_offer_outcomes():
    from repro.pool import QueueConfig
    fleet = FleetManager({"a": PROF_A}, IdleTimeoutPolicy(timeout_s=30.0),
                         budget_mb=5000.0,
                         queue=QueueConfig(depth=1, max_concurrency=1))
    fleet.begin("unit")
    assert fleet.offer(Request(0.0, "a")) == "served"
    assert fleet.offer(Request(0.01, "a")) == "queued"
    assert fleet.offer(Request(0.02, "a")) == "shed"
    with pytest.raises(KeyError, match="unknown app"):
        fleet.offer(Request(0.03, "zzz"))
    s = fleet.finish(10.0)
    assert (s.served, s.sheds, s.flushed) == (2, 1, 0)
