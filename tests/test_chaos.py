"""Chaos tier: seeded fault injection across the zygote serving path.

Fast tier: FaultEvent/FaultPlan semantics, injector matching, the
boot-backoff gate, the per-app circuit breaker, worker shed
classification, drain/finish abandonment accounting, and the bounded
rewarm-failure ring — all in-process (``simulate=True`` swaps signals
for exceptions, so no zygote boots).  A hypothesis property drives
arbitrary plans through a stub fleet and asserts the conservation
invariant ``requests == served + sheds + flushed + errors + abandoned``
always holds.

Slow tier: the canonical crash storm over a real ZygoteFleet (app +
base zygote kills, a wedged handler, circuit-breaker demotion), a base
hot-swap under dispatch burst, and ``repro fleet replay --real
--chaos`` killed with SIGTERM mid-storm.
"""

import json
import os
import signal
import subprocess
import sys
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image without hypothesis: skip sweeps only
    st = None

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            return skipper
        return deco

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.api import load_chaos_report, save_chaos_report
from repro.pool import (
    BreakerConfig,
    CircuitBreaker,
    CrashLoopShed,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    FleetDaemon,
    ForkServerBackoff,
    ForkServerError,
    ForkServerTimeout,
    QueueConfig,
    RealFleetBackend,
    Request,
    Trace,
    chaos_report_payload,
)
from repro.pool.chaos import FAULT_KINDS

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


# ---------------------------------------------------------------------------
# FaultEvent / FaultPlan
# ---------------------------------------------------------------------------

def test_fault_event_validation_and_defaults():
    ev = FaultEvent("kill_app_zygote", at=2, app="a")
    assert ev.site == "protocol" and ev.op_filter == "exec"
    assert FaultEvent("fail_spawn").op_filter is None
    # explicit op overrides the kind default
    assert FaultEvent("socket_eof", op="preload").op_filter == "preload"
    with pytest.raises(ValueError):
        FaultEvent("no_such_kind")
    with pytest.raises(ValueError):
        FaultEvent("socket_eof", at=-1)
    with pytest.raises(ValueError):
        FaultEvent("socket_eof", count=0)
    with pytest.raises(ValueError):
        FaultEvent("socket_eof", count=-2)
    with pytest.raises(ValueError):
        FaultEvent("delay_import", delay_s=-0.1)


def test_fault_plan_round_trip_and_determinism(tmp_path):
    plan = FaultPlan.generate(42, ["a", "b"])
    again = FaultPlan.generate(42, ["a", "b"])
    assert plan.events == again.events  # same seed, same plan
    assert plan.events != FaultPlan.generate(43, ["a", "b"]).events

    path = str(tmp_path / "plan.json")
    plan.save(path)
    loaded = FaultPlan.load(path)
    assert loaded.events == plan.events and loaded.seed == 42

    # a bare JSON list of events is accepted (hand-written plans)
    bare = str(tmp_path / "bare.json")
    with open(bare, "w") as fh:
        json.dump([{"kind": "socket_eof", "at": 1}], fh)
    assert FaultPlan.load(bare).events == [FaultEvent("socket_eof", at=1)]

    storm = FaultPlan.storm(["a", "b"], seed=5)
    assert storm.events == FaultPlan.storm(["a", "b"], seed=5).events
    kinds = [ev.kind for ev in storm.events]
    assert "kill_app_zygote" in kinds and "kill_base_zygote" in kinds
    assert "wedge_handler" in kinds and "fail_spawn" in kinds


def test_injector_matching_at_count_app_op():
    plan = FaultPlan(events=[
        FaultEvent("socket_eof", at=1, app="a", count=2),
        FaultEvent("fail_preload", at=0, app="b"),
    ])
    inj = FaultInjector(plan, simulate=True)
    # occurrence 0 for app a: before `at`, no fire
    inj("protocol", app="a", op="exec")
    # app filter: b's exec traffic never matches a's event
    inj("protocol", app="b", op="exec")
    # op filter: a preload on app a is not an exec occurrence
    inj("protocol", app="a", op="preload")
    # occurrences 1 and 2: fire twice (count=2) ...
    for _ in range(2):
        with pytest.raises(ForkServerError):
            inj("protocol", app="a", op="exec")
    # ... then the event is exhausted
    inj("protocol", app="a", op="exec")
    with pytest.raises(ForkServerError):
        inj("protocol", app="b", op="preload")
    assert inj.counts() == {"socket_eof": 2, "fail_preload": 1}
    assert inj.pending() == []
    occ = [r["occurrence"] for r in inj.injected
           if r["kind"] == "socket_eof"]
    assert occ == [1, 2]


def test_injector_simulated_exception_taxonomy():
    def fire(kind, site, **ctx):
        inj = FaultInjector(FaultPlan(events=[FaultEvent(kind)]),
                            simulate=True)
        inj(site, **ctx)

    with pytest.raises(ForkServerTimeout):
        fire("wedge_handler", "protocol", app="a", op="exec")
    with pytest.raises(ForkServerError) as ei:
        fire("socket_oserror", "protocol", app="a", op="exec")
    assert isinstance(ei.value.__cause__, OSError)
    with pytest.raises(ForkServerError):
        fire("kill_app_zygote", "protocol", app="a", op="exec")
    with pytest.raises(ForkServerError):
        fire("fail_spawn", "spawn_app", app="a")
    with pytest.raises(RuntimeError):
        fire("fail_cold", "cold_start", app="a")
    with pytest.raises(RuntimeError):
        fire("fail_rewarm", "rewarm", app="_tick")
    # kill_base in simulate mode is a no-op (nothing to kill)
    fire("kill_base_zygote", "dispatch", app="a", base=None)
    # delay_import sleeps, never raises
    t0 = time.monotonic()
    inj = FaultInjector(FaultPlan(events=[
        FaultEvent("delay_import", delay_s=0.05)]), simulate=True)
    inj("protocol", app="a", op="preload")
    assert time.monotonic() - t0 >= 0.05


def test_injector_pending_reports_unfired_events():
    plan = FaultPlan(events=[
        FaultEvent("socket_eof", at=9, app="a"),
        FaultEvent("fail_cold", at=0, app="b", count=-1),
    ])
    inj = FaultInjector(plan, simulate=True)
    pend = inj.pending()
    assert {p["kind"] for p in pend} == {"socket_eof", "fail_cold"}
    with pytest.raises(RuntimeError):
        inj("cold_start", app="b")
    # the unlimited event fired once: no longer pending
    assert [p["kind"] for p in inj.pending()] == ["socket_eof"]


# ---------------------------------------------------------------------------
# boot-backoff gate + circuit breaker (fake clocks, no processes)
# ---------------------------------------------------------------------------

def test_forkserver_boot_backoff_gate(tmp_path):
    from repro.pool.forkserver import ForkServer
    now = [0.0]
    fs = ForkServer(str(tmp_path), boot_backoff_s=1.0,
                    boot_backoff_max_s=4.0, clock=lambda: now[0])
    boom = {"n": 0}

    def bad_boot():
        boom["n"] += 1
        raise ForkServerError("no boot for you")

    fs._boot_locked = bad_boot
    with pytest.raises(ForkServerError):
        fs.start()
    assert fs.boot_failures == 1
    # inside the window: gated, no boot attempt burned
    with pytest.raises(ForkServerBackoff):
        fs.start()
    assert boom["n"] == 1
    # past the window: a real attempt, which doubles the backoff
    now[0] = 1.1
    with pytest.raises(ForkServerError):
        fs.start()
    assert fs.boot_failures == 2 and boom["n"] == 2
    now[0] = 2.0  # 1.1 + 2.0 > 2.0: still gated
    with pytest.raises(ForkServerBackoff):
        fs.start()
    # the exponential backoff is capped at boot_backoff_max_s
    now[0] = 100.0
    with pytest.raises(ForkServerError):
        fs.start()
    assert fs.boot_failures == 3
    assert fs._next_boot_t <= 100.0 + 4.0
    # a successful boot resets the gate
    fs._boot_locked = lambda: {"ok": True}
    now[0] = 200.0
    fs.start()
    assert fs.boot_failures == 0 and fs._next_boot_t == 0.0


def test_circuit_breaker_opens_cools_down_and_resets():
    now = [0.0]
    br = CircuitBreaker(BreakerConfig(max_failures=2, cooldown_s=10.0),
                        clock=lambda: now[0])
    assert not br.open
    assert br.record_failure() is False  # 1/2: not yet
    assert br.record_failure() is True   # newly open
    assert br.open and br.trips == 1
    assert br.record_failure() is False  # already open: not "newly"
    # cooldown elapses: half-open (closed for one probe attempt)
    now[0] = 11.0
    assert not br.open
    # the probe fails: re-opens without double-counting the trip
    assert br.record_failure() is True
    assert br.trips == 2
    now[0] = 22.0
    br.record_success()
    assert not br.open and br.failures == 0
    state = br.state()
    assert state["open"] is False and state["trips"] == 2

    with pytest.raises(ValueError):
        BreakerConfig(max_failures=0)
    with pytest.raises(ValueError):
        BreakerConfig(cooldown_s=-1.0)


# ---------------------------------------------------------------------------
# daemon integration over a stub fleet (no subprocesses)
# ---------------------------------------------------------------------------

class _StubFleet:
    """Duck-typed ZygoteFleet: enough surface for RealFleetBackend.
    ``dispatch`` delegates to a per-test callable."""

    def __init__(self, apps, dispatch):
        self.app_dirs = {a: "." for a in apps}
        self._dispatch = dispatch
        self.shared_base = False
        self.budget_mb = None
        self.servers = {}
        self.skipped = []

    def start(self):
        return {"zygotes": [], "skipped": []}

    def stop(self):
        pass

    def used_mb(self):
        return 0.0

    def _base_info(self):
        return {}

    def rewarm_from_dir(self, d):
        return {}

    def dispatch(self, app, **kw):
        return self._dispatch(app, **kw)


def _drain_conservation(payload):
    return payload["requests"] == (
        payload["served"] + payload["sheds"] + payload["flushed"]
        + payload["errors"] + payload["abandoned"])


def test_worker_classifies_timeout_and_crash_loop_as_sheds():
    def dispatch(app, **kw):
        if app == "t":
            raise ForkServerTimeout("wedged")
        if app == "c":
            raise CrashLoopShed("circuit-broken and cold failed")
        if app == "e":
            raise RuntimeError("plain dispatch failure")
        return {"path": "pool", "init_ms": 1.0, "e2e_cold_ms": 2.0}

    be = RealFleetBackend(_StubFleet(["t", "c", "e", "ok"], dispatch),
                          queue=QueueConfig(depth=8))
    d = FleetDaemon(be)
    d.start("classify")
    for app in ("t", "c", "e", "ok"):
        assert d.submit(Request(0.0, app)) == "queued"
    payload = d.shutdown(flush=False)
    per = {r["app"]: r for r in payload["per_app"]}
    assert per["t"]["shed_reasons"] == {"timeout": 1}
    assert per["c"]["shed_reasons"] == {"crash_loop": 1}
    assert per["e"]["errors"] == 1 and per["e"]["sheds"] == 0
    assert per["ok"]["pool_starts"] == 1
    assert payload["shed_reasons"] == {"timeout": 1, "crash_loop": 1}
    assert payload["errors"] == 1 and payload["served"] == 1
    assert _drain_conservation(payload)


def test_worker_counts_degraded_cold_serves():
    def dispatch(app, **kw):
        return {"path": "cold", "init_ms": 1.0, "e2e_cold_ms": 2.0,
                "degraded": "crash_loop"}

    be = RealFleetBackend(_StubFleet(["a"], dispatch),
                          queue=QueueConfig(depth=8))
    d = FleetDaemon(be)
    d.start("degraded")
    assert d.submit(Request(0.0, "a")) == "queued"
    payload = d.shutdown(flush=False)
    assert payload["degraded"] == 1
    assert payload["degrade_reasons"] == {"crash_loop": 1}
    row = payload["per_app"][0]
    assert row["degraded"] == 1 and row["served" if "served" in row
                                        else "requests"] >= 1
    snap_ok = payload["served"] == 1  # degraded serves still count
    assert snap_ok and _drain_conservation(payload)


def test_drain_abandons_stuck_worker_and_blocks_double_count():
    """The satellite bug: join(timeout) returning with the worker alive
    used to lose its in-flight request.  It must be counted as
    abandoned, and the late worker must not also count it."""
    release = threading.Event()

    def dispatch(app, **kw):
        release.wait(timeout=30.0)
        return {"path": "pool", "init_ms": 1.0, "e2e_cold_ms": 2.0}

    be = RealFleetBackend(_StubFleet(["a"], dispatch),
                          queue=QueueConfig(depth=8))
    be.start("stuck")
    assert be.submit(Request(0.0, "a")) == "queued"
    deadline = time.monotonic() + 5.0
    with be._cond:
        while be._in_flight["a"] == 0:
            assert time.monotonic() < deadline, "worker never dequeued"
            be._cond.wait(timeout=0.1)
    gen0 = be._gen
    # the worker is blocked inside dispatch: drain cannot join it.
    # Patch the join grace down so the test doesn't wait 5s.
    orig_join = threading.Thread.join
    try:
        threading.Thread.join = lambda self, timeout=None: \
            orig_join(self, timeout=0.1)
        be.drain(timeout_s=0.3, flush=False)
    finally:
        threading.Thread.join = orig_join
    assert be._gen == gen0 + 1
    payload = be.finish()
    assert payload["abandoned"] == 1
    assert _drain_conservation(payload)
    served_before = payload["served"]
    # let the stuck worker return: its stale-generation request must
    # not be double-counted as served
    release.set()
    time.sleep(0.3)
    payload2 = be.finish()
    assert payload2["served"] == served_before
    assert payload2["abandoned"] == 1


def test_finish_without_drain_accounts_in_flight_as_abandoned():
    started = threading.Event()
    release = threading.Event()

    def dispatch(app, **kw):
        started.set()
        release.wait(timeout=30.0)
        return {"path": "pool", "init_ms": 1.0, "e2e_cold_ms": 2.0}

    be = RealFleetBackend(_StubFleet(["a"], dispatch),
                          queue=QueueConfig(depth=8))
    be.start("inflight")
    be.submit(Request(0.0, "a"))
    assert started.wait(timeout=5.0)
    payload = be.finish()  # no drain: the dispatch is still running
    assert payload["abandoned"] == 1 and _drain_conservation(payload)
    release.set()


def test_rewarm_tick_failures_are_bounded_and_counted():
    be = RealFleetBackend(_StubFleet(["a"], lambda app, **kw: {}),
                          queue=QueueConfig(depth=4))

    def bad_rewarm():
        raise RuntimeError("rewarm exploded")

    d = FleetDaemon(be, rewarm_fn=bad_rewarm)
    for _ in range(FleetDaemon.MAX_REWARM_ERRORS + 25):
        out = d.rewarm_now()
        assert out["ok"] is False
    assert len(d.rewarm_errors) == FleetDaemon.MAX_REWARM_ERRORS
    assert d.rewarm_ticks == 0
    assert d.rewarm_errors[-1].startswith("_tick: ")

    # per-app {"ok": False} results inside a successful tick count too
    d2 = FleetDaemon(be, rewarm_fn=lambda: {
        "a": {"ok": False, "error": "preload failed"},
        "b": {"ok": True}})
    out = d2.rewarm_now()
    assert d2.rewarm_ticks == 1
    assert d2.rewarm_errors == ["a: preload failed"]


def test_fault_hook_injects_rewarm_tick_failure():
    be = RealFleetBackend(_StubFleet(["a"], lambda app, **kw: {}),
                          queue=QueueConfig(depth=4))
    inj = FaultInjector(FaultPlan(events=[
        FaultEvent("fail_rewarm", at=1)]), simulate=True)
    d = FleetDaemon(be, rewarm_fn=lambda: {"ok": True}, fault_hook=inj)
    assert d.rewarm_now().get("ok") is True     # tick 0: clean
    assert d.rewarm_now()["ok"] is False        # tick 1: injected
    assert d.rewarm_now().get("ok") is True     # timer keeps ticking
    assert d.rewarm_ticks == 2
    assert len(d.rewarm_errors) == 1


def test_chaos_report_artifact_round_trip(tmp_path):
    plan = FaultPlan(events=[FaultEvent("socket_eof", app="a")], seed=9)
    inj = FaultInjector(plan, simulate=True)
    with pytest.raises(ForkServerError):
        inj("protocol", app="a", op="exec")
    summary = {"requests": 3, "served": 1, "sheds": 1, "flushed": 1,
               "errors": 0, "abandoned": 0}
    payload = chaos_report_payload(inj, summary=summary,
                                   recoveries={"zygote_restarts": 2})
    assert payload["invariant"]["holds"] is True
    path = str(tmp_path / "chaos.json")
    save_chaos_report(payload, path)
    loaded = load_chaos_report(path)
    assert loaded["seed"] == 9
    assert loaded["recoveries"] == {"zygote_restarts": 2}
    assert loaded["injected_by_kind"] == {"socket_eof": 1}

    # a lossy summary is caught, not papered over
    bad = chaos_report_payload(inj, summary={**summary, "served": 0})
    assert bad["invariant"]["holds"] is False


# ---------------------------------------------------------------------------
# property: any plan preserves request conservation
# ---------------------------------------------------------------------------

_EVENTS = st.builds(
    FaultEvent,
    kind=st.sampled_from(FAULT_KINDS),
    at=st.integers(min_value=0, max_value=3),
    app=st.sampled_from(["a", "b", "*"]),
    count=st.sampled_from([1, 2, -1]),
)


@settings(max_examples=30, deadline=None)
@given(events=st.lists(_EVENTS, min_size=0, max_size=6),
       n_requests=st.integers(min_value=1, max_value=12))
def test_any_fault_plan_preserves_request_conservation(events,
                                                       n_requests):
    inj = FaultInjector(FaultPlan(events=list(events)), simulate=True)

    def dispatch(app, **kw):
        # mirror the real fleet's hook traversal: dispatch site, then
        # the zygote protocol, falling back to a cold start on zygote
        # failure — exactly the surfaces the injector targets
        inj("dispatch", app=app, base=None)
        try:
            inj("protocol", app=app, op="exec", pid=None)
            return {"path": "pool", "init_ms": 1.0, "e2e_cold_ms": 2.0}
        except ForkServerTimeout:
            raise
        except ForkServerError:
            inj("cold_start", app=app)
            return {"path": "cold", "init_ms": 5.0, "e2e_cold_ms": 9.0}

    be = RealFleetBackend(_StubFleet(["a", "b"], dispatch),
                          queue=QueueConfig(depth=3))
    d = FleetDaemon(be)
    d.start("property")
    reqs = [Request(t=i * 0.01, app=("a" if i % 2 else "b"))
            for i in range(n_requests)]
    payload = d.run_trace(Trace("prop", reqs, duration_s=1.0))
    assert payload["requests"] == n_requests
    assert _drain_conservation(payload)
    report = chaos_report_payload(inj, summary=payload)
    assert report["invariant"]["holds"] is True


# ---------------------------------------------------------------------------
# slow tier: real zygotes under the storm
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def suite_root():
    from repro.benchsuite.genlibs import build_suite
    return build_suite()


@pytest.mark.slow
def test_crash_storm_replay_conserves_and_recovers(suite_root,
                                                   tmp_path):
    """The acceptance scenario: a seeded storm (app zygote kill with
    every respawn/cold start failing, a wedged handler, a base kill
    mid-burst) must finish with conservation intact, ``crash_loop``
    and ``timeout`` shed reasons recorded, the breaker tripped, and
    the base rebooted."""
    from repro.pool import ZygoteFleet
    apps = {name: os.path.join(suite_root, "apps", name)
            for name in ["echo", "json_transform"]}
    plan = FaultPlan.storm(["echo", "json_transform"], seed=7)
    inj = FaultInjector(plan)
    fleet = ZygoteFleet(
        apps, shared_base=True, fault_hook=inj,
        breaker=BreakerConfig(max_failures=2, cooldown_s=60.0),
        boot_backoff_s=0.05, revive_on_dispatch=True, timeout_s=5.0)
    be = RealFleetBackend(fleet, queue=QueueConfig(depth=16))
    d = FleetDaemon(be, fault_hook=inj, drain_timeout_s=30.0)
    reqs = [Request(t=i * 0.05,
                    app=("echo" if i % 2 else "json_transform"))
            for i in range(30)]
    d.start("storm")
    payload = d.run_trace(Trace("storm", reqs, duration_s=1.5),
                          pace=1.0)
    assert _drain_conservation(payload)
    per = {r["app"]: r for r in payload["per_app"]}
    assert per["echo"]["shed_reasons"].get("crash_loop", 0) >= 1
    assert per["json_transform"]["shed_reasons"].get("timeout", 0) >= 1
    assert "crash_loop" in payload["shed_reasons"]
    assert "timeout" in payload["shed_reasons"]
    assert fleet.recoveries["breaker_trips"] >= 1
    assert fleet.recoveries["base_reboots"] >= 1
    assert fleet.breakers["echo"].open

    report = chaos_report_payload(inj, summary=payload,
                                  recoveries=fleet.recoveries)
    assert report["invariant"]["holds"] is True
    path = str(tmp_path / "report.json")
    save_chaos_report(report, path)
    assert load_chaos_report(path)["recoveries"]["breaker_trips"] >= 1


@pytest.mark.slow
def test_base_kill_under_burst_reboots_and_keeps_serving(suite_root):
    """Two-tier fleet: SIGKILLing the shared base mid-burst must not
    strand dispatches — ensure_base() reboots it and warm serving
    resumes for freshly revived zygotes."""
    from repro.pool import ZygoteFleet
    apps = {name: os.path.join(suite_root, "apps", name)
            for name in ["echo", "json_transform"]}
    plan = FaultPlan(events=[FaultEvent("kill_base_zygote", at=2)])
    inj = FaultInjector(plan)
    with ZygoteFleet(apps, shared_base=True, fault_hook=inj,
                     boot_backoff_s=0.05, revive_on_dispatch=True,
                     timeout_s=30.0) as fleet:
        served = 0
        for i in range(8):
            m = fleet.dispatch("echo" if i % 2 else "json_transform")
            served += 1
            assert m["path"] in ("pool", "cold")
        assert served == 8
        assert inj.counts().get("kill_base_zygote") == 1
        # the kill landed, the fleet noticed and rebooted the base
        assert fleet.recoveries["base_reboots"] >= 1
        assert fleet.base is not None and fleet.base.alive
        # warm serving still works post-swap
        assert fleet.dispatch("echo")["path"] == "pool"


@pytest.mark.slow
def test_chaos_cli_sigterm_mid_storm(suite_root, tmp_path):
    """SIGTERM during `fleet replay --real --chaos storm` drains
    gracefully: exit 0, both artifacts written, conservation holds."""
    out = str(tmp_path / "summary.json")
    report = str(tmp_path / "chaos.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro", "fleet", "replay", "--real",
         "--root", suite_root, "--shared-base",
         "--apps", "echo,json_transform", "--minutes", "2",
         "--peak-rpm", "30", "--chaos", "storm", "--chaos-seed", "7",
         "--chaos-pace", "1.0", "--boot-backoff-s", "0.05",
         "--breaker-max-failures", "2", "--dispatch-timeout-s", "5",
         "--out", out, "--chaos-report", report],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=env)
    try:
        time.sleep(12.0)  # let zygotes boot and the storm land
        proc.send_signal(signal.SIGTERM)
        stdout, stderr = proc.communicate(timeout=120)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()
    assert proc.returncode == 0, (stdout, stderr)
    loaded = load_chaos_report(report)
    assert loaded["invariant"]["holds"] is True
    assert loaded["injected_by_kind"]  # the storm actually landed
    from repro.api import load_fleet_summary
    summary = load_fleet_summary(out)
    assert summary["requests"] == (
        summary["served"] + summary["sheds"] + summary["flushed"]
        + summary["errors"] + summary["abandoned"])
