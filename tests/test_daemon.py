"""Fleet daemon lifecycle tests: bounded-queue backpressure, rewarm
ticks, graceful drain (including the SIGTERM flush path), and the
fleet_summary artifact both backends emit.

Fast tier: in-process sim daemon (simulated time, no subprocesses).
Slow tier: the real threaded loop over a ZygoteFleet, and
``python -m repro fleet serve --sim --stdin`` killed with SIGTERM.
"""

import io
import json
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.api import load_fleet_summary, save_report
from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import LibraryStats
from repro.pool import (
    AppProfile,
    FleetDaemon,
    FleetManager,
    IdleTimeoutPolicy,
    ProfileGuidedPolicy,
    QueueConfig,
    Request,
    SimFleetBackend,
    Trace,
)

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _report(app: str, lib: str = "fakelib_hot") -> OptimizationReport:
    stat = LibraryStats(name=lib, utilization=0.9, init_s=0.15,
                        init_share=0.5, runtime_samples=90, file="<x>")
    return OptimizationReport(application=app, e2e_s=0.3,
                              total_init_s=0.15, qualifies=True,
                              stats=[stat], defer_targets=[])


def _profiles(*apps, invoke_ms=500.0, cold_ms=500.0):
    return {a: AppProfile(app=a, cold_init_ms=cold_ms, warm_init_ms=20.0,
                          invoke_ms=invoke_ms, rss_mb=100.0)
            for a in apps}


def _sim_daemon(queue, *, apps=("a",), policy=None, reports_dir=None,
                summary_path=None, **daemon_kw) -> FleetDaemon:
    manager = FleetManager(_profiles(*apps),
                           policy or IdleTimeoutPolicy(timeout_s=60.0),
                           budget_mb=2048.0, queue=queue)
    backend = SimFleetBackend(manager, reports_dir=reports_dir)
    return FleetDaemon(backend, summary_path=summary_path, **daemon_kw)


def _burst(n, app="a", gap_s=0.05, duration_s=60.0) -> Trace:
    return Trace("burst", [Request(gap_s * i, app) for i in range(n)],
                 duration_s)


# ---------------------------------------------------------------------------
# fast tier: sim backend
# ---------------------------------------------------------------------------

def test_sim_daemon_conservation_and_summary_artifact(tmp_path):
    out = str(tmp_path / "summary.json")
    d = _sim_daemon(QueueConfig(depth=3, max_concurrency=1),
                    summary_path=out)
    d.start("burst")
    payload = d.run_trace(_burst(20))
    # arrival conservation: every request is served, shed or flushed
    assert payload["requests"] == 20
    assert payload["requests"] == (payload["served"] + payload["sheds"]
                                   + payload["flushed"])
    assert payload["sheds"] > 0  # 20 req/s against ~2/s of capacity
    assert payload["queue_wait_p99_ms"] > 0
    # queue waits surface in end-to-end latency, not beside it
    assert payload["p99_ms"] >= payload["queue_wait_p99_ms"]
    loaded = load_fleet_summary(out)
    assert loaded["source"] == "serve-sim"
    assert loaded["requests"] == 20
    assert loaded["queue"] == {"depth": 3, "max_concurrency": 1,
                               "shed_policy": "reject-new"}
    # the admission breakdown lands in the *saved* artifact too, not
    # just the in-memory payload
    assert sum(loaded["meta"]["admission"].values()) == 20


def test_sim_daemon_drop_oldest_sheds_waiting_not_arriving():
    d = _sim_daemon(QueueConfig(depth=3, max_concurrency=1,
                                shed_policy="drop-oldest"))
    d.start("burst")
    payload = d.run_trace(_burst(20))
    assert payload["sheds"] > 0
    assert payload["requests"] == (payload["served"] + payload["sheds"]
                                   + payload["flushed"])
    # drop-oldest sheds the *waiting* request — the breakdown names it
    assert payload["shed_reasons"] == {"drop-oldest": payload["sheds"]}


def test_sim_daemon_shed_reason_breakdown_sums_to_sheds(tmp_path):
    out = str(tmp_path / "summary.json")
    d = _sim_daemon(QueueConfig(depth=3, max_concurrency=1),
                    summary_path=out)
    d.start("burst")
    payload = d.run_trace(_burst(20))
    assert payload["sheds"] > 0
    assert sum(payload["shed_reasons"].values()) == payload["sheds"]
    # reject-new policy: every shed is a queue-full rejection
    assert set(payload["shed_reasons"]) == {"queue-full"}
    # per-app rows carry the same breakdown, and it also sums
    per_app = {row["app"]: row for row in payload["per_app"]}
    assert sum(sum(r.get("shed_reasons", {}).values())
               for r in per_app.values()) == payload["sheds"]
    # the breakdown survives the artifact round-trip (optional key)
    loaded = load_fleet_summary(out)
    assert loaded["shed_reasons"] == payload["shed_reasons"]


def test_real_backend_shed_reasons_and_locked_snapshot():
    """Admission bookkeeping of the real backend without booting
    zygotes: shed causes are named, and snapshot() aggregates from a
    copy taken under the queue lock."""
    from collections import deque

    from repro.pool.daemon import RealFleetBackend, _AppServeStats

    class _StubFleet:
        app_dirs = {"a": "."}
        shared_base = False

    def _backend(policy):
        be = RealFleetBackend(
            _StubFleet(),
            queue=QueueConfig(depth=1, max_concurrency=1,
                              shed_policy=policy))
        # start() would boot zygotes; wire the admission state directly
        be._queues["a"] = deque()
        be._stats["a"] = _AppServeStats()
        be._in_flight["a"] = 0
        return be

    be = _backend("reject-new")
    assert be.submit(Request(0.0, "a")) == "queued"
    assert be.submit(Request(0.1, "a")) == "shed"
    snap = be.snapshot()
    assert snap["requests"] == 2 and snap["sheds"] == 1
    assert snap["shed_reasons"] == {"queue-full": 1}
    assert snap["per_app"]["a"]["queued"] == 1
    # the snapshot is a copy: mutating it must not corrupt live stats
    snap["shed_reasons"]["queue-full"] = 99
    assert be._stats["a"].shed_reasons == {"queue-full": 1}

    be = _backend("drop-oldest")
    assert be.submit(Request(0.0, "a")) == "queued"
    assert be.submit(Request(0.1, "a")) == "queued"  # displaces oldest
    st = be._stats["a"]
    assert st.arrivals == 2 and st.sheds == 1
    assert st.shed_reasons == {"drop-oldest": 1}
    assert len(be._queues["a"]) == 1


def test_sim_daemon_unbounded_without_queue_config():
    manager = FleetManager(_profiles("a"),
                           IdleTimeoutPolicy(timeout_s=60.0),
                           budget_mb=2048.0)  # queue=None
    d = FleetDaemon(SimFleetBackend(manager))
    d.start("burst")
    payload = d.run_trace(_burst(20))
    assert payload["sheds"] == 0 and payload["served"] == 20
    assert payload["queue"] is None


def test_sim_daemon_flushes_queued_on_early_end():
    """Requests still queued at the horizon (nothing freed in time)
    are flushed, never silently dropped."""
    d = _sim_daemon(QueueConfig(depth=8, max_concurrency=1))
    d.start("tail")
    # all 5 arrive in the last 100 ms of a 1 s horizon; service takes
    # 520 ms, so at most 2 can even start by the end
    trace = Trace("tail", [Request(0.9 + 0.01 * i, "a")
                           for i in range(5)], 1.0)
    payload = d.run_trace(trace)
    assert payload["flushed"] > 0
    assert payload["requests"] == (payload["served"] + payload["sheds"]
                                   + payload["flushed"])


def test_rewarm_tick_loads_report_and_keeps_serving(tmp_path):
    """A rewarm tick mid-stream re-loads the deployed report artifact
    into the policy (defer-set drift reaches the fleet) and drops no
    in-flight or queued work."""
    reports_dir = str(tmp_path)
    policy = ProfileGuidedPolicy(rate_hint_per_s=1.0)
    d = _sim_daemon(QueueConfig(depth=8, max_concurrency=2),
                    policy=policy, reports_dir=reports_dir)
    d.start("live")
    assert policy.preload_modules("a") == []  # no report deployed yet
    for i in range(5):
        d.submit(Request(0.1 * i, "a"))
    # "external CI run" deploys a fresh report artifact, timer fires
    save_report(_report("a"), os.path.join(reports_dir, "a.json"))
    tick = d.rewarm_now()
    assert tick == {"a": {"ok": True}}
    assert d.rewarm_ticks == 1
    assert policy.preload_modules("a")  # hot set arrived
    for i in range(5, 10):
        d.submit(Request(0.1 * i, "a"))
    payload = d.shutdown(end_t=60.0)
    assert payload["rewarm_ticks"] == 1
    assert payload["served"] == 10  # the tick dropped nothing
    assert payload["flushed"] == 0 and payload["sheds"] == 0


def test_rewarm_timer_thread_fires():
    d = _sim_daemon(QueueConfig(depth=4), rewarm_interval_s=0.05)
    d.start("live")
    time.sleep(0.3)
    payload = d.shutdown(end_t=1.0)
    assert payload["rewarm_ticks"] >= 2
    assert d.rewarm_errors == []


def test_rewarm_failure_is_recorded_not_raised():
    def boom():
        raise RuntimeError("artifact store down")
    manager = FleetManager(_profiles("a"), IdleTimeoutPolicy(),
                           budget_mb=1024.0, queue=QueueConfig())
    d = FleetDaemon(SimFleetBackend(manager), rewarm_fn=boom)
    d.start("live")
    out = d.rewarm_now()
    assert out["ok"] is False
    assert d.rewarm_ticks == 0 and len(d.rewarm_errors) == 1
    d.submit(Request(0.0, "a"))
    assert d.shutdown(end_t=1.0)["served"] == 1


def test_stdin_loop_protocol_and_eof_drain():
    d = _sim_daemon(QueueConfig(depth=8, max_concurrency=4))
    d.start("live")
    feed = io.StringIO("\n".join([
        json.dumps({"app": "a"}),
        json.dumps({"app": "a"}),
        "not json",
        json.dumps({"cmd": "stats"}),
        json.dumps({"cmd": "nope"}),
        json.dumps({"app": "unknown-app"}),
        json.dumps({"handler": "x"}),  # no app, no cmd
    ]) + "\n")
    out = io.StringIO()
    clock_t = iter([0.0] + [0.1 * i for i in range(1, 100)])
    payload = d.run_stdin(feed, out, clock=lambda: next(clock_t))
    replies = [json.loads(line) for line in
               out.getvalue().strip().splitlines()]
    assert replies[0]["outcome"] in ("served", "queued")
    assert replies[2] == {"ok": False, "error": "bad json"}
    assert replies[3]["ok"] and "stats" in replies[3]
    assert not replies[4]["ok"]  # unknown cmd
    assert not replies[5]["ok"] and "unknown app" in replies[5]["error"]
    assert not replies[6]["ok"]
    assert replies[-1]["event"] == "summary"
    assert payload["requests"] == 2 and payload["served"] == 2


def test_shutdown_is_idempotent():
    d = _sim_daemon(QueueConfig(depth=4))
    d.start("live")
    d.submit(Request(0.0, "a"))
    p1 = d.shutdown(end_t=10.0)
    p2 = d.shutdown(end_t=99.0)
    assert p1 is p2
    assert d.submit(Request(1.0, "a")) == "draining"


def test_serve_stage_emits_fleet_summary(tmp_path):
    from repro.api import ServeStage
    from repro.api.stages import RunContext
    from repro.pool.trace import poisson_trace
    ctx = RunContext(app="stage_app", root=str(tmp_path))
    stage = ServeStage(sim=True,
                       trace=poisson_trace("stage_app", rate_per_s=3.0,
                                           duration_s=20.0, seed=7),
                       queue_depth=8)
    stage.run(ctx)
    res = ctx.results["serve"]
    assert res["source"] == "serve-sim"
    assert res["requests"] > 0
    path = res["artifact_path"]
    assert load_fleet_summary(path)["requests"] == res["requests"]


# ---------------------------------------------------------------------------
# fast tier: EnginePool queue-aware dispatch (stub engines, real threads)
# ---------------------------------------------------------------------------

class _StubEngine:
    """Duck-typed ServingEngine: slow cold start, instant serve."""

    def __init__(self, cold_s: float = 0.2):
        self._cold_s = cold_s
        self.cold_start_s = None
        self.registry = {}

    def cold_start(self):
        time.sleep(self._cold_s)
        self.cold_start_s = self._cold_s
        return self._cold_s

    def serve(self, entry, tokens, **kw):
        return "out", 0.001


def test_engine_pool_single_flight_and_shed():
    import threading

    from repro.serving.engine import EnginePool, PoolSaturated

    builds = []

    def builder():
        builds.append(1)
        return _StubEngine()

    pool = EnginePool({"m": builder}, max_warm=1, queue_depth=2)
    paths, sheds = [], []

    def call():
        try:
            paths.append(pool.dispatch("m", "generate", None)[2])
        except PoolSaturated:
            sheds.append(1)

    threads = [threading.Thread(target=call) for _ in range(5)]
    for t in threads:
        t.start()
        time.sleep(0.02)  # deterministic arrival order
    for t in threads:
        t.join()
    # one build (single-flight), two waiters coalesced, two shed
    assert len(builds) == 1
    assert paths.count("cold") == 1 and paths.count("queued") == 2
    assert len(sheds) == 2
    stats = pool.stats()
    assert stats["sheds"] == 2 and stats["coalesced"] == 2
    assert stats["queue_wait_p99_s"] > 0
    # pool is warm now: no more waiting
    assert pool.dispatch("m", "generate", None)[2] == "warm"


def test_engine_pool_legacy_path_unchanged():
    from repro.serving.engine import EnginePool
    pool = EnginePool({"m": _StubEngine}, max_warm=1)  # queue_depth=None
    assert pool.dispatch("m", "generate", None)[2] == "cold"
    assert pool.dispatch("m", "generate", None)[2] == "warm"
    assert "sheds" in pool.stats() and pool.stats()["sheds"] == 0


# ---------------------------------------------------------------------------
# slow tier: real zygote fleet + subprocess SIGTERM
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def suite_root():
    from repro.benchsuite.genlibs import build_suite
    return build_suite()


@pytest.mark.slow
def test_real_daemon_serves_and_rewarms(suite_root, tmp_path):
    from repro.pool import RealFleetBackend, ZygoteFleet
    reports_dir = str(tmp_path)
    # hot set must name a library the deployed app really vendors — the
    # zygote imports it on the rewarm tick
    save_report(_report("graph_bfs", lib="fakelib_igraph"),
                os.path.join(reports_dir, "graph_bfs.json"))
    apps = {name: os.path.join(suite_root, "apps", name)
            for name in ["graph_bfs", "echo"]}
    fleet = ZygoteFleet(apps, budget_mb=4096.0)
    backend = RealFleetBackend(
        fleet, queue=QueueConfig(depth=8, max_concurrency=1),
        reports_dir=reports_dir)
    d = FleetDaemon(backend, summary_path=str(tmp_path / "sum.json"),
                    drain_timeout_s=120.0)
    d.start("real-live")
    for i in range(4):
        assert d.submit(Request(float(i), "graph_bfs",
                                handler="bfs")) == "queued"
    assert d.submit(Request(4.0, "echo")) == "queued"
    tick = d.rewarm_now()  # re-preloads graph_bfs's zygote mid-serve
    assert tick["graph_bfs"]["skipped"] is False
    payload = d.shutdown(flush=False)  # end-of-feed: serve the queue
    assert payload["served"] == 5 and payload["flushed"] == 0
    assert payload["pool_starts"] == 5  # all via resident zygotes
    assert payload["rewarm_ticks"] == 1
    assert payload["queue_wait_p99_ms"] > 0
    loaded = load_fleet_summary(str(tmp_path / "sum.json"))
    assert loaded["source"] == "serve-real"
    assert loaded["zygotes"] == ["echo", "graph_bfs"]


@pytest.mark.slow
def test_real_daemon_sigterm_flushes_queue(suite_root, tmp_path):
    """SIGTERM semantics end-to-end: in-flight finishes, queued work is
    flushed into the summary artifact, exit code 0."""
    out = str(tmp_path / "summary.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro", "fleet", "serve", "--sim",
         "--stdin", "--apps", "a,b", "--queue-depth", "32",
         "--summary-out", out, "--rewarm-interval-s", "0.2"],
        stdin=subprocess.PIPE, stdout=subprocess.PIPE,
        stderr=subprocess.PIPE, text=True, env=env)
    try:
        for _ in range(6):
            proc.stdin.write(json.dumps({"app": "a"}) + "\n")
        proc.stdin.flush()
        time.sleep(1.0)
        proc.send_signal(signal.SIGTERM)
        stdout, _ = proc.communicate(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert proc.returncode == 0
    replies = [json.loads(line) for line in stdout.strip().splitlines()]
    assert replies[-1]["event"] == "summary"
    summary = load_fleet_summary(out)
    assert summary["requests"] == 6
    assert summary["requests"] == (summary["served"] + summary["sheds"]
                                   + summary["flushed"])
    assert summary["rewarm_ticks"] >= 1


@pytest.mark.slow
def test_fleet_replay_real_cli_emits_summary(suite_root, tmp_path):
    from repro.cli import main
    out = str(tmp_path / "replay.json")
    rc = main(["fleet", "replay", "--real", "--root", suite_root,
               "--apps", "graph_bfs,echo", "--minutes", "2",
               "--peak-rpm", "20", "--limit", "6", "--out", out])
    assert rc == 0
    summary = load_fleet_summary(out)
    assert summary["source"] == "replay-real"
    assert summary["requests"] == 6 and summary["served"] == 6
    assert summary["cold_starts"] + summary["pool_starts"] == 6


def test_engine_pool_eviction_defers_drop_during_inflight_serve():
    """Evicting a model while another thread is mid-serve on it must
    not drop its components under the request — the drop happens when
    the last in-flight serve returns."""
    import threading

    from repro.serving.engine import EnginePool

    class _Comp:
        def __init__(self):
            self.dropped = False

        def drop(self):
            self.dropped = True

    class _SlowServeEngine(_StubEngine):
        def __init__(self):
            super().__init__(cold_s=0.0)
            self.comp = _Comp()
            self.registry = {"c": self.comp}
            self.serving = threading.Event()
            self.release = threading.Event()

        def serve(self, entry, tokens, **kw):
            self.serving.set()
            assert self.release.wait(timeout=10)
            assert not self.comp.dropped  # must survive the eviction
            return "out", 0.001

    x_engine = _SlowServeEngine()
    pool = EnginePool({"x": lambda: x_engine, "y": _StubEngine},
                      max_warm=1, queue_depth=4)
    x_engine.release.set()                # let the cold serve through
    pool.dispatch("x", "generate", None)  # cold-start x
    x_engine.release.clear()
    x_engine.serving.clear()

    t = threading.Thread(
        target=lambda: pool.dispatch("x", "generate", None))
    t.start()
    assert x_engine.serving.wait(timeout=10)  # x is mid-serve
    pool.dispatch("y", "generate", None)      # evicts x (max_warm=1)
    assert "x" in pool.evictions
    assert not x_engine.comp.dropped          # drop deferred
    x_engine.release.set()
    t.join(timeout=10)
    assert x_engine.comp.dropped              # dropped on serve exit
