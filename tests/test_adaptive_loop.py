"""Closed-loop adaptive optimization tests (live profiler -> drift
detector -> in-process re-optimization -> hot swap).

The anchor is the *differential* test: the in-process regeneration
(``LiveProfiler.regenerate``) must agree with the offline
Profile -> Analyze pipeline (``repro.api.stages.analyze_sink``) when
both see the same recorded profile shards — same defer set, same
qualification verdict, same init accounting.

Fast tier: synthetic shards, deterministic drift windows in trace
time, chaos ``profiler_stall`` survival, the drift_report artifact
round-trip, the sim closed loop beating a static fleet on a
popularity flip, and the rewarm-error exit-status contract.
Slow tier: the real zygote fleet re-optimizing itself mid-replay.
"""

import json
import os
import random

import pytest

from repro.api import load_drift_report, save_drift_report
from repro.api.stages import analyze_sink
from repro.core.adaptive import (
    AdaptiveConfig,
    AdaptiveLoop,
    DriftConfig,
    DriftDetector,
    LiveProfileConfig,
    LiveProfiler,
)
from repro.core.profiler.cct import CCT, Frame
from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import LibraryStats
from repro.pool import (
    AppProfile,
    FleetDaemon,
    FleetManager,
    IdleTimeoutPolicy,
    ProfileGuidedPolicy,
    QueueConfig,
    Request,
    SimFleetBackend,
    Trace,
)
from repro.pool.chaos import FaultEvent, FaultInjector, FaultPlan
from repro.pool.daemon import make_sim_adaptive_loop

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


# ---------------------------------------------------------------------------
# differential: live regeneration == offline analyze_sink
# ---------------------------------------------------------------------------

def _synthetic_records(libs_dir: str, n: int = 6, seed: int = 3):
    """Profile shards in the runner's on-disk format: one hot library
    (heavy runtime use), one cold library (init cost, zero runtime
    samples -> the analyzer must flag it), plus app-code samples."""
    hot = os.path.join(libs_dir, "fakelib_hot", "__init__.py")
    cold = os.path.join(libs_dir, "fakelib_cold", "__init__.py")
    handler = os.path.join(os.path.dirname(libs_dir), "handler.py")
    rng = random.Random(seed)
    records = []
    for _ in range(n):
        def jig(x):
            return x * (1.0 + 0.05 * rng.uniform(-1.0, 1.0))
        init_records = {
            name: {"filename": fn, "self_s": jig(s), "cumulative_s": jig(s),
                   "parent": None, "importer_file": handler,
                   "importer_lineno": 1}
            for name, fn, s in (("fakelib_hot", hot, 0.08),
                                ("fakelib_cold", cold, 0.30))
        }
        cct = CCT()
        # runtime samples: hot library does the work, app code the rest
        cct.add_path((Frame(handler, 5, "handler"),
                      Frame(hot, 10, "work")), count=40)
        cct.add_path((Frame(handler, 7, "handler"),), count=10)
        # init-time samples in the cold library (must NOT count as
        # runtime utilization: path passes module-level __init__ code)
        cct.add_path((Frame(handler, 1, "<module>"),
                      Frame(cold, 1, "<module>")), count=20)
        records.append({"app": "difftest", "init_records": init_records,
                        "cct": cct.to_dict(), "e2e_cold_s": jig(1.0)})
    return records


def _write_shards(sink: str, records) -> None:
    os.makedirs(sink, exist_ok=True)
    with open(os.path.join(sink, "profile-test.jsonl"), "w") as fh:
        for rec in records:
            fh.write(json.dumps(rec) + "\n")


def _live_report(records, libs_dir: str):
    prof = LiveProfiler()
    for rec in records:
        prof.observe("difftest", {"init_records": rec["init_records"],
                                  "cct": rec["cct"],
                                  "e2e_cold_s": rec["e2e_cold_s"]})
    return prof.regenerate("difftest", libs_dir)


def test_differential_live_regeneration_matches_offline(tmp_path):
    """Same shards through both pipelines -> the same optimization
    decision, down to the init-time accounting."""
    libs = str(tmp_path / "libs")
    records = _synthetic_records(libs)
    _write_shards(str(tmp_path / "sink"), records)

    offline = analyze_sink("difftest", str(tmp_path / "sink"), libs)
    live = _live_report(records, libs)

    assert live is not None
    assert live.qualifies == offline.qualifies is True
    assert list(live.defer_targets) == list(offline.defer_targets) \
        == ["fakelib_cold"]
    assert live.total_init_s == pytest.approx(offline.total_init_s)
    assert live.e2e_s == pytest.approx(offline.e2e_s)
    live_stats = {s.name: s for s in live.stats}
    off_stats = {s.name: s for s in offline.stats}
    assert live_stats.keys() == off_stats.keys()
    for name, s in off_stats.items():
        assert live_stats[name].init_s == pytest.approx(s.init_s)
        assert live_stats[name].utilization == \
            pytest.approx(s.utilization)
        assert live_stats[name].runtime_samples == s.runtime_samples


def test_differential_subsampled_live_agrees_on_defer_set(tmp_path):
    """The live profiler rides a sampled subset of production traffic:
    seeing only half the shards must still land on the same defer set
    (the decision is a ratio test, robust to subsampling)."""
    libs = str(tmp_path / "libs")
    records = _synthetic_records(libs, n=8)
    _write_shards(str(tmp_path / "sink"), records)
    offline = analyze_sink("difftest", str(tmp_path / "sink"), libs)
    live = _live_report(records[::2], libs)
    assert live is not None
    assert list(live.defer_targets) == list(offline.defer_targets)
    assert live.qualifies == offline.qualifies


def test_live_profiler_baseline_restores_preloaded_hot_set(tmp_path):
    """Modules preloaded into the zygote never appear in child-side
    import records; the deployed report's baseline shard must keep
    their init cost visible so regeneration doesn't defer them."""
    libs = str(tmp_path / "libs")
    records = _synthetic_records(libs)
    # children forked from a zygote with fakelib_hot preloaded: strip
    # it from every init_records shard
    for rec in records:
        rec["init_records"].pop("fakelib_hot")
    prof = LiveProfiler()
    for rec in records:
        prof.observe("difftest", {"init_records": rec["init_records"],
                                  "cct": rec["cct"],
                                  "e2e_cold_s": rec["e2e_cold_s"]})
    deployed = OptimizationReport(
        application="difftest", e2e_s=1.0, total_init_s=0.38,
        qualifies=True,
        stats=[LibraryStats(
            name="fakelib_hot", utilization=0.8, init_s=0.08,
            init_share=0.08, runtime_samples=40,
            file=os.path.join(libs, "fakelib_hot", "__init__.py"))],
        defer_targets=["fakelib_cold"])
    without = prof.regenerate("difftest", libs)
    prof.set_baseline("difftest", deployed)
    with_base = prof.regenerate("difftest", libs)
    names = {s.name for s in with_base.stats}
    assert "fakelib_hot" in names
    assert "fakelib_hot" not in {s.name for s in without.stats}
    assert "fakelib_hot" not in with_base.defer_targets
    assert "fakelib_cold" in with_base.defer_targets


def test_live_profiler_rolling_state_and_overhead(tmp_path):
    cfg = LiveProfileConfig(max_shards=4, max_e2e=4)
    prof = LiveProfiler(cfg)
    for i in range(10):
        prof.observe("a", {"init_records": {"m": {
            "filename": "<x>", "self_s": 0.01, "cumulative_s": 0.01,
            "parent": None, "importer_file": None,
            "importer_lineno": 0}},
            "e2e_cold_s": 0.5, "overhead_s": 0.01, "exec_s": 0.5,
            "n_signals": 20})
    snap = prof.snapshot()["a"]
    assert snap["profiled_execs"] == 10
    assert snap["shards"] == 4  # ring-bounded
    assert prof.overhead_pct("a") == pytest.approx(2.0)
    assert prof.has_data("a") and not prof.has_data("b")


# ---------------------------------------------------------------------------
# drift detector: deterministic windows in trace time
# ---------------------------------------------------------------------------

def _det(window_s=10.0, **kw) -> DriftDetector:
    kw.setdefault("min_invocations", 10)
    return DriftDetector(DriftConfig(window_s=window_s, **kw))


def _feed(det, counts: dict, t: float, app: str = "app"):
    for handler, n in counts.items():
        det.observe(app, handler, n=n, t=t)


def test_detector_windows_follow_trace_time_not_wall_clock():
    """The detector is constructed on the wall monotonic clock but a
    replay observes in trace time starting at ~0; the first observation
    must re-anchor the window, or no window would ever close."""
    det = _det(window_s=10.0)
    _feed(det, {"h": 50}, t=1.0)
    _feed(det, {"h": 50}, t=11.0)   # closes [1, 11)
    _feed(det, {"h": 50}, t=21.0)   # closes [11, 21)
    det.flush(t=31.0)
    assert len(det.windows) == 3
    assert [w.t_end for w in det.windows] == [11.0, 21.0, 31.0]
    assert all(w.total_invocations == 50 for w in det.windows)


def test_detector_stationary_mix_never_fires():
    det = _det()
    for w in range(6):
        _feed(det, {"h1": 700, "h2": 300}, t=1.0 + 10.0 * w)
    det.flush(t=61.0)
    assert det.fires == 0
    assert all(not w.fired and not w.suppressed for w in det.windows)
    assert max(w.score for w in det.windows) < 1.0


def test_detector_popularity_flip_fires_once():
    det = _det()
    _feed(det, {"h1": 1000}, t=1.0)
    _feed(det, {"h1": 1000}, t=11.0)
    _feed(det, {"h2": 1000}, t=21.0)  # the flip window
    last = det.flush(t=31.0)
    assert det.fires == 1
    assert last is not None and last.fired
    # the full flip moves sigma|delta p| by 2.0 against a noise gate of
    # 4*sqrt(2 * 2/1000) ~ 0.25 -- far past the threshold
    assert last.aggregate_change == pytest.approx(2.0)
    assert last.eps_eff < 0.3
    assert last.score > 5.0


def test_detector_first_window_never_fires():
    """No previous window to diff against: the first close must be
    score-0 on the mix component, whatever the traffic looks like."""
    det = _det()
    _feed(det, {"h9": 1000}, t=1.0)
    win = det.flush(t=11.0)
    assert win is not None and not win.fired
    assert win.mix_score == 0.0 and det.fires == 0


def test_detector_cooldown_suppresses_back_to_back_fires():
    det = _det(cooldown_windows=1)
    mixes = [{"h1": 500}, {"h2": 500}, {"h1": 500}, {"h2": 500}]
    for w, mix in enumerate(mixes):
        _feed(det, mix, t=1.0 + 10.0 * w)
    det.flush(t=41.0)
    fired = [w.fired for w in det.windows]
    suppressed = [w.suppressed for w in det.windows]
    # window 1 fires, window 2 is inside the cooldown (score > 1 but
    # suppressed), window 3 fires again after the cooldown expires
    assert fired == [False, True, False, True]
    assert suppressed == [False, False, True, False]
    assert det.fires == 2


def test_detector_small_window_noise_is_gated():
    """Serving-scale windows: with n=30 per window the multinomial
    noise floor exceeds the paper's epsilon by orders of magnitude;
    modest count jitter must stay under the calibrated gate."""
    det = _det(min_invocations=10)
    rng = random.Random(11)
    for w in range(8):
        n1 = 15 + rng.randint(-4, 4)
        _feed(det, {"h1": n1, "h2": 30 - n1}, t=1.0 + 10.0 * w)
    det.flush(t=81.0)
    assert det.fires == 0
    assert all(w.eps_eff > 0.002 for w in det.windows[1:])


def test_detector_hit_rate_and_new_module_signals():
    # two quiet windows to build history, then a window whose profiled
    # execs all missed the defer set
    det = _det(min_hit_rate=0.5, min_profiled=3)
    _feed(det, {"h": 100}, t=1.0)
    _feed(det, {"h": 100}, t=11.0)
    _feed(det, {"h": 100}, t=21.0)
    for _ in range(5):
        det.note_hit(False)
    win = det.flush(t=31.0)
    assert win.hit_rate == 0.0
    assert win.miss_score == pytest.approx(2.0)
    assert win.fired and det.fires == 1

    det2 = _det(new_module_threshold=3)
    _feed(det2, {"h": 100}, t=1.0)
    _feed(det2, {"h": 100}, t=11.0)
    _feed(det2, {"h": 100}, t=21.0)
    det2.note_new_modules({"numpyish", "pandasish", "torchish",
                           "scipyish"})
    win = det2.flush(t=31.0)
    assert win.new_modules == sorted(
        {"numpyish", "pandasish", "torchish", "scipyish"})
    assert win.new_module_score > 1.0
    assert win.fired

    # too few profiled execs: the hit-rate signal abstains entirely
    det3 = _det(min_profiled=3)
    _feed(det3, {"h": 100}, t=1.0)
    det3.note_hit(False)
    win = det3.flush(t=11.0)
    assert win.hit_rate is None and win.miss_score == 0.0


# ---------------------------------------------------------------------------
# the loop: sampling cadence, re-optimize wiring, chaos survival
# ---------------------------------------------------------------------------

def _loop(regenerate=None, apply=None, swap=None, *, drift=None,
          profile=None, fault_hook=None) -> AdaptiveLoop:
    cfg = AdaptiveConfig(drift=drift or DriftConfig(window_s=10.0,
                                                    min_invocations=10),
                         profile=profile or LiveProfileConfig())
    return AdaptiveLoop(
        regenerate_fn=regenerate or (lambda app, prof: None),
        apply_fn=apply or (lambda report: None),
        swap_fn=swap, config=cfg, fault_hook=fault_hook)


def test_loop_samples_every_nth_dispatch_per_app():
    loop = _loop(profile=LiveProfileConfig(sample_every=4))
    carried = [loop.observe_request("a", t=0.1 * i) is not None
               for i in range(8)]
    assert carried == [True, False, False, False, True, False, False,
                       False]
    # a second app gets its own cadence, not the tail of app a's
    assert loop.observe_request("b", t=1.0) is not None
    cfg = loop.observe_request("a", t=1.1)
    assert cfg is None or set(cfg) == {"interval_s", "timer",
                                       "max_depth"}


def test_loop_observe_exec_pops_profile_payload():
    loop = _loop()
    metrics = {"init_ms": 5.0, "live_profile": {
        "init_records": {}, "e2e_cold_s": 0.1, "overhead_s": 0.0,
        "exec_s": 0.1}}
    loop.observe_exec("a", metrics)
    assert "live_profile" not in metrics  # never leaks into summaries
    assert loop.profiler.has_data("a")
    loop.observe_exec("a", {"init_ms": 5.0})  # no payload: no-op


def test_loop_confirmed_drift_regenerates_applies_and_swaps():
    applied, swaps = [], []

    def regen(app, prof):
        return OptimizationReport(
            application=app, e2e_s=0.5, total_init_s=0.2,
            qualifies=True, stats=[], defer_targets=["deadlib"])

    loop = _loop(regen, applied.append, lambda: swaps.append(1))
    for i in range(20):
        loop.observe_request("a", "h1", t=1.0 + 0.1 * i)
    for i in range(20):
        loop.observe_request("a", "h1", t=11.0 + 0.1 * i)
    for i in range(20):
        loop.observe_request("a", "h2", t=21.0 + 0.1 * i)
    loop.flush(t=31.0)
    assert loop.detector.fires == 1
    assert [r.application for r in applied] == ["a"]
    assert swaps == [1] and loop.swaps == 1
    s = loop.summary()
    assert s["fires"] == 1 and s["applied"] == 1
    assert s["base_swaps"] == 1 and s["errors"] == 0
    act = loop.actions[-1]
    assert act["applied"][0]["defer_targets"] == ["deadlib"]
    assert act["swapped"] is True


def test_loop_profiler_stall_chaos_is_survived():
    """An injected profiler_stall aborts one re-optimization round;
    the error lands in the report and serving continues untouched."""
    applied = []

    def regen(app, prof):
        return OptimizationReport(application=app, e2e_s=0.5,
                                  total_init_s=0.2, qualifies=True,
                                  stats=[], defer_targets=[])

    inj = FaultInjector(FaultPlan([FaultEvent("profiler_stall")]),
                        simulate=True)
    loop = _loop(regen, applied.append, fault_hook=inj)
    flips = [{"h1": 20}, {"h1": 20}, {"h2": 20}, {"h2": 20},
             {"h1": 20}]
    for w, mix in enumerate(flips):
        for handler, n in mix.items():
            for i in range(n):
                loop.observe_request("a", handler,
                                     t=1.0 + 10.0 * w + 0.1 * i)
    loop.flush(t=51.0)
    # two fires: the first re-optimization was stalled by chaos, the
    # second (after cooldown) went through
    assert loop.detector.fires == 2
    assert len(loop.errors) == 1 and "stall" in loop.errors[0]
    assert len(applied) == 1
    assert any("error" in a for a in loop.actions)
    assert [ev["kind"] for ev in inj.injected] == ["profiler_stall"]
    # the failed round still never raised into the serving path
    loop.observe_request("a", "h1", t=60.0)
    assert loop.summary()["errors"] == 1


def test_drift_report_artifact_round_trip(tmp_path):
    loop = _loop()
    for w in range(3):
        for i in range(15):
            loop.observe_request("a", "h1" if w < 2 else "h2",
                                 t=1.0 + 10.0 * w + 0.1 * i)
    loop.flush(t=31.0)
    payload = loop.drift_report_payload("unit")
    path = str(tmp_path / "drift.json")
    save_drift_report(payload, path)
    loaded = load_drift_report(path)
    assert loaded["source"] == "unit"
    assert loaded["fires"] == loop.detector.fires
    assert len(loaded["windows"]) == 3
    for win in loaded["windows"]:
        assert {"t_end", "invocations", "mix_change", "eps_eff",
                "score", "fired", "suppressed"} <= set(win)
    assert loaded["config"]["window_s"] == 10.0
    assert "sampler_overhead_pct" in loaded

    with pytest.raises(Exception):
        load_drift_report(str(tmp_path / "missing.json"))


def test_drift_gauges_exported():
    from repro.obs.metrics import default_registry
    loop = _loop()
    for w in range(2):
        for i in range(15):
            loop.observe_request("a", "h", t=1.0 + 10.0 * w + 0.1 * i)
    loop.flush(t=21.0)
    text = default_registry().render()
    assert "repro_drift_score" in text
    assert "repro_sampler_overhead_pct" in text


# ---------------------------------------------------------------------------
# sim fleet: the closed loop beats a static deployment on a flip
# ---------------------------------------------------------------------------

def test_sim_adaptive_loop_reoptimizes_through_policy():
    """make_sim_adaptive_loop wires apply -> policy.add_report: after a
    confirmed flip the newly-hot app gains a report-backed keep-alive
    floor it did not have before."""
    profiles = {
        a: AppProfile(app=a, cold_init_ms=400.0, warm_init_ms=40.0,
                      invoke_ms=30.0, rss_mb=128.0, zygote_rss_mb=32.0)
        for a in ("hot", "cold")
    }
    policy = ProfileGuidedPolicy(rate_hint_per_s=1.0)
    manager = FleetManager(profiles, policy, budget_mb=2048.0)
    loop = make_sim_adaptive_loop(
        manager, config=AdaptiveConfig(
            drift=DriftConfig(window_s=10.0, min_invocations=10)))
    ka_before = policy.keep_alive_s("cold")
    manager.begin("flip")
    t = 0.0
    for w, app in enumerate(["hot", "hot", "cold"]):
        for i in range(20):
            t = 1.0 + 10.0 * w + 0.1 * i
            loop.observe_request(app, None, t=t)
            manager.offer(Request(t, app))
    summary = manager.finish(40.0)
    loop.flush(t=40.0)
    assert loop.detector.fires == 1
    assert loop.applied >= 1
    # the regenerated report reached the policy: keep-alive moved off
    # the no-report floor to the amortization horizon
    assert policy.keep_alive_s("cold") > ka_before
    assert summary.n_requests == 60


def test_sim_closed_loop_beats_static_on_popularity_flip():
    """The bench acceptance scenario, smoke-sized: yesterday's reports
    cover only the pre-flip head; the adaptive fleet must win on cold
    ratio and not lose on p99 init latency."""
    from benchmarks.bench_fleet import run_adaptive_comparison
    res = run_adaptive_comparison(smoke=True)
    assert res["drift_fires"] >= 1
    assert res["adaptive_cold_ratio"] < res["static_cold_ratio"]
    assert res["adaptive_p99_init_ms"] <= res["static_p99_init_ms"]
    assert res["adaptive_beats_static"] is True
    assert os.path.exists(res["drift_report_path"])


# ---------------------------------------------------------------------------
# rewarm errors: swallowed failures must surface in summary + exit code
# ---------------------------------------------------------------------------

def test_rewarm_errors_surface_in_summary_payload(tmp_path):
    from repro.api import load_fleet_summary

    def boom():
        raise RuntimeError("artifact store down")

    manager = FleetManager(
        {"a": AppProfile(app="a", cold_init_ms=100.0, warm_init_ms=10.0,
                         invoke_ms=10.0, rss_mb=64.0)},
        IdleTimeoutPolicy(timeout_s=60.0), budget_mb=1024.0,
        queue=QueueConfig(depth=8))
    out = str(tmp_path / "sum.json")
    d = FleetDaemon(SimFleetBackend(manager), rewarm_fn=boom,
                    summary_path=out)
    d.start("live")
    d.rewarm_now()
    d.rewarm_now()
    d.submit(Request(0.0, "a"))
    payload = d.shutdown(end_t=10.0)
    # the ring buffer alone would hide the failures from the artifact
    assert payload["rewarm_errors"] == 2
    assert payload["served"] == 1  # serving was never disturbed
    assert load_fleet_summary(out)["rewarm_errors"] == 2


def test_fleet_serve_exits_nonzero_on_rewarm_errors(tmp_path,
                                                    monkeypatch,
                                                    capsys):
    """A report artifact that goes corrupt mid-run (a partial CI
    write) makes the forced rewarm tick fail; the serve run must say
    so in its exit status, not just a log line."""
    from repro.api import save_report
    from repro.cli import main

    reports_dir = tmp_path / "reports"
    reports_dir.mkdir()
    report_path = reports_dir / "a.json"
    save_report(OptimizationReport(
        application="a", e2e_s=0.3, total_init_s=0.15, qualifies=True,
        stats=[], defer_targets=[]), str(report_path))

    class _Feed:
        """Valid report at boot; corrupt it just before the tick."""

        def __iter__(self):
            yield json.dumps({"app": "a"}) + "\n"
            report_path.write_text("{not json")
            yield json.dumps({"cmd": "rewarm"}) + "\n"

    monkeypatch.setattr("sys.stdin", _Feed())
    rc = main(["fleet", "serve", "--sim", "--stdin", "--apps", "a",
               "--queue-depth", "8",
               "--reports-dir", str(reports_dir),
               "--summary-out", str(tmp_path / "sum.json")])
    assert rc == 1
    assert "rewarm error" in capsys.readouterr().err
    summary = json.loads((tmp_path / "sum.json").read_text())
    assert summary["rewarm_errors"] >= 1
    assert summary["served"] == 1


def test_fleet_serve_sim_adaptive_cli_writes_drift_report(tmp_path,
                                                          monkeypatch):
    """--adaptive on the sim daemon: the summary carries the adaptive
    block and --drift-out lands a loadable drift_report artifact."""
    import io

    from repro.cli import main

    feed = io.StringIO("".join(json.dumps({"app": "a"}) + "\n"
                               for _ in range(6)))
    monkeypatch.setattr("sys.stdin", feed)
    drift_out = tmp_path / "drift.json"
    rc = main(["fleet", "serve", "--sim", "--stdin", "--apps", "a,b",
               "--queue-depth", "8", "--adaptive",
               "--drift-window-s", "5",
               "--drift-out", str(drift_out),
               "--summary-out", str(tmp_path / "sum.json")])
    assert rc == 0
    summary = json.loads((tmp_path / "sum.json").read_text())
    assert "adaptive" in summary
    assert summary["adaptive"]["fires"] == 0  # six arrivals: no drift
    loaded = load_drift_report(str(drift_out))
    assert loaded["source"] == "serve-sim"
    assert loaded["fires"] == 0


def test_drift_status_cli_renders_report(tmp_path, capsys):
    from repro.cli import main

    loop = _loop()
    for w in range(3):
        for i in range(15):
            loop.observe_request("a", "h1" if w < 2 else "h2",
                                 t=1.0 + 10.0 * w + 0.1 * i)
    loop.flush(t=31.0)
    path = str(tmp_path / "drift.json")
    save_drift_report(loop.drift_report_payload("unit"), path)

    assert main(["drift", "status", path]) == 0
    out = capsys.readouterr().out
    assert "unit" in out and "fired" in out

    assert main(["drift", "status", path, "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["fires"] == loop.detector.fires


# ---------------------------------------------------------------------------
# slow tier: the real zygote fleet re-optimizes itself mid-replay
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def suite_root():
    from repro.benchsuite.genlibs import build_suite
    return build_suite()


@pytest.mark.slow
def test_real_fleet_adaptive_replay_hot_swaps_midstream(suite_root):
    """Handler-mix shift against real zygotes: the loop must confirm
    the drift, regenerate in-process from live child profiles, apply
    through rewarm (and the two-tier base swap) — all with zero sheds
    and full request conservation."""
    from repro.pool.fleet import ZygoteFleet

    apps = {name: os.path.join(suite_root, "apps", name)
            for name in ["graph_bfs", "echo"]}
    fleet = ZygoteFleet(apps, budget_mb=4096.0, shared_base=True,
                        base_min_apps=2)
    # small windows + a permissive guard: the slow tier can afford ~30
    # real dispatches, not the thousands the default gate is sized for
    cfg = AdaptiveConfig(
        profile=LiveProfileConfig(sample_every=1, interval_s=0.005),
        drift=DriftConfig(window_s=5.0, min_invocations=6,
                          noise_guard=0.5, cooldown_windows=1))
    with fleet:
        loop = fleet.make_adaptive_loop(config=cfg)
        reqs = []
        # two windows of graph_bfs/bfs history, then the mix flips
        # mid-stream: echo takes over while graph_bfs keeps a trickle
        # (so the fired window's app set has live shards to
        # regenerate from)
        mixes = [
            [("graph_bfs", "bfs")] * 8,
            [("graph_bfs", "bfs")] * 8,
            [("echo", None)] * 6 + [("graph_bfs", "bfs")] * 2,
            [("echo", None)] * 6 + [("graph_bfs", "bfs")] * 2,
        ]
        for w, mix in enumerate(mixes):
            for i, (app, handler) in enumerate(mix):
                reqs.append(Request(0.5 + 5.0 * w + 0.5 * i, app,
                                    handler=handler))
        trace = Trace("adaptive-shift", reqs, 21.0)
        rows = fleet.replay(trace, adaptive=loop)
        summary = fleet.last_summary
    assert loop.detector.fires >= 1
    assert loop.applied >= 1  # live-regenerated reports were deployed
    assert loop.swaps >= 1  # the shared base was hot-swapped
    assert not loop.errors
    # conservation with zero sheds through the swap
    assert summary["requests"] == len(reqs)
    assert summary["served"] == len(reqs)
    assert summary.get("sheds", 0) == 0
    assert summary["adaptive"]["fires"] == loop.detector.fires
    assert {r["app"] for r in rows} == {"graph_bfs", "echo"}
    # the profiled execs really carried the sampler
    snap = loop.profiler.snapshot()
    assert any(st["profiled_execs"] > 0 for st in snap.values())
    assert loop.profiler.overhead_pct() < 50.0


@pytest.mark.slow
def test_fleet_replay_real_adaptive_cli(suite_root, tmp_path):
    from repro.cli import main
    out = str(tmp_path / "replay.json")
    drift_out = str(tmp_path / "drift.json")
    rc = main(["fleet", "replay", "--real", "--root", suite_root,
               "--apps", "graph_bfs,echo", "--minutes", "2",
               "--peak-rpm", "20", "--limit", "8", "--adaptive",
               "--drift-window-s", "30", "--out", out,
               "--drift-out", drift_out])
    assert rc == 0
    from repro.api import load_fleet_summary
    summary = load_fleet_summary(out)
    assert summary["source"] == "replay-real"
    assert summary["requests"] == 8
    assert "adaptive" in summary
    loaded = load_drift_report(drift_out)
    assert loaded["source"] == "replay-real"
    assert "windows" in loaded and "config" in loaded
