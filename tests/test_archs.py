"""Per-architecture smoke tests on reduced configs (CPU).

For every assigned architecture:
  * one train loss+grad step -> finite loss, no NaN grads, right shapes;
  * prefill -> decode_step chain matches the teacher-forced full forward
    (the strongest cache-correctness check a serving stack has).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models import (
    SHAPES, decode_step, forward, init_params, input_specs, loss_fn,
    materialize, prefill,
)
from repro.models.config import ShapeSpec

jax.config.update("jax_enable_x64", False)

# every case jit-compiles a full reduced model; minutes of wall clock
pytestmark = pytest.mark.slow


def _small_train_shape(cfg):
    return ShapeSpec("smoke_train", 32 + (cfg.vision_tokens or 0), 2,
                     "train")


def _batch_for(cfg, shape, seed=0):
    batch = materialize(input_specs(cfg, shape), seed=seed)
    if "tokens" in batch:
        batch["tokens"] = batch["tokens"] % cfg.vocab
    if "labels" in batch:
        batch["labels"] = batch["labels"] % cfg.vocab
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, _small_train_shape(cfg))

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p_: loss_fn(cfg, p_, b), has_aux=True)(p)
        return loss, metrics, grads

    loss, metrics, grads = step(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss is not finite"
    flat = jax.tree.leaves(grads)
    assert flat, f"{arch}: empty grads"
    for g in flat:
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32))), \
            f"{arch}: NaN/inf grad"
    # loss decreases under an SGD step for SOME step size (sanity that
    # grads point in a descent direction).  A single fixed lr is not
    # deterministic across archs: sharp-curvature models (whisper,
    # xlstm) overshoot at 1e-2 even though the gradient is correct, so
    # back off like a line search before declaring the grads useless.
    for lr in (1e-2, 1e-3, 1e-4, 1e-5, 1e-6):
        params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                               params, grads)
        loss2, _, _ = step(params2, batch)
        if float(loss2) < float(loss) - 1e-4:
            break
    else:
        pytest.fail(f"{arch}: no descent at any step size "
                    f"(loss {float(loss)} -> {float(loss2)})")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, T0, n_dec = 2, 8, 5
    total = T0 + n_dec
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (B, total), 0, cfg.vocab, jnp.int32)
    extra = {}
    if cfg.vision_tokens:
        extra["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.vision_tokens, cfg.d_model),
            jnp.float32).astype(cfg.jdtype)
    if cfg.encoder_layers:
        extra["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(4), (B, cfg.encoder_seq, cfg.d_model),
            jnp.float32).astype(cfg.jdtype)

    # teacher-forced logits for the whole sequence
    from repro.models.model import _head  # noqa: PLC0415
    h, _, _ = forward(cfg, params, tokens, **extra)
    full_logits = _head(cfg, params, h)  # (B, S', V)

    # prefill on the first T0 tokens, then decode the rest step by step
    logits_p, caches, _ = jax.jit(
        lambda p, t, e: prefill(cfg, p, t, cache_len=total +
                                (cfg.vision_tokens or 0), **{
                                    k: e[k] for k in e})
    )(params, tokens[:, :T0], extra)
    vt = cfg.vision_tokens or 0
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(full_logits[:, vt + T0 - 1], np.float32),
        rtol=2e-3, atol=2e-3, err_msg=f"{arch}: prefill logits mismatch")

    dec = jax.jit(lambda p, t, pos, c: decode_step(cfg, p, t, pos, c))
    for i in range(n_dec):
        pos = jnp.full((B,), vt + T0 + i, jnp.int32)
        logits_d, caches = dec(params, tokens[:, T0 + i:T0 + i + 1], pos,
                               caches)
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(full_logits[:, vt + T0 + i], np.float32),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode step {i} logits mismatch")


def test_chunked_attention_matches_plain():
    """The online-softmax XLA path must agree with plain masked attention."""
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    B, S, K, G, hd = 2, 64, 2, 2, 16
    q = jax.random.normal(key, (B, S, K, G, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    for window, cap in [(None, None), (16, None), (None, 30.0)]:
        plain = L.attention(q, k, v, q_positions=pos, kv_positions=pos,
                            causal=True, window=window, softcap_val=cap)
        chunked = L.attention(q, k, v, q_positions=pos, kv_positions=pos,
                              causal=True, window=window, softcap_val=cap,
                              chunk_q=16, chunk_kv=16)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(plain),
                                   rtol=2e-3, atol=2e-3)


def test_decode_past_local_window_ring_buffer():
    """Ring-buffer local cache stays correct after wrapping the window."""
    arch = "recurrentgemma-2b"
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B = 2
    total = cfg.window_size + 12  # force wraparound
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, total), 0,
                                cfg.vocab, jnp.int32)
    from repro.models.model import _head
    h, _, _ = forward(cfg, params, tokens)
    full_logits = _head(cfg, params, h)

    T0 = 4
    _, caches, _ = prefill(cfg, params, tokens[:, :T0], cache_len=total)
    dec = jax.jit(lambda p, t, pos, c: decode_step(cfg, p, t, pos, c))
    for i in range(T0, total):
        pos = jnp.full((B,), i, jnp.int32)
        logits_d, caches = dec(params, tokens[:, i:i + 1], pos, caches)
    np.testing.assert_allclose(
        np.asarray(logits_d, np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=5e-3, atol=5e-3)
