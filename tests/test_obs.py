"""Observability stack tests: tracer, metrics registry, Prometheus
exposition, structured log, anatomy analysis, console folding, the
trace_events artifact, and the instrumented daemon/engine paths.

Fast tier: everything in-process.  Slow tier: child-process spans
surviving the fork-server protocol round-trip over a real benchsuite
app, and cold-vs-pool span shapes over a live ZygoteFleet.
"""

import io
import json
import os
import threading
import time
import urllib.request

import pytest

from repro.api.artifacts import load_trace_events, save_trace_events
from repro.obs.anatomy import (
    UNATTRIBUTED, folded_stacks, phase_breakdown, render_report,
    top_imports,
)
from repro.obs.console import render_table, rows_from_exposition, run_top
from repro.obs.exposition import (
    CONTENT_TYPE, MetricsServer, write_metrics_textfile,
)
from repro.obs.log import Logger, configure as configure_log
from repro.obs.metrics import (
    MetricsRegistry, default_registry, histogram_quantile,
    parse_exposition, validate_exposition,
)
from repro.obs.tracing import (
    Span, Tracer, configure_tracing, get_tracer, new_id,
    spans_from_import_timer,
)


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Every test starts from disabled tracing and an empty registry."""
    configure_tracing(enabled=False)
    get_tracer().clear()
    default_registry().reset()
    yield
    configure_tracing(enabled=False)
    get_tracer().clear()
    default_registry().reset()


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_dict_roundtrip_omits_empty_fields():
    s = Span(name="x", trace_id="t", span_id="s", t_start_ms=1.2345,
             duration_ms=2.5)
    d = s.to_dict()
    assert "parent_id" not in d and "attrs" not in d
    back = Span.from_dict(d)
    assert back.name == "x" and back.duration_ms == 2.5
    s2 = Span(name="y", trace_id="t", span_id="s2", parent_id="s",
              attrs={"app": "a"})
    d2 = s2.to_dict()
    assert d2["parent_id"] == "s" and d2["attrs"] == {"app": "a"}


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    with tr.span("request", app="a") as h:
        assert not h            # falsy noop handle
        assert h.ctx() is None  # -> protocol carries no trace field
        h.set("k", "v")         # must not raise
    assert tr.snapshot() == []
    tr.add("x", trace_id="t", t_start_ms=0.0, duration_ms=1.0)
    assert tr.snapshot() == []


def test_span_nesting_sums_within_wall_time():
    tr = Tracer(enabled=True)
    with tr.span("request", app="a") as root:
        with tr.span("dispatch", ctx=root.ctx()):
            time.sleep(0.005)
        with tr.span("invoke", ctx=root.ctx()):
            time.sleep(0.002)
    spans = {s.name: s for s in tr.snapshot()}
    assert set(spans) == {"request", "dispatch", "invoke"}
    root_s = spans["request"]
    for name in ("dispatch", "invoke"):
        child = spans[name]
        assert child.trace_id == root_s.trace_id
        assert child.parent_id == root_s.span_id
        assert child.t_start_ms >= root_s.t_start_ms
        assert (child.t_start_ms + child.duration_ms
                <= root_s.t_start_ms + root_s.duration_ms + 0.001)
    assert (spans["dispatch"].duration_ms + spans["invoke"].duration_ms
            <= root_s.duration_ms + 0.001)


def test_ring_buffer_caps_and_counts_drops():
    tr = Tracer(capacity=4, enabled=True)
    for i in range(10):
        tr.add(f"s{i}", trace_id="t", t_start_ms=float(i),
               duration_ms=1.0)
    assert len(tr.snapshot()) == 4
    assert tr.dropped == 6
    assert [s.name for s in tr.snapshot()] == ["s6", "s7", "s8", "s9"]


def test_record_dicts_skips_malformed():
    tr = Tracer(enabled=True)
    tr.record_dicts([
        {"name": "ok", "trace_id": "t", "span_id": "a",
         "t_start_ms": 0.0, "duration_ms": 1.0},
        {"not": "a span"},
        None,
    ])
    assert [s.name for s in tr.snapshot()] == ["ok"]


def test_spans_from_import_timer_preserves_parent_chain():
    from repro.core.profiler.import_timer import ModuleInitRecord
    records = {
        "libA": ModuleInitRecord(name="libA", filename="<x>",
                                 self_s=0.01, cumulative_s=0.03,
                                 parent=None),
        "libA.sub": ModuleInitRecord(name="libA.sub", filename="<x>",
                                     self_s=0.02, cumulative_s=0.02,
                                     parent="libA"),
    }
    out = spans_from_import_timer(records, trace_id="t",
                                  parent_id="phase", t_start_ms=100.0)
    by_name = {d["name"]: d for d in out}
    assert by_name["import:libA"]["parent_id"] == "phase"
    assert (by_name["import:libA.sub"]["parent_id"]
            == by_name["import:libA"]["span_id"])
    assert by_name["import:libA"]["duration_ms"] == pytest.approx(30.0)
    assert by_name["import:libA"]["attrs"]["self_ms"] == pytest.approx(
        10.0)


# ---------------------------------------------------------------------------
# metrics registry + exposition
# ---------------------------------------------------------------------------

def test_counter_histogram_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests", labels=("app",))
    c.labels(app="a").inc()
    c.labels(app="a").inc(2)
    with pytest.raises(ValueError):
        c.labels(app="a").inc(-1)
    with pytest.raises(ValueError):
        c.labels(bogus="x")
    g = reg.gauge("depth", "queue depth")
    g.set(5)
    g.dec(2)
    h = reg.histogram("lat_ms", "latency", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    h.observe(100.0)
    snap = reg.snapshot()
    fam = {f["name"]: f for f in snap["families"]}
    assert fam["req_total"]["series"][0]["value"] == 3
    assert fam["depth"]["series"][0]["value"] == 3
    hs = fam["lat_ms"]["series"][0]
    assert hs["counts"] == [1, 1, 1] and hs["count"] == 3
    assert hs["sum"] == pytest.approx(105.5)


def test_registry_get_or_create_is_idempotent_but_kind_clashes_raise():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "x", labels=("app",))
    b = reg.counter("x_total", "x", labels=("app",))
    assert a is b
    with pytest.raises(ValueError):
        reg.gauge("x_total", "x")
    with pytest.raises(ValueError):
        reg.counter("x_total", "x", labels=("other",))


def test_snapshot_merge_adds_counters_and_histograms():
    reg = MetricsRegistry()
    reg.counter("n_total", "n").inc(2)
    reg.histogram("h_ms", "h", buckets=(1.0,)).observe(0.5)
    reg.gauge("g", "g").set(7)
    snap = reg.snapshot()
    reg.merge_snapshot(snap)
    fam = {f["name"]: f for f in reg.snapshot()["families"]}
    assert fam["n_total"]["series"][0]["value"] == 4
    assert fam["h_ms"]["series"][0]["count"] == 2
    assert fam["g"]["series"][0]["value"] == 7  # gauges: last wins


def test_exposition_renders_valid_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("repro_requests_total", "reqs",
                labels=("app",)).labels(app="a").inc(3)
    reg.histogram("repro_wait_ms", "wait",
                  labels=("app",)).labels(app="a").observe(2.0)
    text = reg.render()
    assert validate_exposition(text) == []
    parsed = parse_exposition(text)
    assert parsed["types"]["repro_requests_total"] == "counter"
    assert parsed["types"]["repro_wait_ms"] == "histogram"
    samples = {(n, tuple(sorted(l.items()))): v
               for n, l, v in parsed["samples"]}
    assert samples[("repro_requests_total", (("app", "a"),))] == 3.0
    # cumulative buckets end with +Inf == _count
    infs = [v for n, l, v in parsed["samples"]
            if n == "repro_wait_ms_bucket" and l.get("le") == "+Inf"]
    counts = [v for n, l, v in parsed["samples"]
              if n == "repro_wait_ms_count"]
    assert infs == counts == [1.0]


def test_histogram_quantile_upper_bound_estimate():
    pairs = [(1.0, 0.0), (10.0, 9.0), (100.0, 10.0),
             (float("inf"), 10.0)]
    assert histogram_quantile(0.5, pairs) == 10.0
    assert histogram_quantile(0.99, pairs) == 100.0
    assert histogram_quantile(0.5, []) is None


def test_metrics_server_serves_scrapes(tmp_path):
    reg = MetricsRegistry()
    reg.counter("up_total", "x").inc()
    with MetricsServer(registry=reg, port=0) as srv:
        with urllib.request.urlopen(srv.url, timeout=5) as resp:
            assert resp.headers["Content-Type"] == CONTENT_TYPE
            body = resp.read().decode()
        assert "up_total 1" in body
        health = urllib.request.urlopen(
            srv.url.replace("/metrics", "/healthz"), timeout=5)
        assert health.read().strip() == b"ok"
    path = str(tmp_path / "m.prom")
    write_metrics_textfile(path, registry=reg)
    assert validate_exposition(open(path).read()) == []


# ---------------------------------------------------------------------------
# structured log
# ---------------------------------------------------------------------------

def test_log_json_mode_and_level_threshold():
    buf = io.StringIO()
    configure_log(level="info", json_mode=True, stream=buf)
    log = Logger("test.comp")
    log.debug("dropped", n=1)
    log.info("kept", app="a", ms=1.2345)
    lines = [json.loads(l) for l in buf.getvalue().splitlines()]
    assert len(lines) == 1
    evt = lines[0]
    assert evt["event"] == "kept" and evt["component"] == "test.comp"
    assert evt["level"] == "info" and evt["app"] == "a"
    with pytest.raises(ValueError):
        configure_log(level="nope")


def test_log_text_mode_formats_key_values():
    buf = io.StringIO()
    configure_log(level="debug", json_mode=False, stream=buf)
    Logger("c").warning("thing-happened", count=3, msg="two words")
    line = buf.getvalue().strip()
    assert "WARNING" in line and "thing-happened" in line
    assert "count=3" in line and '"two words"' in line


# ---------------------------------------------------------------------------
# anatomy
# ---------------------------------------------------------------------------

def _request_trace(tid: str, wall_ms: float, children: dict) -> list:
    root = {"name": "request", "trace_id": tid, "span_id": f"{tid}-r",
            "t_start_ms": 0.0, "duration_ms": wall_ms}
    out = [root]
    t = 0.0
    for name, dur in children.items():
        out.append({"name": name, "trace_id": tid,
                    "span_id": f"{tid}-{name}",
                    "parent_id": f"{tid}-r",
                    "t_start_ms": t, "duration_ms": dur})
        t += dur
    return out


def test_phase_breakdown_attributes_and_residual_sums_to_wall():
    spans = (_request_trace("t1", 100.0,
                            {"queue_wait": 10.0, "dispatch": 80.0})
             + _request_trace("t2", 50.0, {"dispatch": 50.0}))
    out = phase_breakdown(spans)
    assert out["requests"] == 2 and out["traces"] == 2
    assert out["wall_ms_total"] == pytest.approx(150.0)
    rows = {r["phase"]: r for r in out["phases"]}
    # phase self-times + unattributed == wall, exactly
    assert sum(r["total_ms"] for r in out["phases"]) == pytest.approx(
        150.0)
    assert rows[UNATTRIBUTED]["total_ms"] == pytest.approx(10.0)
    assert out["attributed_frac"] == pytest.approx(140.0 / 150.0,
                                                   abs=1e-4)
    assert rows["dispatch"]["count"] == 2


def test_phase_breakdown_boot_traces_are_their_own_phase():
    spans = _request_trace("t1", 100.0, {"dispatch": 100.0})
    spans.append({"name": "zygote_boot", "trace_id": "b1",
                  "span_id": "b1-r", "t_start_ms": 0.0,
                  "duration_ms": 200.0})
    out = phase_breakdown(spans)
    assert out["requests"] == 1 and out["traces"] == 2
    rows = {r["phase"]: r for r in out["phases"]}
    assert rows["zygote_boot"]["total_ms"] == pytest.approx(200.0)
    # a boot trace is attributed (to its phase), not "unexplained"
    assert out["attributed_frac"] == pytest.approx(1.0)


def test_folded_stacks_self_time_paths():
    spans = _request_trace("t1", 100.0, {"dispatch": 80.0})
    spans.append({"name": "import:libA", "trace_id": "t1",
                  "span_id": "t1-i", "parent_id": "t1-dispatch",
                  "t_start_ms": 0.0, "duration_ms": 30.0})
    lines = dict(l.rsplit(" ", 1) for l in folded_stacks(spans))
    assert lines["request"] == str(20 * 1000)
    assert lines["request;dispatch"] == str(50 * 1000)
    assert lines["request;dispatch;import:libA"] == str(30 * 1000)


def test_top_imports_aggregates_by_module():
    spans = []
    for tid in ("t1", "t2"):
        spans.append({"name": "import:libA", "trace_id": tid,
                      "span_id": f"{tid}-i", "t_start_ms": 0.0,
                      "duration_ms": 40.0,
                      "attrs": {"module": "libA", "self_ms": 15.0}})
    rows = top_imports(spans, n=5)
    assert rows[0]["module"] == "libA"
    assert rows[0]["count"] == 2
    assert rows[0]["cumulative_ms"] == pytest.approx(80.0)
    assert rows[0]["self_ms"] == pytest.approx(30.0)


def test_render_report_is_printable():
    spans = _request_trace("t1", 100.0, {"dispatch": 90.0})
    text = render_report(spans, meta={"source": "test"})
    assert "cold-start anatomy" in text and "dispatch" in text


# ---------------------------------------------------------------------------
# trace_events artifact
# ---------------------------------------------------------------------------

def test_trace_events_artifact_roundtrip(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("request", app="a"):
        pass
    reg = MetricsRegistry()
    reg.counter("n_total", "n").inc()
    path = str(tmp_path / "te.json")
    save_trace_events(tr.snapshot(), path, metrics=reg.snapshot(),
                      meta={"source": "test"})
    art = load_trace_events(path)
    assert art.kind == "trace_events" and art.schema_version == 1
    assert len(art.spans) == 1 and art.spans[0]["name"] == "request"
    assert art.metrics["schema"] == "repro.metrics/1"
    assert art.meta["source"] == "test"
    raw = json.load(open(path))
    assert raw["kind"] == "trace_events"


# ---------------------------------------------------------------------------
# console
# ---------------------------------------------------------------------------

def _console_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    req = reg.counter("repro_requests_total", "r",
                      labels=("app", "outcome"))
    req.labels(app="a", outcome="queued").inc(8)
    req.labels(app="a", outcome="shed").inc(2)
    reg.counter("repro_sheds_total", "s", labels=("app", "reason")
                ).labels(app="a", reason="queue-full").inc(2)
    reg.counter("repro_served_total", "s", labels=("app",)
                ).labels(app="a").inc(8)
    dis = reg.counter("repro_dispatch_total", "d",
                      labels=("app", "path"))
    dis.labels(app="a", path="pool").inc(6)
    dis.labels(app="a", path="cold").inc(2)
    h = reg.histogram("repro_queue_wait_ms", "w", labels=("app",),
                      buckets=(1.0, 10.0))
    h.labels(app="a").observe(0.5)
    h.labels(app="a").observe(5.0)
    reg.counter("repro_base_swaps_total", "b").inc(3)
    return reg


def test_rows_from_exposition_folds_per_app():
    folded = rows_from_exposition(_console_registry().render())
    assert len(folded["apps"]) == 1
    row = folded["apps"][0]
    assert row["app"] == "a"
    assert row["requests"] == 10 and row["served"] == 8
    assert row["cold%"] == "25.0"      # 2 cold / 8 starts
    assert row["shed%"] == "20.0"      # 2 shed / 10 requests
    assert row["wait_p99_ms"] == "10.0"
    assert folded["fleet"]["base_swaps"] == 3.0
    text = render_table(folded, clock="12:00:00")
    assert "base_swaps=3" in text and "a" in text


def test_run_top_bounded_iterations_from_file(tmp_path):
    path = str(tmp_path / "m.prom")
    write_metrics_textfile(path, registry=_console_registry())
    outputs = []
    rc = run_top(path, interval_s=0.0, iterations=2, clear=False,
                 write=outputs.append)
    assert rc == 0 and len(outputs) == 2
    assert run_top(str(tmp_path / "missing.prom"), iterations=1,
                   write=outputs.append) == 1


# ---------------------------------------------------------------------------
# instrumented daemon / engine (fast tier, in-process)
# ---------------------------------------------------------------------------

def _sim_daemon(apps=("a", "b")):
    from repro.pool import (
        AppProfile, FleetDaemon, FleetManager, IdleTimeoutPolicy,
        QueueConfig, SimFleetBackend,
    )
    profiles = {a: AppProfile(app=a, cold_init_ms=400.0,
                              warm_init_ms=20.0, invoke_ms=30.0,
                              rss_mb=100.0) for a in apps}
    manager = FleetManager(profiles, IdleTimeoutPolicy(timeout_s=60.0),
                           budget_mb=2048.0,
                           queue=QueueConfig(depth=4,
                                             max_concurrency=1))
    return FleetDaemon(SimFleetBackend(manager))


def test_sim_daemon_emits_request_spans_and_counters():
    from repro.pool.trace import Request, Trace
    configure_tracing(enabled=True)
    d = _sim_daemon()
    d.start("t")
    reqs = [Request(t=float(i), app="a") for i in range(5)]
    payload = None
    try:
        for r in reqs:
            d.submit(r)
    finally:
        payload = d.shutdown(end_t=10.0)
    spans = get_tracer().snapshot()
    assert sum(1 for s in spans if s.name == "request") == 5
    snap = default_registry().snapshot()
    fam = {f["name"]: f for f in snap["families"]}
    total = sum(s["value"]
                for s in fam["repro_requests_total"]["series"])
    assert total == 5
    assert payload["requests"] == 5


class _InstantEngine:
    """Duck-typed ServingEngine: instant cold start and serve."""

    def __init__(self):
        self.cold_start_s = None
        self.registry = {}

    def cold_start(self):
        self.cold_start_s = 0.001
        return self.cold_start_s

    def serve(self, entry, tokens, **kw):
        return tokens, 0.0005


def test_engine_pool_cold_span_only_on_miss():
    from repro.serving.engine import EnginePool
    configure_tracing(enabled=True)
    pool = EnginePool({"m": _InstantEngine}, max_warm=2)
    pool.dispatch("m", "generate", [1])     # miss -> cold
    pool.dispatch("m", "generate", [1])     # hit -> warm
    spans = get_tracer().snapshot()
    dispatches = [s for s in spans if s.name == "engine_dispatch"]
    colds = [s for s in spans if s.name == "cold_start"]
    assert [d.attrs["path"] for d in dispatches] == ["cold", "warm"]
    assert len(colds) == 1
    assert colds[0].parent_id == dispatches[0].span_id
    snap = default_registry().snapshot()
    fam = {f["name"]: f for f in snap["families"]}
    ent = fam["repro_engine_dispatch_total"]
    series = {tuple(s["labels"]): s["value"] for s in ent["series"]}
    assert ent["labels"] == ["model", "path"]
    assert series[("m", "cold")] == 1
    assert series[("m", "warm")] == 1


def test_engine_pool_stats_breaks_out_pool_saturated_sheds():
    from repro.serving.engine import EnginePool, PoolSaturated

    class _SlowColdEngine(_InstantEngine):
        def cold_start(self):
            time.sleep(0.2)
            self.cold_start_s = 0.2
            return self.cold_start_s

    pool = EnginePool({"m": _SlowColdEngine}, max_warm=1,
                      queue_depth=0)
    sheds = []
    t = threading.Thread(target=lambda: pool.dispatch(
        "m", "generate", [1]))
    t.start()
    time.sleep(0.05)  # builder is mid-cold-start; depth 0 -> shed
    with pytest.raises(PoolSaturated):
        pool.dispatch("m", "generate", [1])
    t.join()
    stats = pool.stats()
    assert stats["sheds"] == 1
    assert stats["shed_reasons"] == {"pool-saturated": 1}


def test_tracer_disabled_daemon_path_untouched():
    """The whole serve path with tracing off records nothing."""
    from repro.pool.trace import Request
    d = _sim_daemon()
    d.start("t")
    for i in range(3):
        d.submit(Request(t=float(i), app="a"))
    d.shutdown(end_t=5.0)
    assert get_tracer().snapshot() == []


# ---------------------------------------------------------------------------
# slow tier: child-process spans over the fork-server protocol
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def suite_root():
    from repro.benchsuite.genlibs import build_suite
    return build_suite()


@pytest.mark.slow
def test_forkserver_spans_survive_protocol_roundtrip(suite_root):
    from repro.pool.forkserver import ForkServer
    configure_tracing(enabled=True)
    tracer = get_tracer()
    app_dir = os.path.join(suite_root, "apps", "graph_bfs")
    with ForkServer(app_dir, preload=["fakelib_igraph"]) as fs:
        with tracer.span("request", app="graph_bfs") as root:
            m = fs.exec(invocations=1, seed=1, trace=root.ctx())
        spans = m.get("spans", [])
        assert spans, "traced exec must ship child spans back"
        names = [s["name"] for s in spans]
        assert "fork" in names and "invoke" in names
        assert any(n.startswith("import:") for n in names)
        # every child span joins the caller's trace, rooted under it
        ids = {s["span_id"] for s in spans}
        for s in spans:
            assert s["trace_id"] == root.trace_id
            assert s.get("parent_id") in ids | {root.span_id}
        # the child clock (CLOCK_MONOTONIC) is system-wide: spans nest
        # inside the parent-side request wall time
        tracer.record_dicts(spans)
        all_spans = {s.span_id: s for s in tracer.snapshot()}
        req = all_spans[root.span_id]
        fork = next(s for s in tracer.snapshot() if s.name == "fork")
        assert fork.t_start_ms >= req.t_start_ms - 1.0
        assert (fork.t_start_ms + fork.duration_ms
                <= req.t_start_ms + req.duration_ms + 1.0)
        # an untraced exec ships no spans
        m2 = fs.exec(invocations=1, seed=2)
        assert "spans" not in m2


@pytest.mark.slow
def test_fleet_cold_requests_carry_fork_spans_warm_dont(suite_root):
    """Pool-path (zygote) requests fork and import; cold-path requests
    go through the subprocess cold_start span instead."""
    from repro.pool.fleet import ZygoteFleet
    from repro.pool.trace import Request, Trace
    configure_tracing(enabled=True)
    apps = {"echo": os.path.join(suite_root, "apps", "echo")}
    with ZygoteFleet(apps, budget_mb=4096.0) as fleet:
        fleet.replay(Trace(name="t",
                           requests=[Request(0.0, "echo"),
                                     Request(1.0, "echo")],
                           duration_s=2.0))
    by_trace = {}
    for s in get_tracer().snapshot():
        by_trace.setdefault(s.trace_id, []).append(s)
    req_traces = [ss for ss in by_trace.values()
                  if any(s.name == "request" for s in ss)]
    assert len(req_traces) == 2
    for ss in req_traces:
        names = {s.name for s in ss}
        root = next(s for s in ss if s.name == "request")
        assert root.attrs["path"] == "pool"
        # zygote dispatch = fork + handler import, never cold_start
        assert "fork" in names and "cold_start" not in names
        assert any(n.startswith("import") for n in names)
    # boot traces exist and are separate from request traces
    boots = [ss for ss in by_trace.values()
             if any(s.name == "zygote_boot" for s in ss)]
    assert len(boots) == 1
