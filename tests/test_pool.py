"""Warm-pool subsystem tests: traces, policies, simulator math, the
fork-server against a real benchsuite app, and the adaptive controller's
cooldown / pool-rewarm hooks."""

import math
import os

import pytest

from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import measure_cold_starts, measure_pool_starts
from repro.core.adaptive.controller import ControllerConfig, SlimStartController
from repro.core.adaptive.monitor import MonitorConfig
from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import LibraryStats
from repro.pool import (
    AppProfile,
    FixedSizePolicy,
    FleetSimulator,
    ForkServer,
    HistogramPolicy,
    IdleTimeoutPolicy,
    ProfileGuidedPolicy,
    Request,
    Trace,
    bursty_trace,
    diurnal_trace,
    handler_skewed_trace,
    hot_set_from_report,
    poisson_trace,
    standard_traces,
)


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

def test_traces_deterministic_and_ordered():
    for make in (lambda s: poisson_trace("a", rate_per_s=2.0,
                                         duration_s=200.0, seed=s),
                 lambda s: diurnal_trace("a", duration_s=400.0, seed=s),
                 lambda s: bursty_trace("a", duration_s=400.0, seed=s)):
        t1, t2, t3 = make(5), make(5), make(6)
        assert [r.t for r in t1] == [r.t for r in t2]
        assert [r.t for r in t1] != [r.t for r in t3]
        ts = [r.t for r in t1]
        assert ts == sorted(ts)
        assert all(0.0 <= t < t1.duration_s for t in ts)


def test_poisson_rate():
    tr = poisson_trace("a", rate_per_s=5.0, duration_s=2000.0, seed=1)
    assert tr.mean_rate_per_s == pytest.approx(5.0, rel=0.1)


def test_diurnal_peak_vs_trough():
    period = 400.0
    tr = diurnal_trace("a", base_rate_per_s=0.1, peak_rate_per_s=5.0,
                       period_s=period, duration_s=4 * period, seed=2)
    # crest of the cycle is at period/2 (+k*period); trough at 0/period
    crest = sum(1 for r in tr
                if (r.t % period) > period * 0.35
                and (r.t % period) < period * 0.65)
    trough = sum(1 for r in tr
                 if (r.t % period) < period * 0.15
                 or (r.t % period) > period * 0.85)
    assert crest > 3 * max(trough, 1)


def test_bursty_is_overdispersed():
    tr = bursty_trace("a", duration_s=2000.0, seed=3)
    iats = tr.interarrivals()
    assert len(iats) > 50
    mean = sum(iats) / len(iats)
    var = sum((x - mean) ** 2 for x in iats) / len(iats)
    # Poisson would have CV ~ 1; on/off modulation must exceed it
    assert math.sqrt(var) / mean > 1.5


def test_handler_skewed_mix():
    tr = handler_skewed_trace("a", ["h0", "h1", "h2"], rate_per_s=5.0,
                              duration_s=1000.0, seed=4)
    counts = {}
    for r in tr:
        assert r.handler in {"h0", "h1", "h2"}
        counts[r.handler] = counts.get(r.handler, 0) + 1
    assert counts["h0"] > counts["h1"] > counts["h2"]


def test_standard_traces_shapes():
    traces = standard_traces("a", ["h0", "h1"], duration_s=300.0)
    assert set(traces) == {"poisson", "diurnal", "bursty", "handler_skewed"}
    assert set(standard_traces("a", None, duration_s=300.0)) == {
        "poisson", "diurnal", "bursty"}


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def _fake_report() -> OptimizationReport:
    def stat(name, samples, init_s):
        return LibraryStats(name=name, utilization=samples / 100.0,
                            init_s=init_s, init_share=init_s / 0.2,
                            runtime_samples=samples, file="<x>")
    return OptimizationReport(
        application="app", e2e_s=0.2, total_init_s=0.15, qualifies=True,
        stats=[stat("liba", 50, 0.08), stat("liba.sub", 20, 0.03),
               stat("libb", 0, 0.05), stat("libb.viz", 0, 0.03),
               stat("libc", 5, 0.02)],
        defer_targets=["libb"],
    )


def test_hot_set_from_report_maximal_prefixes_minus_deferred():
    hot = hot_set_from_report(_fake_report())
    assert "libb" not in hot and "libb.viz" not in hot  # deferred subtree
    assert "liba" in hot and "libc" in hot
    assert "liba.sub" not in hot  # covered by the liba prefix


def test_fixed_and_idle_policies():
    fixed = FixedSizePolicy(size=3)
    assert fixed.prewarm("app") == 3
    assert fixed.keep_alive_s("app") == math.inf
    idle = IdleTimeoutPolicy(timeout_s=42.0)
    assert idle.prewarm("app") == 0
    assert idle.keep_alive_s("app") == 42.0


def test_histogram_policy_learns_interarrivals():
    pol = HistogramPolicy(percentile=0.95, default_s=600.0, floor_s=10.0,
                          min_samples=8)
    assert pol.keep_alive_s("app") == 600.0  # no data yet -> default
    for i in range(30):
        pol.observe_arrival("app", 30.0 * i)
    ka = pol.keep_alive_s("app")
    assert 10.0 <= ka <= 31.0 and ka == pytest.approx(30.0, abs=1.0)
    # a different app is tracked independently
    assert pol.keep_alive_s("other") == 600.0


def test_profile_guided_policy_from_report():
    pol = ProfileGuidedPolicy(rate_hint_per_s=1.0)
    pol.add_report(_fake_report())
    assert pol.preload_modules("app") == hot_set_from_report(_fake_report())
    assert pol.prewarm("app") == 1  # ceil(1.0 * 0.2 s)
    # keep-alive amortizes the HOT (non-deferred) init: 0.15 - 0.05 = 0.1 s
    assert pol.keep_alive_s("app") == pytest.approx(400.0 * 0.1)
    # unknown app: conservative floor
    assert pol.prewarm("other") == 0
    assert pol.keep_alive_s("other") == pol.floor_s


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------

PROF = AppProfile(app="app", cold_init_ms=100.0, invoke_ms=10.0,
                  warm_init_ms=5.0, rss_mb=1024.0)


def _trace(times, duration):
    return Trace("manual", [Request(t, "app") for t in times], duration)


def test_simulator_cold_start_ratio_math():
    # keep-alive 50 s, arrivals at 0, 10, 100: the 10 s gap stays warm,
    # the 90 s gap expires -> 2 cold starts out of 3
    sim = FleetSimulator(PROF, IdleTimeoutPolicy(timeout_s=50.0))
    rep = sim.run(_trace([0.0, 10.0, 100.0], 120.0))
    assert rep.n_requests == 3
    assert rep.cold_starts == 2
    assert rep.cold_start_ratio == pytest.approx(2 / 3)
    assert rep.reclaims == 1
    assert sorted(rep.latencies_ms) == [15.0, 110.0, 110.0]
    assert rep.p50_ms == 110.0


def test_simulator_prewarm_eliminates_cold_starts():
    rep = FleetSimulator(PROF, FixedSizePolicy(size=1)).run(
        _trace([0.0, 10.0, 100.0], 120.0))
    assert rep.cold_starts == 0
    assert all(lat == 15.0 for lat in rep.latencies_ms)
    # one instance resident for the whole trace
    assert rep.memory_mb_s == pytest.approx(1024.0 * 120.0, rel=1e-6)


def test_simulator_concurrency_spawns_instances():
    # two arrivals 1 ms apart: the warm instance is still busy (115 ms
    # service), so the second must cold-start a new instance
    rep = FleetSimulator(PROF, IdleTimeoutPolicy(timeout_s=1000.0)).run(
        _trace([0.0, 0.001], 10.0))
    assert rep.cold_starts == 2
    assert rep.max_instances == 2


def test_simulator_memory_accounts_reclaim_moment():
    # keep-alive 10 s: each instance finishes 0.11 s after its arrival
    # (110 ms cold latency) and dies 10 s later — neither is charged to
    # trace end (100 s)
    rep = FleetSimulator(PROF, IdleTimeoutPolicy(timeout_s=10.0)).run(
        _trace([0.0, 50.0], 100.0))
    assert rep.cold_starts == 2  # second arrival is past the reclaim
    assert rep.reclaims == 2
    expected = 1024.0 * 2 * (0.11 + 10.0)
    assert rep.memory_mb_s == pytest.approx(expected, rel=1e-6)


def test_simulator_reclaims_idle_tail_at_trace_end():
    # a single request at t=0 with a 10 s keep-alive must be charged
    # ~10.11 s of memory, not the full 100 s trace (the reclaim happens
    # in the idle tail, after the last arrival)
    rep = FleetSimulator(PROF, IdleTimeoutPolicy(timeout_s=10.0)).run(
        _trace([0.0], 100.0))
    assert rep.reclaims == 1
    assert rep.memory_mb_s == pytest.approx(1024.0 * (0.11 + 10.0),
                                            rel=1e-6)


def test_app_profile_from_stats():
    from repro.benchsuite.harness import ColdStartStats
    c = ColdStartStats(app="x", n=2, init_ms=[100.0, 120.0],
                       e2e_ms=[130.0, 150.0], peak_rss_kb=[2048, 2048])
    p = ColdStartStats(app="x", n=2, init_ms=[10.0, 12.0],
                       e2e_ms=[40.0, 42.0], peak_rss_kb=[2048, 2048])
    prof = AppProfile.from_stats(c, p)
    assert prof.cold_init_ms == pytest.approx(110.0)
    assert prof.invoke_ms == pytest.approx(30.0)
    assert prof.warm_init_ms == pytest.approx(11.0)
    assert prof.rss_mb == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# fork-server against a real deployed app (subprocess-heavy)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def suite_root_dir():
    return build_suite()


@pytest.mark.slow
def test_forkserver_warm_beats_fresh_cold(suite_root_dir):
    app_dir = os.path.join(suite_root_dir, "apps", "graph_bfs")
    fresh = measure_cold_starts(app_dir, n=2)
    pool = measure_pool_starts(app_dir, n=2, preload=["fakelib_igraph"])
    assert pool.init_mean < fresh.init_mean / 2  # the 2x acceptance bar
    assert pool.n == 2 and len(pool.init_ms) == 2
    assert all(m > 0 for m in pool.e2e_ms)


@pytest.mark.slow
def test_forkserver_bad_preload_fails_loudly(suite_root_dir):
    """A typo'd hot set must not silently degrade to a bare zygote —
    the benchmark would report bare-pool numbers as hot-pool ones."""
    from repro.pool import ForkServerError
    app_dir = os.path.join(suite_root_dir, "apps", "graph_bfs")
    fs = ForkServer(app_dir, preload=["fakelib_igrap"])  # typo
    with pytest.raises(ForkServerError, match="failed to boot"):
        fs.start()
    assert fs.proc is None  # boot failure tears the zygote down


@pytest.mark.slow
def test_forkserver_protocol_and_rewarm(suite_root_dir):
    app_dir = os.path.join(suite_root_dir, "apps", "graph_bfs")
    with ForkServer(app_dir) as fs:
        assert fs.ready["ok"] and fs.ready["preloaded"] == []
        m = fs.exec(invocations=2, handler="bfs", seed=1)
        assert m["invocations"] == {"bfs": 2}
        assert m["init_ms"] > 0 and m["peak_rss_kb"] > 0
        # adaptive re-warm: a report whose hot set is fakelib_igraph
        rep = OptimizationReport(
            application="graph_bfs", e2e_s=0.1, total_init_s=0.05,
            qualifies=True,
            stats=[LibraryStats(name="fakelib_igraph", utilization=0.9,
                                init_s=0.05, init_share=0.5,
                                runtime_samples=90, file="<x>")])
        out = fs.rewarm(rep)
        assert out["preloaded"] == ["fakelib_igraph"]
        assert fs.ping()["preloaded"] == ["fakelib_igraph"]
        # preloaded zygote now forks warm instances
        warm = fs.exec(invocations=1, handler="bfs", seed=2)
        assert warm["init_ms"] < m["init_ms"]
        # rewarm with the same report is a no-op
        assert fs.rewarm(rep) == {"ok": True, "preloaded": [],
                                  "errors": []}


@pytest.mark.slow
def test_forkserver_zygote_crash_rewarm_recovers(suite_root_dir):
    """Kill the zygote mid-run: exec must fail loudly, and the adaptive
    ``rewarm`` hook must boot a fresh zygote (with the hot set merged
    into the preload) after which forks succeed again."""
    from repro.pool import ForkServerError
    app_dir = os.path.join(suite_root_dir, "apps", "graph_bfs")
    rep = OptimizationReport(
        application="graph_bfs", e2e_s=0.1, total_init_s=0.05,
        qualifies=True,
        stats=[LibraryStats(name="fakelib_igraph", utilization=0.9,
                            init_s=0.05, init_share=0.5,
                            runtime_samples=90, file="<x>")])
    fs = ForkServer(app_dir)
    try:
        fs.start()
        assert fs.alive
        m = fs.exec(invocations=1, handler="bfs", seed=1)
        assert m["init_ms"] > 0

        fs.proc.kill()  # the mid-run crash (OOM killer analog)
        fs.proc.wait(timeout=10)
        assert not fs.alive
        with pytest.raises(ForkServerError):
            fs.exec(invocations=1, handler="bfs", seed=2)

        out = fs.rewarm(rep)
        assert out.get("restarted") is True
        assert "fakelib_igraph" in out["preloaded"]
        assert fs.alive
        assert fs.ping()["preloaded"] == ["fakelib_igraph"]
        warm = fs.exec(invocations=1, handler="bfs", seed=3)
        assert warm["init_ms"] > 0
        assert warm["init_ms"] < m["init_ms"]  # hot set now preloaded
    finally:
        fs.stop()


@pytest.mark.slow
def test_zygote_fleet_crash_falls_back_cold_then_rewarms(suite_root_dir):
    """Fleet-level recovery: a dead zygote degrades the app to cold
    starts (dispatch never fails), and the controller's rewarm brings
    the pool path back."""
    from repro.pool import ZygoteFleet
    app_dir = os.path.join(suite_root_dir, "apps", "graph_bfs")
    rep = OptimizationReport(
        application="graph_bfs", e2e_s=0.1, total_init_s=0.05,
        qualifies=True,
        stats=[LibraryStats(name="fakelib_igraph", utilization=0.9,
                            init_s=0.05, init_share=0.5,
                            runtime_samples=90, file="<x>")])
    with ZygoteFleet({"graph_bfs": app_dir}) as fleet:
        assert fleet.dispatch("graph_bfs", handler="bfs",
                              seed=1)["path"] == "pool"
        fs = fleet.servers["graph_bfs"]
        fs.proc.kill()
        fs.proc.wait(timeout=10)
        m = fleet.dispatch("graph_bfs", handler="bfs", seed=2)
        assert m["path"] == "cold"  # degraded, not broken
        out = fleet.rewarm(rep)
        assert out.get("restarted") is True and not out["skipped"]
        assert fleet.dispatch("graph_bfs", handler="bfs",
                              seed=3)["path"] == "pool"
        assert fleet.dispatches["graph_bfs"] == {"pool": 2, "cold": 1,
                                                 "fallback": 0}


# ---------------------------------------------------------------------------
# adaptive controller: cooldown + pool rewarm hook
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _controller(clock, cooldown_s=0.0, rewarm_fn=None):
    reports = iter([_fake_report() for _ in range(10)])
    applied = []
    ctl = SlimStartController(
        profile_fn=lambda: next(reports),
        optimize_fn=applied.append,
        config=ControllerConfig(
            monitor=MonitorConfig(window_s=1.0, epsilon=0.1),
            cooldown_s=cooldown_s),
        clock=clock,
        rewarm_fn=rewarm_fn,
    )
    return ctl, applied


def _drive_shift(ctl, clock, handler_a, handler_b):
    """Two full windows of a, then windows of b -> monitor triggers."""
    for _ in range(5):
        ctl.on_invocation(handler_a)
    clock.t += 1.1
    ctl.on_invocation(handler_a)  # closes window 1 (baseline, no trigger)
    clock.t += 1.1
    ctl.on_invocation(handler_b)  # closes window 2 ({a}->{a}: no change)
    clock.t += 1.1
    ctl.on_invocation(handler_b)  # closes window 3 ({a}->{b}: trigger)


def test_controller_cooldown_suppresses_reprofiles():
    clock = _Clock()
    ctl, applied = _controller(clock, cooldown_s=100.0)
    _drive_shift(ctl, clock, "a", "b")
    assert ctl.profile_phases == 1
    # another shift right away: trigger fires but cooldown suppresses
    _drive_shift(ctl, clock, "b", "a")
    assert ctl.monitor.triggers >= 2
    assert ctl.profile_phases == 1
    # after the cooldown elapses the next trigger profiles again
    clock.t += 200.0
    _drive_shift(ctl, clock, "a", "b")
    assert ctl.profile_phases == 2
    assert len(applied) == 2


def test_controller_rewarms_pool_after_optimize():
    clock = _Clock()
    seen = []
    ctl, applied = _controller(clock, rewarm_fn=seen.append)
    rep = ctl.force_profile()
    assert applied == [rep]
    assert seen == [rep]
    assert ctl.rewarms == 1 and ctl.rewarm_errors == []


def test_controller_rewarm_failure_does_not_abort_phase():
    clock = _Clock()

    def boom(report):
        raise RuntimeError("zygote gone")

    ctl, applied = _controller(clock, rewarm_fn=boom)
    rep = ctl.force_profile()
    assert applied == [rep]          # optimize still applied
    assert ctl.profile_phases == 1   # phase completed
    assert ctl.rewarms == 0
    assert ctl.rewarm_errors and "zygote gone" in ctl.rewarm_errors[0]
