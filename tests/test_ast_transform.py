"""Unit tests for the automated code optimizer (paper §IV-B)."""

import subprocess
import sys
import textwrap

import pytest

from repro.core.optimizer.ast_transform import (
    COMMENT_TAG,
    OptimizeResult,
    optimize_source,
    optimize_file,
    restore_file,
)


def run_snippet(code: str) -> str:
    """Execute code in a fresh interpreter, return stdout."""
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0, proc.stderr
    return proc.stdout


def test_defers_global_import_into_function():
    src = textwrap.dedent("""\
        import json
        import os

        def handler(event):
            return json.dumps(event)

        def other():
            return os.getcwd()
    """)
    out, res = optimize_source(src, ["json"])
    assert res.changed
    assert "import json" in [d.strip() for d in res.deferred]
    # global import commented out
    assert f"# import json  {COMMENT_TAG}" in out
    # deferred into the using function only
    assert "    import json  # SLIMSTART" in out
    assert "import os\n" in out  # untouched
    # still executes correctly
    assert "{}" in run_snippet(out + "\nprint(handler({}))\n")


def test_from_import_with_alias():
    src = textwrap.dedent("""\
        from json import dumps as jd

        def handler(event):
            return jd(event)
    """)
    out, res = optimize_source(src, ["json"])
    assert res.changed
    assert "from json import dumps as jd  # SLIMSTART" in out
    assert "{}" in run_snippet(out + "\nprint(handler({}))\n")


def test_dotted_import_binds_root():
    src = textwrap.dedent("""\
        import os.path

        def handler(p):
            return os.path.basename(p)
    """)
    out, res = optimize_source(src, ["os.path"])
    assert res.changed
    assert "import os.path  # SLIMSTART" in out
    assert run_snippet(out + "\nprint(handler('/a/b'))\n").strip() == "b"


def test_module_level_use_is_unsafe_and_skipped():
    src = textwrap.dedent("""\
        import json

        CONST = json.dumps({})

        def handler():
            return CONST
    """)
    out, res = optimize_source(src, ["json"])
    assert not res.changed
    assert res.skipped and "json" in res.skipped[0]
    assert out == src


def test_lambda_use_at_module_level_is_unsafe():
    src = textwrap.dedent("""\
        import json

        f = lambda x: json.dumps(x)
    """)
    out, res = optimize_source(src, ["json"])
    assert not res.changed


def test_reexport_gets_pep562_shim():
    src = textwrap.dedent("""\
        from json import dumps

        __all__ = ["dumps"]
    """)
    out, res = optimize_source(src, ["json"])
    assert res.changed
    assert "dumps" in res.shimmed
    assert "__getattr__" in out
    # The shim serves the attribute on external access.
    code = (
        "import types, sys\n"
        "mod = types.ModuleType('fakemod')\n"
        f"exec({out!r}, mod.__dict__)\n"
        "sys.modules['fakemod'] = mod\n"
        "print(mod.dumps({'a': 1}))\n"
    )
    assert '"a": 1' in run_snippet(code)


def test_function_local_rebind_excluded():
    src = textwrap.dedent("""\
        import json

        def uses(x):
            return json.dumps(x)

        def rebinds():
            json = "shadow"
            return json
    """)
    out, res = optimize_source(src, ["json"])
    assert res.changed
    # import inserted only in `uses` (one indented insertion; the other
    # match is the commented-out global line)
    inserted = [l for l in out.splitlines()
                if l.startswith("    import json")]
    assert len(inserted) == 1
    stdout = run_snippet(out + "\nprint(uses(1)); print(rebinds())\n")
    assert "shadow" in stdout


def test_docstring_preserved_insertion_after():
    src = textwrap.dedent('''\
        import json

        def handler(event):
            """Doc."""
            return json.dumps(event)
    ''')
    out, res = optimize_source(src, ["json"])
    assert res.changed
    lines = out.splitlines()
    doc_idx = next(i for i, l in enumerate(lines) if '"""Doc."""' in l)
    assert "import json" in lines[doc_idx + 1]
    assert "{}" in run_snippet(out + "\nprint(handler({}))\n")


def test_decorator_use_is_module_level_and_unsafe():
    src = textwrap.dedent("""\
        import functools

        @functools.cache
        def handler():
            return 1
    """)
    out, res = optimize_source(src, ["functools"])
    assert not res.changed  # decorator evaluated at import time


def test_star_import_never_deferred():
    src = "from json import *\n\ndef handler(x):\n    return dumps(x)\n"
    out, res = optimize_source(src, ["json"])
    assert not res.changed


def test_untargeted_imports_untouched():
    src = "import json\n\ndef handler(x):\n    return json.dumps(x)\n"
    out, res = optimize_source(src, ["csv"])
    assert not res.changed and out == src


def test_optimize_file_roundtrip(tmp_path):
    p = tmp_path / "mod.py"
    src = "import json\n\ndef f(x):\n    return json.dumps(x)\n"
    p.write_text(src)
    res = optimize_file(str(p), ["json"])
    assert res.changed
    assert (tmp_path / "mod.py.orig").exists()
    assert COMMENT_TAG in p.read_text()
    assert restore_file(str(p))
    assert p.read_text() == src


def test_relative_import_in_package_init():
    src = textwrap.dedent("""\
        from . import drawing

        def plot(g):
            return drawing.render(g)
    """)
    out, res = optimize_source(src, ["mylib.drawing"],
                               module_name="mylib", is_package=True)
    assert res.changed
    # resolved to an absolute deferred import of the submodule
    assert "import mylib.drawing as drawing  # SLIMSTART" in out


def test_nested_function_gets_import_at_outermost_user():
    src = textwrap.dedent("""\
        import json

        def outer():
            def inner(x):
                return json.dumps(x)
            return inner(1)
    """)
    out, res = optimize_source(src, ["json"])
    assert res.changed
    assert run_snippet(out + "\nprint(outer())\n").strip() == "1"
