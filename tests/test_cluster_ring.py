"""Property-based tests for the cluster's rendezvous-hashing ring and
placement planner.

The churn bounds here are the cluster's rebalance contract (see
docs/cluster.md): rendezvous hashing moves *exactly* the departed
node's apps on leave, and on join only *onto* the new node (~K/N of K
apps in expectation).  Like the other property suites, hypothesis is
optional — a CI image without it skips the sweeps instead of erroring
at collection."""

import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image without hypothesis: skip sweeps only
    st = None

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            return skipper
        return deco

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.cluster import (ConsistentHashRing, hot_set_affinity,
                           plan_placement)

_NODES = st.integers(min_value=2, max_value=9)
_APPS = st.integers(min_value=1, max_value=60)
_SEED = st.integers(min_value=0, max_value=2**31)


def _ring(n_nodes: int, seed: int) -> ConsistentHashRing:
    return ConsistentHashRing((f"n{i}" for i in range(n_nodes)),
                              seed=seed)


def _apps(n: int) -> list:
    return [f"app{i:03d}" for i in range(n)]


# ---------------------------------------------------------------------------
# determinism: placement is a pure function of (seed, nodes, apps)
# ---------------------------------------------------------------------------

@given(n_nodes=_NODES, n_apps=_APPS, seed=_SEED)
@settings(max_examples=40, deadline=None)
def test_placement_is_deterministic(n_nodes, n_apps, seed):
    apps = _apps(n_apps)
    a = _ring(n_nodes, seed).place_all(apps)
    b = _ring(n_nodes, seed).place_all(apps)
    assert a == b
    # and sha256-based, so independent of process hash randomization:
    # every app maps into the node set
    assert set(a.values()) <= {f"n{i}" for i in range(n_nodes)}


@given(n_nodes=_NODES, n_apps=_APPS, seed=_SEED)
@settings(max_examples=25, deadline=None)
def test_sharing_plan_is_deterministic(n_nodes, n_apps, seed):
    apps = _apps(n_apps)
    hot_sets = {a: ["libc", f"fam{i % 3}", f"priv_{a}"]
                for i, a in enumerate(apps)}
    one = plan_placement(apps, _ring(n_nodes, seed),
                         strategy="sharing", hot_sets=hot_sets,
                         seed=seed)
    two = plan_placement(apps, _ring(n_nodes, seed),
                         strategy="sharing", hot_sets=hot_sets,
                         seed=seed)
    assert one == two


# ---------------------------------------------------------------------------
# churn bounds: the rendezvous-hashing contract
# ---------------------------------------------------------------------------

@given(n_nodes=_NODES, n_apps=_APPS, seed=_SEED)
@settings(max_examples=40, deadline=None)
def test_leave_moves_exactly_the_departed_nodes_apps(n_nodes, n_apps,
                                                     seed):
    apps = _apps(n_apps)
    ring = _ring(n_nodes, seed)
    before = ring.place_all(apps)
    victim = ring.nodes[seed % n_nodes]
    ring.remove(victim)
    after = ring.place_all(apps)
    moved = {a for a in apps if before[a] != after[a]}
    # every app that lived on the victim moved; nobody else did
    assert moved == {a for a in apps if before[a] == victim}
    assert victim not in set(after.values())


@given(n_nodes=_NODES, n_apps=_APPS, seed=_SEED)
@settings(max_examples=40, deadline=None)
def test_join_moves_only_onto_the_new_node(n_nodes, n_apps, seed):
    apps = _apps(n_apps)
    ring = _ring(n_nodes, seed)
    before = ring.place_all(apps)
    ring.add("newcomer")
    after = ring.place_all(apps)
    moved = {a for a in apps if before[a] != after[a]}
    # the only legal destination for a moved app is the new node
    assert all(after[a] == "newcomer" for a in moved)
    # un-moved apps keep their exact owner (stability)
    assert all(after[a] == before[a] for a in set(apps) - moved)


@given(seed=_SEED)
@settings(max_examples=15, deadline=None)
def test_join_churn_is_near_k_over_n(seed):
    """With K apps on N equal nodes, a join should move about K/(N+1)
    apps.  A generous x3 bound stays far from flakiness while still
    catching a broken hash (which moves ~K*(N/(N+1)) of them)."""
    n_nodes, n_apps = 5, 200
    apps = _apps(n_apps)
    ring = _ring(n_nodes, seed)
    before = ring.place_all(apps)
    ring.add("newcomer")
    after = ring.place_all(apps)
    moved = sum(1 for a in apps if before[a] != after[a])
    expected = n_apps / (n_nodes + 1)
    assert moved <= 3 * expected


@given(n_apps=st.integers(min_value=1, max_value=40), seed=_SEED)
@settings(max_examples=25, deadline=None)
def test_weighted_node_attracts_more_apps(n_apps, seed):
    """A node with weight 0 is illegal; a heavier node owns at least
    as many apps as the same node at weight 1 (monotonicity of the
    weighted-HRW transform)."""
    apps = _apps(max(n_apps, 20))
    light = ConsistentHashRing(["a", "b"], seed=seed)
    heavy = ConsistentHashRing(["a", "b"], seed=seed,
                               weights={"a": 8.0, "b": 1.0})
    light_count = sum(1 for app in apps
                      if light.place(app) == "a")
    heavy_count = sum(1 for app in apps
                      if heavy.place(app) == "a")
    assert heavy_count >= light_count
    with pytest.raises(ValueError):
        ConsistentHashRing(["a"], weights={"a": 0.0})


# ---------------------------------------------------------------------------
# sharing planner: grouping and balance
# ---------------------------------------------------------------------------

@given(seed=_SEED, n_families=st.integers(min_value=2, max_value=4))
@settings(max_examples=20, deadline=None)
def test_sharing_groups_families_and_balances_load(seed, n_families):
    """Families-of-apps with a shared fat module end up co-located,
    and the default load cap keeps nodes balanced."""
    n_apps = 4 * n_families
    apps = [f"app{i:02d}" for i in range(n_apps)]
    hot_sets = {a: ["runtime", f"family{i % n_families}", f"priv_{a}"]
                for i, a in enumerate(apps)}
    ring = _ring(n_families, seed)
    placement = plan_placement(apps, ring, strategy="sharing",
                               hot_sets=hot_sets, seed=seed)
    by_node: dict = {}
    for app, node in placement.items():
        by_node.setdefault(node, []).append(app)
    cap = math.ceil(n_apps / n_families)
    assert all(len(v) <= cap for v in by_node.values())
    # every family is fully co-located: one node hosts all 4 siblings
    for fam in range(n_families):
        owners = {placement[a] for i, a in enumerate(apps)
                  if i % n_families == fam}
        assert len(owners) == 1


def test_affinity_scores_overlap():
    assert hot_set_affinity([], [["x"]]) == 0.0
    assert hot_set_affinity(["a"], []) == 0.0
    assert hot_set_affinity(["a", "b"], [["c"], ["d"]]) == 0.0
    full = hot_set_affinity(["a", "b"], [["a"], ["b"]])
    assert full == pytest.approx(1.0)
    half = hot_set_affinity(["a", "b"], [["a"], ["c"]])
    assert half == pytest.approx(0.5)


def test_place_among_and_empty_ring_errors():
    ring = _ring(3, 0)
    assert ring.place("x", among=["n1"]) == "n1"
    with pytest.raises(ValueError):
        ring.place("x", among=["ghost"])
    with pytest.raises(ValueError):
        plan_placement(["x"], ConsistentHashRing())
    with pytest.raises(ValueError):
        plan_placement(["x"], ring, strategy="nope")
