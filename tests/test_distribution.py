"""Distribution-layer tests: sharding rules, cache specs, input specs,
and the loop-aware HLO collective parser used by the roofline."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.distributed.sharding import (
    DEFAULT_RULES, cache_pspecs, opt_pspecs, param_pspecs, resolve_axes,
)
from repro.models import SHAPES, applicable_shapes, input_specs
from repro.models.model import init_cache, model_template
from repro.models.layers import ParamSpec


def _mesh22():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_axes_divisibility_fallback():
    mesh = jax.make_mesh((1,), ("model",))
    # dims that don't divide the mesh axis fall back to replication
    spec = resolve_axes((49155, 1024), ("vocab", "embed"), DEFAULT_RULES,
                        mesh)
    assert spec == P()  # model axis size 1 -> nothing to shard


def test_param_pspecs_structure_matches_params():
    for arch in ["gemma3-27b", "whisper-large-v3", "olmoe-1b-7b"]:
        cfg = get_config(arch)
        mesh = _mesh22()
        specs = param_pspecs(cfg, mesh)
        tmpl = model_template(cfg)
        t1 = jax.tree.structure(
            specs, is_leaf=lambda x: isinstance(x, P))
        t2 = jax.tree.structure(
            tmpl, is_leaf=lambda x: isinstance(x, ParamSpec))
        assert t1 == t2, arch


def test_cache_pspecs_structure_matches_cache():
    for arch in ["qwen2.5-32b", "recurrentgemma-2b", "xlstm-350m",
                 "whisper-large-v3"]:
        cfg = get_config(arch)
        mesh = _mesh22()
        shapes = jax.eval_shape(lambda c=cfg: init_cache(c, 4, 64))
        specs = cache_pspecs(cfg, mesh, 4, 64)
        t1 = jax.tree.structure(specs,
                                is_leaf=lambda x: isinstance(x, P))
        t2 = jax.tree.structure(shapes)
        assert t1 == t2, arch
        # every spec has rank <= leaf rank
        for s, leaf in zip(
                jax.tree.leaves(specs,
                                is_leaf=lambda x: isinstance(x, P)),
                jax.tree.leaves(shapes)):
            assert len(s) <= len(leaf.shape)


def test_opt_pspecs_zero1_adds_data_axis():
    cfg = get_config("granite-8b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    o = opt_pspecs(cfg, mesh)
    # same tree structure as params for master/mu/nu
    p = param_pspecs(cfg, mesh)
    assert jax.tree.structure(
        o.master, is_leaf=lambda x: isinstance(x, P)) == \
        jax.tree.structure(p, is_leaf=lambda x: isinstance(x, P))


def test_input_specs_all_cells_build():
    """Every assigned (arch x applicable shape) cell has well-defined
    ShapeDtypeStruct inputs — 32 cells, no allocation."""
    n = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in applicable_shapes(cfg):
            spec = input_specs(cfg, SHAPES[shape_name])
            leaves = jax.tree.leaves(spec)
            assert leaves and all(
                isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
            if SHAPES[shape_name].kind == "decode":
                assert "caches" in spec and "pos" in spec
            n += 1
    assert n == 32  # 3 shapes x 10 archs + long_500k x 2 subquadratic


def test_long500k_applicability():
    subq = [a for a in ARCH_IDS
            if "long_500k" in applicable_shapes(get_config(a))]
    assert sorted(subq) == ["recurrentgemma-2b", "xlstm-350m"]


# ---------------------------------------------------------------- parser
HLO_SAMPLE = """
HloModule test

%wide.cond (p: (s32[], bf16[4,8])) -> pred[] {
  %iter = s32[] get-tuple-element(%p), index=0
  ROOT %cmp = pred[] compare(%iter, s32[] constant(21)), direction=LT
}

%wide.body (p: (s32[], bf16[4,8])) -> (s32[], bf16[4,8]) {
  %x = bf16[4,8]{1,0} get-tuple-element(%p), index=1
  %ag = bf16[8,8]{1,0} all-gather(bf16[4,8]{1,0} %x), dimensions={0}
  %ar = bf16[4,8]{1,0} all-reduce(bf16[4,8]{1,0} %x), to_apply=%sum
  ROOT %t = (s32[], bf16[4,8]) tuple(%i, %ar)
}

ENTRY %main.1 (a: bf16[4,8]) -> bf16[4,8] {
  %w = (s32[], bf16[4,8]) while(%init), condition=%wide.cond, body=%wide.body
  %top = bf16[4,8]{1,0} all-reduce(bf16[4,8]{1,0} %a), to_apply=%sum
  ROOT %r = bf16[4,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collective_parser_loop_aware():
    from repro.launch.dryrun import collective_bytes
    out = collective_bytes(HLO_SAMPLE)
    # bytes = collective *result* shapes (per-device traffic proxy):
    # in-loop x21: all-gather result (8,8) bf16 + all-reduce result (4,8)
    # top-level: one all-reduce result (4,8)
    assert out["all-gather"] == 21 * 8 * 8 * 2
    assert out["all-reduce"] == 21 * 4 * 8 * 2 + 4 * 8 * 2
    assert out["total"] == out["all-gather"] + out["all-reduce"]
