"""`python -m repro` CLI smoke tests.

Fast tier: in-process ``repro.cli.main`` calls covering
profile → report → optimize → restore and the ci-check gate on a
generated benchsuite app (tiny profiling budgets).  Tests that spawn
the CLI itself (or a zygote) as a subprocess are marked ``slow`` per
the ROADMAP tiering rule.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.api import load_report, peek, save_report, save_trace
from repro.benchsuite.genlibs import build_suite
from repro.cli import main
from repro.pool.trace import Request, Trace

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


@pytest.fixture(scope="module")
def root(tmp_path_factory):
    """An isolated suite root so CLI runs don't clobber .benchsuite."""
    return build_suite(str(tmp_path_factory.mktemp("cli-suite")))


def _deployment_files(deploy_dir):
    out = {}
    for dirpath, _dirs, files in os.walk(deploy_dir):
        for fn in files:
            if fn.endswith(".py"):
                p = os.path.join(dirpath, fn)
                out[os.path.relpath(p, deploy_dir)] = open(p).read()
    return out


# ---------------------------------------------------------------------------
# fast tier: in-process CLI
# ---------------------------------------------------------------------------

def test_profile_report_optimize_restore(root, tmp_path, capsys):
    out = str(tmp_path / "echo.json")
    rc = main(["profile", "echo", "--root", root, "--instances", "1",
               "--invocations", "10", "--out", out])
    assert rc == 0
    assert peek(out) == ("optimization_report", 2)
    rep = load_report(out)
    assert rep.application == "echo"

    rc = main(["report", out])
    assert rc == 0
    assert "SLIMSTART Summary" in capsys.readouterr().out

    rc = main(["report", out, "--json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["schema_version"] == 2

    rc = main(["optimize", "echo", "--root", root, "--report", out])
    assert rc == 0
    summary = json.loads(capsys.readouterr().out)
    assert os.path.isdir(summary["variant_dir"])

    rc = main(["restore", "echo", "--root", root])
    assert rc == 0


def test_static_optimize_restore_roundtrip(root, capsys):
    """optimize --static rewrites files; restore brings back the exact
    original sources (the .orig round trip, deployment-wide)."""
    app_dir = os.path.join(root, "apps", "graph_bfs")
    baseline = _deployment_files(app_dir)
    rc = main(["optimize", "graph_bfs", "--root", root, "--static"])
    assert rc == 0
    summary = json.loads(capsys.readouterr().out)
    assert summary["deferred"] >= 1
    variant = summary["variant_dir"]
    changed = _deployment_files(variant)
    assert changed != baseline  # the rewrite really happened

    rc = main(["restore", variant])
    assert rc == 0
    restored = json.loads(capsys.readouterr().out)
    assert restored["restored"] >= 1
    assert _deployment_files(variant) == baseline  # exact round trip


def test_ci_check_pass_then_drift(root, tmp_path, capsys):
    deployed = str(tmp_path / "deployed.json")
    rc = main(["profile", "echo", "--root", root, "--instances", "1",
               "--invocations", "10", "--out", deployed, "--json"])
    assert rc == 0
    capsys.readouterr()

    rc = main(["ci-check", "echo", "--root", root, "--deployed",
               deployed, "--instances", "1", "--invocations", "10"])
    assert rc == 0
    assert "PASS" in capsys.readouterr().out

    # simulate workload drift: the deployed report defers a package the
    # fresh profile won't -> the CI gate must fail with exit code 1
    rep = load_report(deployed)
    rep.defer_targets = ["fakelib_pandas"]
    save_report(rep, deployed)
    rc = main(["ci-check", "echo", "--root", root, "--deployed",
               deployed, "--instances", "1", "--invocations", "10"])
    assert rc == 1
    assert "no_longer_deferred" in capsys.readouterr().out

    # --retries re-profiles a mismatch; persistent drift still fails
    rc = main(["ci-check", "echo", "--root", root, "--deployed",
               deployed, "--instances", "1", "--invocations", "10",
               "--retries", "1"])
    assert rc == 1
    assert '"attempt": 2' in capsys.readouterr().out


def test_fleet_replay_sim_and_trace_artifact(tmp_path, capsys):
    rc = main(["fleet", "replay", "--minutes", "5", "--policy", "idle",
               "--apps", "a,b"])
    assert rc == 0
    assert '"cold_starts"' in capsys.readouterr().out

    trace = Trace("unit", [Request(0.0, "appx", None),
                           Request(2.0, "appx", None)], duration_s=5.0)
    tpath = save_trace(trace, str(tmp_path / "trace.json"))
    rc = main(["fleet", "replay", "--trace", tpath, "--policy", "fixed"])
    assert rc == 0
    out = capsys.readouterr().out
    assert '"requests": 2' in out and "appx" in out


def test_cli_error_exit_codes(root, tmp_path, capsys):
    bad = tmp_path / "trunc.json"
    bad.write_text('{"kind": "optimization_report", ')
    assert main(["report", str(bad)]) == 2
    assert main(["restore", "no_such_app", "--root", root]) == 2
    assert main(["pool", "serve"]) == 2
    # optimize without a saved report: clear failure, not a KeyError
    assert main(["optimize", "graph_mst", "--root", root]) == 2
    capsys.readouterr()


# ---------------------------------------------------------------------------
# slow tier: real subprocesses (zygote / module entry point)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_pool_serve_forks_instances(root, capsys):
    rc = main(["pool", "serve", "echo", "--root", root,
               "--requests", "2"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "zygote ready" in out
    assert "mean pool-start init" in out


@pytest.mark.slow
def test_module_entrypoint_subprocess(root, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = str(tmp_path / "echo.json")
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "profile", "echo", "--root",
         root, "--instances", "1", "--invocations", "5", "--out", out,
         "--json"],
        capture_output=True, text=True, env=env, timeout=180)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert peek(out) == ("optimization_report", 2)


def test_fleet_replay_out_writes_summary_artifact(tmp_path, capsys):
    from repro.api import load_fleet_summary
    out = str(tmp_path / "summary.json")
    rc = main(["fleet", "replay", "--minutes", "5", "--policy", "idle",
               "--apps", "a,b", "--queue-depth", "4",
               "--max-concurrency", "1", "--out", out])
    assert rc == 0
    capsys.readouterr()
    data = load_fleet_summary(out)
    assert data["source"] == "replay-sim"
    assert data["queue"]["depth"] == 4
    assert data["requests"] == (data["served"] + data["sheds"]
                                + data["flushed"])


def test_fleet_serve_sim_trace_mode(tmp_path, capsys):
    from repro.api import load_fleet_summary
    out = str(tmp_path / "serve.json")
    rc = main(["fleet", "serve", "--sim", "--apps", "a,b",
               "--minutes", "3", "--peak-rpm", "30",
               "--queue-depth", "8", "--summary-out", out])
    assert rc == 0
    assert '"source": "serve-sim"' in capsys.readouterr().out
    data = load_fleet_summary(out)
    assert data["requests"] > 0
    assert data["requests"] == (data["served"] + data["sheds"]
                                + data["flushed"])


def test_fleet_serve_stdin_needs_apps(capsys):
    rc = main(["fleet", "serve", "--sim", "--stdin", "--apps", ""])
    assert rc == 2
    capsys.readouterr()


def test_docs_generate_and_check(tmp_path, capsys):
    out = str(tmp_path / "cli.md")
    assert main(["docs", "--out", out]) == 0
    content = open(out).read()
    assert "GENERATED FILE" in content
    assert "fleet serve" in content and "--queue-depth" in content
    assert main(["docs", "--check", "--out", out]) == 0
    # drift: edited file must fail the check
    open(out, "a").write("\nstale edit\n")
    assert main(["docs", "--check", "--out", out]) == 1
    # missing file must fail the check too
    assert main(["docs", "--check",
                 "--out", str(tmp_path / "nope.md")]) == 1
    capsys.readouterr()


def test_committed_cli_reference_is_current(capsys):
    """The repo's own docs/cli.md must match the argparse tree — the
    same gate CI runs."""
    repo_root = os.path.dirname(SRC)
    cwd = os.getcwd()
    os.chdir(repo_root)
    try:
        assert main(["docs", "--check"]) == 0
    finally:
        os.chdir(cwd)
    capsys.readouterr()
