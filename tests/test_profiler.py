"""Unit tests: import timer (Eq. 1-3), sampler, utilization (Eq. 4),
adaptive monitor (Eq. 5-7), async collector."""

import os
import sys
import textwrap
import time

import pytest

from repro.core.profiler.cct import CCT, Frame
from repro.core.profiler.collector import AsyncCollector, read_shards
from repro.core.profiler.import_timer import ImportTimer
from repro.core.profiler.sampler import CallPathSampler, SamplerConfig
from repro.core.profiler.utilization import (
    AnalyzerConfig,
    ModuleMapper,
    UtilizationAnalyzer,
)
from repro.core.adaptive.monitor import MonitorConfig, WorkloadMonitor


# ---------------------------------------------------------------- fixtures

def make_fake_lib(root, name="fakelib", spin_ms=5):
    """Create a tiny package with measurable import-time work."""
    pkg = root / name
    (pkg / "sub").mkdir(parents=True)
    spin = textwrap.dedent(f"""\
        import time as _t
        _end = _t.perf_counter() + {spin_ms / 1000.0}
        while _t.perf_counter() < _end:
            pass
    """)
    (pkg / "__init__.py").write_text(spin + f"from {name} import core\n"
                                     + f"from {name} import sub\n")
    (pkg / "core.py").write_text(spin + "def work(n):\n"
                                 "    s = 0\n"
                                 "    for i in range(n):\n"
                                 "        s += i * i\n"
                                 "    return s\n")
    (pkg / "sub" / "__init__.py").write_text(spin + "def unused():\n"
                                             "    return 1\n")
    return str(root)


@pytest.fixture
def fake_lib(tmp_path):
    root = make_fake_lib(tmp_path)
    sys.path.insert(0, root)
    yield root
    sys.path.remove(root)
    for mod in [m for m in sys.modules if m.startswith("fakelib")]:
        del sys.modules[mod]


# ------------------------------------------------------------ import timer

def test_import_timer_hierarchy(fake_lib):
    with ImportTimer(only_prefixes=("fakelib",)) as timer:
        import fakelib  # noqa: F401
    # All three modules recorded
    assert {"fakelib", "fakelib.core", "fakelib.sub"} <= set(timer.records)
    # Eq.1: total == sum of self times, each ≥ spin time
    total = timer.total_initialization_s()
    assert total >= 3 * 0.004
    # Eq.2: library time aggregates all modules
    lib_times = timer.library_times()
    assert abs(lib_times["fakelib"] - total) < 1e-9
    # Eq.3: package prefixes
    pkg = timer.package_times()
    assert pkg["fakelib.sub"] >= 0.004
    assert pkg["fakelib"] == pytest.approx(total)
    # parent chain: fakelib.core was imported by fakelib's __init__
    rec = timer.records["fakelib.core"]
    assert rec.parent == "fakelib"
    chain = timer.import_chain("fakelib.core")
    assert [r.name for r in chain] == ["fakelib", "fakelib.core"]
    # self-time excludes children: fakelib's self ~spin, not 3*spin
    assert timer.records["fakelib"].self_s < 2.5 * 0.005 + 0.01


def test_import_timer_untracked_prefix(fake_lib, tmp_path):
    with ImportTimer(only_prefixes=("otherlib",)) as timer:
        import fakelib  # noqa: F401
    assert "fakelib" not in timer.records


def test_import_timer_serialization(fake_lib):
    with ImportTimer(only_prefixes=("fakelib",)) as timer:
        import fakelib  # noqa: F401
    back = ImportTimer.from_dict(timer.to_dict())
    assert back.total_initialization_s() == pytest.approx(
        timer.total_initialization_s())


# ---------------------------------------------------------------- sampler

def busy(duration_s):
    end = time.process_time() + duration_s
    x = 0
    while time.process_time() < end:
        x += 1
    return x


def test_sampler_captures_busy_function():
    sampler = CallPathSampler(SamplerConfig(interval_s=0.005, timer="prof"))
    with sampler:
        busy(0.25)
    cct = sampler.build_cct()
    assert cct.total_samples >= 10
    agg = cct.leaf_self_samples()
    assert any(fr.funcname == "busy" for fr in agg), agg.keys()


def test_sampler_stop_stops_sampling():
    sampler = CallPathSampler(SamplerConfig(interval_s=0.005))
    with sampler:
        busy(0.05)
    n = len(sampler.drain())
    busy(0.1)
    assert len(sampler.drain()) == 0 or len(sampler.drain()) < max(n, 2)


# -------------------------------------------------------------- utilization

def test_utilization_end_to_end(fake_lib, tmp_path):
    with ImportTimer(only_prefixes=("fakelib",)) as timer:
        import fakelib  # noqa: F401
    sampler = CallPathSampler(SamplerConfig(interval_s=0.002, timer="prof"))
    t0 = time.perf_counter()
    with sampler:
        fakelib.core.work(2_000_000)
    e2e = time.perf_counter() - t0 + timer.total_initialization_s()
    cct = sampler.build_cct()
    mapper = ModuleMapper((fake_lib,))
    # app_gate=0.01: the init/e2e wall-clock ratio is load-sensitive on a
    # shared CPU; the mechanism under test (CCT attribution) is not
    analyzer = UtilizationAnalyzer(
        timer, cct, mapper, e2e_s=e2e,
        config=AnalyzerConfig(min_init_share=0.001, app_gate=0.01))
    assert analyzer.qualifies()
    stats = analyzer.stats()
    assert stats["fakelib.core"].runtime_samples > 0
    assert stats["fakelib.sub"].runtime_samples == 0
    findings = analyzer.findings()
    flagged = {f.package for f in findings}
    assert "fakelib.sub" in flagged
    sub = next(f for f in findings if f.package == "fakelib.sub")
    assert sub.kind == "unused"
    # core is heavily used => not flagged
    assert "fakelib.core" not in flagged


def test_module_mapper(tmp_path):
    mapper = ModuleMapper((str(tmp_path),))
    f = str(tmp_path / "nltk" / "sem" / "__init__.py")
    assert mapper.module_of(f) == "nltk.sem"
    assert mapper.library_of(f) == "nltk"
    f2 = str(tmp_path / "nltk" / "tokenize.py")
    assert mapper.module_of(f2) == "nltk.tokenize"
    assert mapper.module_of("/elsewhere/x.py") is None


# ------------------------------------------------------------------ monitor

def test_monitor_triggers_on_shift():
    t = [0.0]
    mon = WorkloadMonitor(MonitorConfig(window_s=10.0, epsilon=0.2),
                          clock=lambda: t[0])
    # window 1: all traffic to A
    for _ in range(100):
        mon.record("A")
    t[0] = 11.0
    mon.record("A")  # closes window 1 (baseline, no trigger)
    for _ in range(99):
        mon.record("A")
    t[0] = 22.0
    mon.record("B")  # closes window 2: still ~all A => no trigger
    for _ in range(99):
        mon.record("B")
    t[0] = 33.0
    stats = mon.record("B")  # closes window 3: A->B shift => trigger
    assert stats is not None
    assert stats.aggregate_change > 1.5  # ~|1-0| + |0-1| ≈ 2
    assert stats.triggered
    assert mon.triggers == 1


def test_monitor_stable_workload_never_triggers():
    t = [0.0]
    mon = WorkloadMonitor(MonitorConfig(window_s=1.0, epsilon=0.05),
                          clock=lambda: t[0])
    for w in range(10):
        for _ in range(50):
            mon.record("A")
        for _ in range(50):
            mon.record("B")
        t[0] += 1.01
        mon.record("A")
    assert mon.triggers == 0


# ---------------------------------------------------------------- collector

def test_collector_batches_and_persists(tmp_path):
    sink = str(tmp_path / "sink")
    with AsyncCollector(sink, batch_size=10, flush_interval_s=0.05) as col:
        for i in range(25):
            col.put({"i": i})
    records = read_shards(sink)
    assert len(records) == 25
    assert sorted(r["i"] for r in records) == list(range(25))
    assert col.written == 25 and col.dropped == 0
