"""Property-based tests for keep-alive policies and fleet arbitration.

Like ``test_kernels``, hypothesis is optional: a CI image without it
skips the sweeps instead of erroring at collection."""

import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image without hypothesis: skip sweeps only
    st = None

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            return skipper
        return deco

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.core.adaptive import DriftConfig, DriftDetector
from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import LibraryStats
from repro.pool import (
    AppProfile,
    FleetManager,
    HistogramPolicy,
    IdleTimeoutPolicy,
    ProfileGuidedPolicy,
    Request,
    Trace,
)


def _report(app: str, e2e_s: float, init_s: float) -> OptimizationReport:
    stat = LibraryStats(name="libhot", utilization=0.9, init_s=init_s,
                        init_share=init_s / max(e2e_s, 1e-9),
                        runtime_samples=50, file="<x>")
    return OptimizationReport(application=app, e2e_s=e2e_s,
                              total_init_s=init_s, qualifies=True,
                              stats=[stat], defer_targets=[])


# ---------------------------------------------------------------------------
# HistogramPolicy: keep-alive stays within its configured bounds
# ---------------------------------------------------------------------------

@given(
    arrivals=st.lists(
        st.floats(min_value=0.0, max_value=1e5, allow_nan=False,
                  allow_infinity=False),
        min_size=0, max_size=120),
    percentile=st.floats(min_value=0.0, max_value=1.0),
)
@settings(max_examples=80, deadline=None)
def test_histogram_keep_alive_within_percentile_bounds(arrivals,
                                                       percentile):
    floor_s, cap_s = 10.0, 3600.0
    pol = HistogramPolicy(percentile=percentile, default_s=600.0,
                          floor_s=floor_s, cap_s=cap_s, min_samples=8)
    for t in sorted(arrivals):
        pol.observe_arrival("app", t)
    ka = pol.keep_alive_s("app")
    # always inside the configured clamp (default_s also lies within it)
    assert floor_s <= ka <= cap_s
    iats = pol._iats.get("app", [])
    if len(iats) >= pol.min_samples:
        # a learned value can never exceed the clamped largest gap seen
        assert ka <= max(floor_s, min(cap_s, max(iats)))
        # ...and never undershoots the clamped smallest gap
        assert ka >= min(cap_s, max(floor_s, min(iats)))


@given(arrivals=st.lists(
    st.floats(min_value=0.0, max_value=1e4, allow_nan=False,
              allow_infinity=False), min_size=16, max_size=60))
@settings(max_examples=50, deadline=None)
def test_histogram_percentile_monotone_in_percentile(arrivals):
    ts = sorted(arrivals)
    lo = HistogramPolicy(percentile=0.5, min_samples=8)
    hi = HistogramPolicy(percentile=0.99, min_samples=8)
    for t in ts:
        lo.observe_arrival("a", t)
        hi.observe_arrival("a", t)
    assert lo.keep_alive_s("a") <= hi.keep_alive_s("a")


# ---------------------------------------------------------------------------
# ProfileGuidedPolicy: prewarm never exceeds the budget
# ---------------------------------------------------------------------------

@given(
    e2e_s=st.floats(min_value=1e-4, max_value=100.0),
    init_s=st.floats(min_value=0.0, max_value=50.0),
    rate=st.floats(min_value=0.0, max_value=1e4),
    max_prewarm=st.integers(min_value=0, max_value=32),
)
@settings(max_examples=120, deadline=None)
def test_profile_guided_prewarm_never_exceeds_budget(e2e_s, init_s, rate,
                                                     max_prewarm):
    pol = ProfileGuidedPolicy(rate_hint_per_s=1.0, max_prewarm=max_prewarm)
    pol.add_report(_report("app", e2e_s, min(init_s, e2e_s)))
    assert 0 <= pol.prewarm("app") <= max_prewarm
    # any sequence of observed rates keeps the recommendation in budget
    pol.observe_rate("app", rate)
    pol.observe_rate("app", rate * 10.0)
    assert 0 <= pol.prewarm("app") <= max_prewarm
    assert pol.prewarm("unknown") == 0
    ka = pol.keep_alive_s("app")
    assert pol.floor_s <= ka <= pol.cap_s and math.isfinite(ka)


# ---------------------------------------------------------------------------
# FleetManager: retention never violates the shared budget
# ---------------------------------------------------------------------------

_PROFILES = {
    "a": AppProfile(app="a", cold_init_ms=150.0, invoke_ms=10.0,
                    warm_init_ms=5.0, rss_mb=100.0, zygote_rss_mb=80.0),
    "b": AppProfile(app="b", cold_init_ms=60.0, invoke_ms=5.0,
                    warm_init_ms=3.0, rss_mb=50.0, zygote_rss_mb=40.0),
    "c": AppProfile(app="c", cold_init_ms=400.0, invoke_ms=25.0,
                    warm_init_ms=10.0, rss_mb=300.0, zygote_rss_mb=250.0),
}


@given(
    arrivals=st.lists(
        st.tuples(st.floats(min_value=0.0, max_value=600.0,
                            allow_nan=False, allow_infinity=False),
                  st.sampled_from(sorted(_PROFILES))),
        min_size=1, max_size=80),
    budget_mb=st.sampled_from([60.0, 150.0, 500.0, 2000.0]),
    policy_kind=st.sampled_from(["idle", "hist", "pg"]),
)
@settings(max_examples=60, deadline=None)
def test_fleet_retention_respects_budget_for_any_arrivals(arrivals,
                                                          budget_mb,
                                                          policy_kind):
    reqs = [Request(t, app) for t, app in sorted(arrivals,
                                                 key=lambda x: x[0])]
    trace = Trace("prop", reqs, 601.0)
    if policy_kind == "idle":
        policy = IdleTimeoutPolicy(timeout_s=120.0)
    elif policy_kind == "hist":
        policy = HistogramPolicy(min_samples=4)
    else:
        policy = ProfileGuidedPolicy(rate_hint_per_s=0.5)
        for app in _PROFILES:
            policy.add_report(_report(app, 0.2, 0.15))
    fleet = FleetManager(_PROFILES, policy, budget_mb=budget_mb)
    s = fleet.replay(trace)
    # the arbiter never leaves retained state above the shared budget
    assert s.budget_violations == 0
    assert s.n_requests == len(reqs)
    assert s.cold_starts + s.pool_starts <= s.n_requests + \
        s.prewarm_spawns
    assert all(lat > 0 for rep in s.per_app.values()
               for lat in rep.latencies_ms)
    assert s.memory_mb_s >= 0.0
    assert s.evictions >= 0 and s.prewarm_spawns >= 0


# ---------------------------------------------------------------------------
# Two-tier accounting: shared/private split invariants (PR 5)
# ---------------------------------------------------------------------------

@given(
    zygote_rss=st.lists(st.floats(min_value=20.0, max_value=500.0,
                                  allow_nan=False, allow_infinity=False),
                        min_size=1, max_size=8),
    base_frac=st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
    private_frac=st.floats(min_value=0.0, max_value=1.0,
                           allow_nan=False),
)
@settings(max_examples=80, deadline=None)
def test_shared_base_charges_never_exceed_one_per_app_total(
        zygote_rss, base_frac, private_frac):
    """The accounting identity behind the two-tier fleet: with the
    shared base no larger than the smallest member zygote, charging
    base-once + per-app increments can never exceed the one-zygote-
    per-app total — sharing may only reduce the fleet's bill."""
    base_mb = base_frac * min(zygote_rss)
    profiles = {
        f"app{i}": AppProfile(
            app=f"app{i}", cold_init_ms=100.0, invoke_ms=10.0,
            warm_init_ms=5.0, rss_mb=50.0, zygote_rss_mb=rss,
            # a measured private delta, when present, is at most the
            # pages above the base (CoW cannot create memory)
            zygote_private_mb=private_frac * max(rss - base_mb, 0.0))
        for i, rss in enumerate(zygote_rss)
    }
    policy = ProfileGuidedPolicy(rate_hint_per_s=0.5)
    for app in profiles:
        policy.add_report(_report(app, 0.2, 0.15))
    one = FleetManager(profiles, policy, budget_mb=1e9)
    two = FleetManager(profiles, policy, budget_mb=1e9,
                       shared_base_mb=base_mb)
    one.begin("prop")
    two.begin("prop")
    for mgr in (one, two):
        for st_ in mgr._apps.values():
            st_.zygote_up = True
    one_total = one._used_mb()
    two_total = two._used_mb()
    # sum of private deltas + base <= sum of full per-app RSS
    assert two_total <= one_total + 1e-6
    # every per-app charge is within [0, full RSS]
    for app, st_ in two._apps.items():
        charge = st_.zygote_charge_mb(base_mb)
        assert 0.0 <= charge <= st_.zygote_rss_mb() + 1e-9
    # and with no base the two accountings agree exactly
    assert two._apps.keys() == one._apps.keys()
    plain = FleetManager(profiles, policy, budget_mb=1e9,
                         shared_base_mb=0.0)
    plain.begin("prop")
    for st_ in plain._apps.values():
        st_.zygote_up = True
    assert plain._used_mb() == one_total


# ---------------------------------------------------------------------------
# DriftDetector: noise-calibrated gate invariants (adaptive loop)
# ---------------------------------------------------------------------------

import random as _random  # noqa: E402  (kept below the hypothesis shim)


def _drift_detector(window_s=10.0, **kw) -> DriftDetector:
    kw.setdefault("min_invocations", 10)
    return DriftDetector(DriftConfig(window_s=window_s, **kw))


@given(
    weights=st.lists(st.floats(min_value=0.05, max_value=1.0,
                               allow_nan=False, allow_infinity=False),
                     min_size=1, max_size=6),
    n_per_window=st.integers(min_value=20, max_value=2000),
    n_windows=st.integers(min_value=3, max_value=8),
    seed=st.integers(min_value=0, max_value=10_000),
)
@settings(max_examples=60, deadline=None)
def test_drift_detector_stationary_workload_never_fires(
        weights, n_per_window, n_windows, seed):
    """Every window draws from the SAME handler distribution; the
    multinomial sampling noise between windows must stay under the
    calibrated gate eps_eff = noise_guard * sqrt(k(1/n1 + 1/n2)), so
    the detector never declares drift on stationary traffic."""
    rng = _random.Random(seed)
    handlers = [f"h{i}" for i in range(len(weights))]
    det = _drift_detector()
    for w in range(n_windows):
        draws = rng.choices(handlers, weights=weights, k=n_per_window)
        for h in handlers:
            n = draws.count(h)
            if n:
                det.observe("app", h, n=n, t=1.0 + 10.0 * w)
    det.flush(t=1.0 + 10.0 * n_windows)
    assert det.fires == 0
    assert all(not w.fired and not w.suppressed for w in det.windows)
    # and the gate never collapses below the paper's epsilon floor
    assert all(w.eps_eff >= det.drift_config.epsilon
               for w in det.windows)


@given(
    shifts=st.lists(st.integers(min_value=0, max_value=500),
                    min_size=2, max_size=6),
    n=st.integers(min_value=500, max_value=1000),
)
@settings(max_examples=60, deadline=None)
def test_drift_score_monotone_in_mix_shift_magnitude(shifts, n):
    """Holding window sizes fixed, a larger handler-mix shift can
    never score *lower*: score(d) is nondecreasing in d (it is
    sigma|delta p| = 2d/n against a fixed eps_eff)."""
    def final_score(d: int) -> float:
        det = _drift_detector()
        det.observe("app", "h1", n=n, t=1.0)          # baseline window
        det.observe("app", "h1", n=n - d, t=11.0)      # shifted window
        if d:
            det.observe("app", "h2", n=d, t=11.0)
        det.flush(t=21.0)
        return det.windows[-1].score

    scores = [final_score(d) for d in sorted(set(shifts))]
    assert all(a <= b + 1e-12 for a, b in zip(scores, scores[1:]))


@given(
    d=st.integers(min_value=0, max_value=1000),
    guard_lo=st.floats(min_value=0.5, max_value=2.0, allow_nan=False),
    guard_hi=st.floats(min_value=2.0, max_value=8.0, allow_nan=False),
)
@settings(max_examples=60, deadline=None)
def test_drift_score_antitone_in_noise_guard(d, guard_lo, guard_hi):
    """A stricter (larger) noise guard can only shrink the mix score:
    raising the gate must never make the same shift look *more*
    drifted."""
    def score(guard: float) -> float:
        det = _drift_detector(noise_guard=guard)
        det.observe("app", "h1", n=1000, t=1.0)
        det.observe("app", "h1", n=1000 - d, t=11.0)
        if d:
            det.observe("app", "h2", n=d, t=11.0)
        det.flush(t=21.0)
        return det.windows[-1].mix_score

    assert score(guard_hi) <= score(guard_lo) + 1e-12
