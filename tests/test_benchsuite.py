"""Integration tests: the full SLIMSTART loop on the synthetic suite.

These run real subprocess cold starts and the complete
profile -> analyze -> optimize -> re-measure pipeline on a couple of
apps (kept small: few instances / invocations — the benchmarks run the
full sweep).
"""

import json
import os

import pytest

from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import (
    measure_cold_starts,
    measure_warm_overhead,
    run_instance,
)
from repro.benchsuite.pipeline import SlimstartPipeline, StaticPipeline
from repro.benchsuite.specs import APPS, LIBS, lib_closure
from repro.benchsuite.workload import ShiftingWorkload, skewed_weights


@pytest.fixture(scope="module")
def suite_root_dir():
    return build_suite()


# subprocess-per-cold-start integration tests; the full module is the
# slow tier (spec/workload checks that need no subprocess stay fast)
def test_spec_consistency():
    # every app's libs exist and close transitively
    for app in APPS.values():
        for lib in app.libs:
            assert lib in LIBS, f"{app.name} references unknown {lib}"
        closure = lib_closure(app.libs)
        assert set(app.libs) <= set(closure)
    # textblob pulls nltk; cvecore pulls xmlschema -> elementpath
    assert "fakelib_nltk" in lib_closure(("fakelib_textblob",))
    assert "fakelib_elementpath" in lib_closure(("fakelib_cvecore",))
    # handler weights sum to ~1
    for app in APPS.values():
        assert sum(h.weight for h in app.handlers) == pytest.approx(1.0, abs=1e-6)


@pytest.mark.slow
def test_suite_builds_and_apps_run(suite_root_dir):
    apps = os.listdir(os.path.join(suite_root_dir, "apps"))
    assert len(apps) == len(APPS)
    # every app cold-starts and every handler executes
    for name in ["graph_bfs", "echo", "cve_bin_tool"]:
        app_dir = os.path.join(suite_root_dir, "apps", name)
        meta = json.load(open(os.path.join(app_dir, "meta.json")))
        for handler in meta["handlers"]:
            m = run_instance(app_dir, invocations=1, handler=handler)
            assert m["init_ms"] > 0
            assert m["e2e_cold_ms"] >= m["init_ms"]


@pytest.mark.slow
def test_slimstart_pipeline_graph_bfs(suite_root_dir):
    pipe = SlimstartPipeline("graph_bfs", suite_root_dir)
    res = pipe.run(instances=2, invocations=80)
    report = res.report
    assert report.qualifies
    flagged = {f.package for f in report.findings}
    # the unused visualization/community subtrees must be flagged...
    meta = APPS["graph_bfs"]
    for pkg in meta.expected_flagged:
        assert pkg in flagged, f"{pkg} not flagged (got {flagged})"
    # ...and the hot path must NOT be flagged
    assert "fakelib_igraph.core" not in flagged
    assert "fakelib_igraph" not in flagged

    base = measure_cold_starts(pipe.app_dir, n=3)
    opt = measure_cold_starts(res.variant_dir, n=3)
    assert base.init_mean / opt.init_mean > 1.3  # real speedup
    assert base.rss_mean_mb / opt.rss_mean_mb > 1.1  # real memory cut

    # correctness: every handler (incl. rare ones needing deferred libs)
    for handler in json.load(open(os.path.join(pipe.app_dir, "meta.json")))["handlers"]:
        m = run_instance(res.variant_dir, invocations=1, handler=handler)
        assert m["e2e_cold_ms"] > 0


@pytest.mark.slow
def test_static_baseline_misses_workload_dependent(suite_root_dir):
    """Paper Observation 2: static keeps reachable-but-unused libraries."""
    stat = StaticPipeline("graph_bfs", suite_root_dir).run()
    base = measure_cold_starts(os.path.join(suite_root_dir, "apps", "graph_bfs"), n=3)
    sopt = measure_cold_starts(stat.variant_dir, n=3)
    static_speedup = base.init_mean / sopt.init_mean
    assert static_speedup >= 0.95  # static never hurts
    # SLIMSTART's variant (built by the previous test or rebuilt here)
    pipe = SlimstartPipeline("graph_bfs", suite_root_dir)
    res = pipe.run(instances=2, invocations=80)
    dyn = measure_cold_starts(res.variant_dir, n=3)
    dyn_speedup = base.init_mean / dyn.init_mean
    assert dyn_speedup > static_speedup + 0.2, (dyn_speedup, static_speedup)


@pytest.mark.slow
def test_clean_app_not_optimized(suite_root_dir):
    """Apps below the 10% init gate / with fully-used libs produce no
    defer targets (paper: 17 of 22 apps flagged, 5 clean)."""
    pipe = SlimstartPipeline("echo", suite_root_dir)
    res = pipe.run(instances=1, invocations=30)
    assert res.report.defer_targets == []


@pytest.mark.slow
def test_profiler_overhead_within_budget(suite_root_dir):
    """Paper Fig. 9: sampling overhead ≤ ~10-15%."""
    app_dir = os.path.join(suite_root_dir, "apps", "graph_bfs")
    base_ms, prof_ms = measure_warm_overhead(app_dir, invocations=60)
    assert prof_ms / base_ms < 1.25  # generous CI margin; bench reports exact


# ---------------------------------------------------------------------------
# periodic RSS sampling: true peaks for shrink-then-exit workloads
# ---------------------------------------------------------------------------

def test_peak_rss_sampler_sees_transient_ballast():
    """A workload that frees its ballast before exit must still report a
    true peak: the sampler watches current VmRSS (the only signal on
    VmHWM-less kernels) while the ballast is held."""
    import gc
    import time

    from repro.benchsuite.runner import PeakRssSampler, current_rss_kb

    baseline_kb = current_rss_kb()
    sampler = PeakRssSampler(interval_s=0.002)
    with sampler:
        ballast = bytearray(96 * 1024 * 1024)
        ballast[::4096] = b"\x01" * len(ballast[::4096])  # fault pages in
        time.sleep(0.05)  # hold while the sampler runs
        del ballast
        gc.collect()
        time.sleep(0.01)
    assert sampler.samples >= 2
    # the 96 MB transient must be in the recorded peak even though it
    # was freed before the sampler stopped
    assert sampler.peak_kb >= baseline_kb + 60 * 1024
    # stop() is idempotent and keeps the peak
    assert sampler.stop() == sampler.peak_kb


def test_peak_rss_sampler_with_injected_reader():
    from repro.benchsuite.runner import PeakRssSampler

    values = iter([100, 900, 200])
    sampler = PeakRssSampler(interval_s=60.0,  # thread never fires
                             read_kb=lambda: next(values, 200))
    sampler.start()
    assert sampler.peak_kb == 100  # initial sample taken at start()
    sampler._sample()
    sampler._sample()
    assert sampler.stop() == 900  # transient maximum retained


def test_runner_reports_peak_of_shrink_then_exit_child(tmp_path):
    """End-to-end: a handler that allocates 80 MB, frees it, then
    returns must report a peak_rss_kb covering the ballast."""
    from repro.benchsuite.harness import run_instance

    app_dir = tmp_path / "shrink_app"
    app_dir.mkdir()
    (app_dir / "handler.py").write_text(
        "import time\n"
        "WEIGHTS = {'burst': 1.0}\n"
        "def handler(ev):\n"
        "    ballast = bytearray(80 * 1024 * 1024)\n"
        "    ballast[::4096] = b'\\x01' * len(ballast[::4096])\n"
        "    time.sleep(0.06)  # the working phase that uses the ballast\n"
        "    del ballast\n"
        "    return {'ok': True}\n")
    m = run_instance(str(app_dir), invocations=2, seed=1)
    assert m["peak_rss_kb"] >= 80 * 1024


def test_workload_generators():
    w = skewed_weights(["a", "b", "c", "d"])
    assert w["a"] > w["b"] > w["c"] > w["d"]
    assert sum(w.values()) == pytest.approx(1.0)
    trace = ShiftingWorkload.stable_then_shift(
        ["a", "b"], window_s=10.0, rate_per_s=50.0, seed=3)
    events = list(trace.events())
    assert len(events) > 100
    ts = [t for t, _ in events]
    assert ts == sorted(ts)
