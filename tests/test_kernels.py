"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles.

Fixed cases cover block-boundary padding, GQA grouping, windows and
softcaps across dtypes; hypothesis sweeps randomize shapes within CPU
budget.  Tolerances: fp32 1e-5 / bf16 2e-2 (matmul rounding).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image without hypothesis: skip sweeps only
    st = None

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            return skipper
        return deco

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels import ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ----------------------------------------------------------------- flash
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,K,Sq,Skv,hd,causal,window,cap",
    [
        (2, 4, 2, 64, 64, 32, True, None, None),      # GQA
        (1, 2, 2, 48, 48, 16, True, None, None),      # off-block seq
        (1, 4, 1, 40, 40, 32, True, 16, None),        # MQA + window
        (1, 2, 2, 33, 33, 16, True, None, 30.0),      # softcap + ragged
        (1, 2, 2, 16, 80, 16, False, None, None),     # bidir, Sq != Skv
    ])
def test_flash_attention_vs_ref(B, H, K, Sq, Skv, hd, causal, window, cap,
                                dtype):
    key = jax.random.PRNGKey(0)
    q = _rand(key, (B, H, Sq, hd), dtype)
    k = _rand(jax.random.fold_in(key, 1), (B, K, Skv, hd), dtype)
    v = _rand(jax.random.fold_in(key, 2), (B, K, Skv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=cap, block_q=16, block_kv=16,
                          interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@settings(max_examples=8, deadline=None)
@given(
    B=st.integers(1, 2), K=st.integers(1, 2), G=st.integers(1, 3),
    sq=st.integers(3, 40), hd=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    window=st.sampled_from([None, 8]),
)
def test_flash_attention_hypothesis(B, K, G, sq, hd, causal, window):
    key = jax.random.PRNGKey(sq * hd + G)
    H = K * G
    q = _rand(key, (B, H, sq, hd), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (B, K, sq, hd), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (B, K, sq, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=16, block_kv=16, interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               **TOL[jnp.float32])


# ---------------------------------------------------------------- decode
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,K,G,S,hd,window,cap,ring",
    [
        (2, 2, 2, 64, 32, None, None, False),
        (1, 1, 4, 48, 16, None, None, False),   # MQA, ragged S
        (2, 2, 1, 40, 16, 16, None, True),      # ring buffer + window
        (1, 2, 2, 33, 16, None, 30.0, False),   # softcap
    ])
def test_decode_attention_vs_ref(B, K, G, S, hd, window, cap, ring, dtype):
    key = jax.random.PRNGKey(1)
    q = _rand(key, (B, K, G, hd), dtype)
    k = _rand(jax.random.fold_in(key, 1), (B, K, S, hd), dtype)
    v = _rand(jax.random.fold_in(key, 2), (B, K, S, hd), dtype)
    if ring:
        cur = S + 7  # wrapped ring: slot i holds position with slot == i%S
        base = jnp.arange(S)
        kv_pos = jnp.where(base <= cur % S, base + (cur // S) * S,
                           base + (cur // S - 1) * S)
        kv_pos = jnp.broadcast_to(kv_pos, (B, S))
        q_pos = jnp.full((B,), cur, jnp.int32)
    else:
        n_valid = S - 5
        kv_pos = jnp.where(jnp.arange(S) < n_valid, jnp.arange(S), -1)
        kv_pos = jnp.broadcast_to(kv_pos, (B, S))
        q_pos = jnp.full((B,), n_valid - 1, jnp.int32)
    out = decode_attention(q, k, v, q_pos, kv_pos, window=window,
                           softcap=cap, block_kv=16, interpret=True)
    want = ref.ref_decode_attention(q, k, v, q_pos, kv_pos, window=window,
                                    softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 2), K=st.integers(1, 2), G=st.integers(1, 4),
       S=st.integers(4, 50), hd=st.sampled_from([8, 16]),
       window=st.sampled_from([None, 8]))
def test_decode_attention_hypothesis(B, K, G, S, hd, window):
    key = jax.random.PRNGKey(S + hd)
    q = _rand(key, (B, K, G, hd), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (B, K, S, hd), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (B, K, S, hd), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_pos = jnp.full((B,), S - 1, jnp.int32)
    out = decode_attention(q, k, v, q_pos, kv_pos, window=window,
                           block_kv=16, interpret=True)
    want = ref.ref_decode_attention(q, k, v, q_pos, kv_pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               **TOL[jnp.float32])


# ---------------------------------------------------------------- rg-lru
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,R,with_h0", [
    (2, 64, 128, False),
    (1, 40, 130, True),   # ragged channel dim
    (2, 17, 64, True),    # ragged time dim
])
def test_rglru_scan_vs_ref(B, S, R, with_h0, dtype):
    key = jax.random.PRNGKey(2)
    # decays in (0, 1) like real RG-LRU coefficients
    a = jax.nn.sigmoid(_rand(key, (B, S, R), jnp.float32)).astype(dtype)
    b = _rand(jax.random.fold_in(key, 1), (B, S, R), dtype)
    h0 = (_rand(jax.random.fold_in(key, 2), (B, R), dtype)
          if with_h0 else None)
    out = rglru_scan(a, b, h0, block_t=16, block_r=128, interpret=True)
    want = ref.ref_rglru_scan(a, b, h0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 2), S=st.integers(2, 40),
       R=st.sampled_from([32, 100, 128]))
def test_rglru_hypothesis(B, S, R):
    key = jax.random.PRNGKey(S * R)
    a = jax.nn.sigmoid(_rand(key, (B, S, R), jnp.float32))
    b = _rand(jax.random.fold_in(key, 1), (B, S, R), jnp.float32)
    out = rglru_scan(a, b, block_t=16, block_r=128, interpret=True)
    want = ref.ref_rglru_scan(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------- ops layer consistency
def test_attention_op_matches_model_layer():
    """kernels.ops must agree with the model's XLA attention path."""
    from repro.models import layers as L
    from repro.kernels import ops
    key = jax.random.PRNGKey(3)
    B, S, K, G, hd = 2, 32, 2, 2, 16
    q = _rand(key, (B, S, K, G, hd), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (B, S, K, hd), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (B, S, K, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    for window, cap in [(None, None), (8, None), (None, 30.0)]:
        xla = L.attention(q, k, v, q_positions=pos, kv_positions=pos,
                          causal=True, window=window, softcap_val=cap)
        pallas = ops.attention_op(q, k, v, causal=True, window=window,
                                  softcap=cap)
        np.testing.assert_allclose(np.asarray(pallas), np.asarray(xla),
                                   rtol=2e-3, atol=2e-3)
