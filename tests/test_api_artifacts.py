"""Schema-versioned artifact layer: round-trips, golden v1 migration,
error paths, and atomic writes (no subprocesses — fast tier)."""

import json
import os

import pytest

from repro.api import (
    ArtifactError,
    BenchResultArtifact,
    ReportArtifact,
    as_report,
    load_any,
    load_bench_result,
    load_report,
    load_report_meta,
    load_stats,
    load_trace,
    peek,
    save_bench_result,
    save_report,
    save_stats,
    save_trace,
)
from repro.benchsuite.harness import ColdStartStats
from repro.core.profiler.import_timer import ModuleInitRecord
from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import (
    InefficiencyFinding,
    LibraryStats,
)
from repro.pool.trace import Request, Trace

GOLDEN_V1 = os.path.join(os.path.dirname(__file__), "data", "artifacts",
                         "optimization_report_v1.json")


def make_report() -> OptimizationReport:
    rep = OptimizationReport(application="test_app", e2e_s=0.3,
                             total_init_s=0.2, qualifies=True,
                             defer_targets=["libx.sub"])
    rep.stats = [LibraryStats(name="libx", utilization=0.9, init_s=0.15,
                              init_share=0.5, runtime_samples=20,
                              file="libs/libx/__init__.py")]
    rep.findings = [InefficiencyFinding(
        package="libx.sub", kind="unused", utilization=0.0, init_s=0.05,
        init_share=0.17, file="libs/libx/sub.py",
        import_chain=[ModuleInitRecord(
            name="libx.sub", filename="", importer_file="handler.py",
            importer_lineno=3)])]
    return rep


# ---------------------------------------------------------------------------
# report round-trip + envelope
# ---------------------------------------------------------------------------

def test_report_roundtrip(tmp_path):
    path = str(tmp_path / "rep.json")
    save_report(make_report(), path, meta={"instances": 2})
    kind, version = peek(path)
    assert (kind, version) == ("optimization_report", 2)
    rep = load_report(path)
    assert rep.application == "test_app"
    assert rep.defer_targets == ["libx.sub"]
    assert rep.stats[0].name == "libx"
    # call paths survive the round-trip (the v0 loader dropped them)
    assert rep.findings[0].import_chain[0].importer_file == "handler.py"
    assert load_report_meta(path) == {"instances": 2}


def test_report_save_is_atomic_and_leaves_no_temp(tmp_path):
    path = str(tmp_path / "rep.json")
    save_report(make_report(), path)
    good = open(path).read()
    # a failing serialization must not clobber the good file
    with pytest.raises(TypeError):
        save_bench_result("x", {"bad": object()}, path)
    assert open(path).read() == good
    assert os.listdir(tmp_path) == ["rep.json"]  # no stray temp files


def test_load_any_dispatch(tmp_path):
    path = str(tmp_path / "rep.json")
    save_report(make_report(), path)
    art = load_any(path)
    assert isinstance(art, ReportArtifact)
    assert art.report.application == "test_app"


def test_as_report_accepts_object_artifact_and_path(tmp_path):
    rep = make_report()
    assert as_report(rep) is rep
    path = save_report(rep, str(tmp_path / "r.json"))
    assert as_report(path).application == "test_app"
    assert as_report(ReportArtifact(rep)) is rep
    with pytest.raises(TypeError):
        as_report(42)


# ---------------------------------------------------------------------------
# golden v1 -> v2 migration
# ---------------------------------------------------------------------------

def test_golden_v1_loads_with_deprecation_warning():
    with pytest.warns(DeprecationWarning, match="unversioned"):
        rep = load_report(GOLDEN_V1)
    assert rep.application == "golden_app"
    assert rep.defer_targets == ["fakelib_nltk.sem"]
    assert [s.name for s in rep.stats] == ["fakelib_nltk",
                                           "fakelib_nltk.sem"]
    chain = rep.findings[0].import_chain
    assert [r.name for r in chain] == ["fakelib_nltk", "fakelib_nltk.sem"]
    assert chain[1].importer_lineno == 11


def test_golden_v1_resave_upgrades_schema(tmp_path):
    with pytest.warns(DeprecationWarning):
        rep = load_report(GOLDEN_V1)
    out = str(tmp_path / "upgraded.json")
    save_report(rep, out)
    assert peek(out) == ("optimization_report", 2)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # no warning the second time
        rep2 = load_report(out)
    assert rep2.to_dict() == rep.to_dict()


def test_deprecated_report_methods_still_work(tmp_path):
    path = str(tmp_path / "r.json")
    with pytest.warns(DeprecationWarning, match="save is deprecated"):
        make_report().save(path)
    with pytest.warns(DeprecationWarning, match="load is deprecated"):
        rep = OptimizationReport.load(path)
    assert rep.application == "test_app"


# ---------------------------------------------------------------------------
# error paths (satellite: clear errors with the offending path)
# ---------------------------------------------------------------------------

def _v1_payload() -> dict:
    return json.load(open(GOLDEN_V1))


def test_missing_key_raises_with_path(tmp_path):
    bad = _v1_payload()
    del bad["defer_targets"]
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(bad))
    with pytest.warns(DeprecationWarning), \
            pytest.raises(ArtifactError, match="missing keys") as ei:
        load_report(str(p))
    assert str(p) in str(ei.value)
    assert "defer_targets" in str(ei.value)


def test_unknown_key_raises_with_path(tmp_path):
    bad = _v1_payload()
    bad["bogus_field"] = 1
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(bad))
    with pytest.warns(DeprecationWarning), \
            pytest.raises(ArtifactError, match="unknown keys"):
        load_report(str(p))


def test_truncated_json_raises_artifact_error(tmp_path):
    p = tmp_path / "trunc.json"
    p.write_text('{"kind": "optimization_report", "schema_ver')
    with pytest.raises(ArtifactError, match="truncated"):
        load_report(str(p))


def test_newer_schema_version_refused(tmp_path):
    doc = {"kind": "optimization_report", "schema_version": 99,
           **_v1_payload()}
    p = tmp_path / "future.json"
    p.write_text(json.dumps(doc))
    with pytest.raises(ArtifactError, match="newer"):
        load_report(str(p))


def test_kind_mismatch_refused(tmp_path):
    doc = {"kind": "trace", "schema_version": 1, **_v1_payload()}
    p = tmp_path / "wrong.json"
    p.write_text(json.dumps(doc))
    with pytest.raises(ArtifactError, match="kind mismatch"):
        load_report(str(p))


def test_missing_file_raises_artifact_error(tmp_path):
    with pytest.raises(ArtifactError, match="cannot read"):
        load_report(str(tmp_path / "nope.json"))


# ---------------------------------------------------------------------------
# trace / stats / bench_result artifacts
# ---------------------------------------------------------------------------

def test_trace_roundtrip(tmp_path):
    trace = Trace("t1", [Request(0.5, "appa", None),
                         Request(1.25, "appb", "h2")], duration_s=10.0)
    path = save_trace(trace, str(tmp_path / "t.json"), meta={"seed": 3})
    assert peek(path) == ("trace", 1)
    t2 = load_trace(path)
    assert t2.name == "t1" and t2.duration_s == 10.0
    assert t2.requests == trace.requests


def test_stats_roundtrip(tmp_path):
    stats = ColdStartStats(app="appa", n=2, init_ms=[10.0, 12.0],
                           e2e_ms=[20.0, 22.0],
                           peak_rss_kb=[1024.0, 2048.0])
    path = save_stats(stats, str(tmp_path / "s.json"))
    assert peek(path) == ("cold_start_stats", 1)
    s2 = load_stats(path)
    assert s2.app == "appa" and s2.init_ms == [10.0, 12.0]
    assert s2.init_mean == pytest.approx(11.0)


def test_bench_result_roundtrip_and_v1_migration(tmp_path):
    path = str(tmp_path / "b.json")
    save_bench_result("bench_x", {"rows": [1, 2]}, path)
    assert peek(path) == ("bench_result", 2)
    assert load_bench_result(path) == {"rows": [1, 2]}
    # legacy raw payload (the seed's benchmarks/results format)
    legacy = {"figure": "Fig. 1", "rows": [{"app": "a"}]}
    p = tmp_path / "legacy.json"
    p.write_text(json.dumps(legacy))
    with pytest.warns(DeprecationWarning):
        art = BenchResultArtifact.load(str(p))
    assert art.data == legacy
    assert art.name == "Fig. 1"


# ---------------------------------------------------------------------------
# fleet_summary
# ---------------------------------------------------------------------------

def _fleet_summary_payload(**over):
    payload = {
        "source": "serve-sim", "requests": 10, "served": 8,
        "cold_starts": 2, "cold_start_ratio": 0.2, "p50_ms": 50.0,
        "p99_ms": 120.0, "sheds": 1, "flushed": 1,
        "queue_wait_p50_ms": 5.0, "queue_wait_p99_ms": 30.0,
        "per_app": [{"app": "a", "requests": 10}],
        "queue": {"depth": 4, "max_concurrency": 2,
                  "shed_policy": "reject-new"},
    }
    payload.update(over)
    return payload


def test_fleet_summary_roundtrip_and_load_any(tmp_path):
    from repro.api import (FleetSummaryArtifact, load_fleet_summary,
                           save_fleet_summary)
    path = str(tmp_path / "fs.json")
    save_fleet_summary(_fleet_summary_payload(), path,
                       meta={"run": "unit"})
    assert peek(path) == ("fleet_summary", 1)
    data = load_fleet_summary(path)
    assert data["served"] == 8 and data["queue"]["depth"] == 4
    assert data["meta"] == {"run": "unit"}
    art = load_any(path)
    assert isinstance(art, FleetSummaryArtifact)
    assert art.meta == {"run": "unit"}


def test_fleet_summary_schema_violations(tmp_path):
    import json as _json

    from repro.api import load_fleet_summary, save_fleet_summary
    path = str(tmp_path / "fs.json")
    bad = _fleet_summary_payload()
    del bad["sheds"]  # missing required key: fails at *write* time
    with pytest.raises(ArtifactError, match="missing keys.*sheds"):
        save_fleet_summary(bad, path)
    # a foreign/unknown key fails at load time, naming the path
    doc = {"kind": "fleet_summary", "schema_version": 1,
           **_fleet_summary_payload(), "unexpected": 1}
    with open(path, "w") as fh:
        _json.dump(doc, fh)
    with pytest.raises(ArtifactError, match="unknown keys.*unexpected"):
        load_fleet_summary(path)
    save_fleet_summary(_fleet_summary_payload(), path)
    assert load_fleet_summary(path)["requests"] == 10


def test_fleet_summary_from_live_replay_validates(tmp_path):
    """What FleetManager.artifact_payload emits must satisfy the
    schema the artifact declares — producers and schema can't drift."""
    from repro.api import load_fleet_summary, save_fleet_summary
    from repro.pool import (AppProfile, FleetManager, IdleTimeoutPolicy,
                            QueueConfig, Request, Trace)
    prof = {"a": AppProfile(app="a", cold_init_ms=100.0, invoke_ms=10.0,
                            warm_init_ms=5.0, rss_mb=64.0)}
    fm = FleetManager(prof, IdleTimeoutPolicy(timeout_s=30.0),
                      budget_mb=256.0,
                      queue=QueueConfig(depth=2, max_concurrency=1))
    summary = fm.replay(Trace("t", [Request(0.01 * i, "a")
                                    for i in range(10)], 10.0))
    path = str(tmp_path / "live.json")
    save_fleet_summary(summary.artifact_payload(source="replay-sim"),
                       path)
    data = load_fleet_summary(path)
    assert data["requests"] == 10
    assert data["requests"] == (data["served"] + data["sheds"]
                                + data["flushed"])
