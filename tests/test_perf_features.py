"""Tests for the §Perf levers: int8 KV cache, MoE dispatch groups, and
the structural cost model that feeds the roofline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import SHAPES, decode_step, forward, init_params, prefill
from repro.models.config import ShapeSpec
from repro.models.model import _head


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "gemma2-9b"])
def test_int8_kv_decode_greedy_equivalent(arch):
    """int8 KV decode must keep greedy decoding equivalent (argmax
    agreement with the fp cache) and logits within quantization error."""
    cfg = get_reduced(arch).with_(kv_quant="int8")
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, T0, n_dec = 2, 8, 4
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T0 + n_dec),
                                0, cfg.vocab, jnp.int32)
    h, _, _ = forward(cfg, params, tokens)
    full_logits = _head(cfg, params, h)
    _, caches, _ = prefill(cfg, params, tokens[:, :T0],
                           cache_len=T0 + n_dec)
    # cache leaves for global attention are int8 + scales
    k_leaf = caches["scan"]["pos0"]["k"] if "scan" in caches else None
    for i in range(n_dec):
        pos = jnp.full((B,), T0 + i, jnp.int32)
        ld, caches = decode_step(cfg, params, tokens[:, T0 + i:T0 + i + 1],
                                 pos, caches)
        ref = np.asarray(full_logits[:, T0 + i])
        got = np.asarray(ld)
        assert (got.argmax(-1) == ref.argmax(-1)).all(), \
            f"{arch}: greedy divergence at step {i}"
        denom = np.abs(ref).max()
        assert np.abs(got - ref).max() / denom < 0.25  # quant bound


def test_int8_cache_dtype():
    cfg = get_reduced("qwen2.5-32b").with_(kv_quant="int8")
    from repro.models.model import init_cache
    caches = init_cache(cfg, 2, 16)
    blk = caches["scan"]["pos0"]
    assert blk["k"].dtype == jnp.int8
    assert "k_scale" in blk and blk["k_scale"].dtype == jnp.float32


@pytest.mark.parametrize("group", [64, 128])
def test_moe_group_size_preserves_output(group):
    """Smaller dispatch groups change only capacity granularity; with a
    dropless capacity factor the MoE output is identical."""
    cfg = get_reduced("granite-moe-1b-a400m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                cfg.vocab, jnp.int32)
    h1, _, _ = forward(cfg, params, tokens)
    cfg2 = cfg.with_(moe_group=group)
    h2, _, _ = forward(cfg2, params, tokens)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_costmodel_matches_cost_analysis_unrolled():
    """The structural FLOP model must track XLA's cost analysis on a
    small *unrolled* config (where loop-body undercounting is absent)."""
    from benchmarks.costmodel import forward_flops
    cfg = get_reduced("granite-8b").with_(
        n_layers=2, scan_layers=False, remat="none", dtype="float32")
    shape = ShapeSpec("tiny", 64, 2, "train")
    est = forward_flops(cfg, shape)

    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                cfg.vocab, jnp.int32)

    def fwd(p, t):
        h, _, _ = forward(cfg, p, t)
        return _head(cfg, p, h).sum()

    compiled = jax.jit(fwd).lower(params, tokens).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = float(cost["flops"])
    # structural model within 35% of XLA's count for the forward pass
    # (XLA counts elementwise flops we exclude, we count attention
    # flops it fuses); the roofline needs order-of-magnitude fidelity
    assert 0.65 < est / hlo < 1.5, (est, hlo)
