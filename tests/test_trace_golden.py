"""Golden-file regression tests for the trace generators.

Every benchmark's workload flows through ``repro.pool.trace``; a silent
change to a generator (different RNG consumption order, a tweaked
default) would shift *every* benchmark's arrival pattern at once.  These
tests pin each generator's exact output for a fixed seed against a
checked-in golden file.

If a change to the generators is *intentional*, regenerate the goldens
with::

    PYTHONPATH=src python tests/test_trace_golden.py --regenerate

and commit the diff alongside the generator change.
"""

import json
import os
import sys

import pytest

from repro.pool import (
    azure_synthetic_rows,
    bursty_trace,
    diurnal_trace,
    handler_skewed_trace,
    poisson_trace,
    trace_from_azure_rows,
)

DATA_DIR = os.path.join(os.path.dirname(__file__), "data", "traces")


def _golden_traces():
    """The pinned generator calls — seeds and parameters must not drift."""
    return {
        "poisson": poisson_trace("app", rate_per_s=2.0, duration_s=30.0,
                                 seed=7),
        "diurnal": diurnal_trace("app", base_rate_per_s=0.2,
                                 peak_rate_per_s=3.0, period_s=20.0,
                                 duration_s=40.0, seed=7),
        "bursty": bursty_trace("app", idle_rate_per_s=0.1,
                               burst_rate_per_s=8.0, mean_burst_s=5.0,
                               mean_idle_s=10.0, duration_s=60.0, seed=7),
        "handler_skewed": handler_skewed_trace(
            "app", ["h0", "h1", "h2"], rate_per_s=3.0, duration_s=30.0,
            zipf_s=1.6, seed=7),
        "azure": trace_from_azure_rows(
            azure_synthetic_rows(["app0", "app1"], minutes=5,
                                 peak_rpm=12.0, popularity_s=1.5,
                                 diurnal_period_min=5, seed=7,
                                 handlers={"app0": ["h0", "h1"]}),
            seed=8),
    }


def _serialize(trace) -> dict:
    return {
        "name": trace.name,
        "duration_s": trace.duration_s,
        "requests": [[round(r.t, 6), r.app, r.handler] for r in trace],
    }


@pytest.mark.parametrize("shape", ["poisson", "diurnal", "bursty",
                                   "handler_skewed", "azure"])
def test_trace_generator_matches_golden(shape):
    with open(os.path.join(DATA_DIR, f"{shape}.json")) as fh:
        golden = json.load(fh)
    got = _serialize(_golden_traces()[shape])
    # JSON round-trips null -> None; normalize handlers for comparison
    golden["requests"] = [[t, a, h] for t, a, h in golden["requests"]]
    assert got["name"] == golden["name"]
    assert got["duration_s"] == golden["duration_s"]
    assert len(got["requests"]) == len(golden["requests"]), \
        f"{shape}: request count drifted — workloads of every benchmark " \
        f"replaying this shape just changed"
    assert got["requests"] == golden["requests"]


def _regenerate():
    os.makedirs(DATA_DIR, exist_ok=True)
    for name, tr in _golden_traces().items():
        path = os.path.join(DATA_DIR, f"{name}.json")
        with open(path, "w") as fh:
            json.dump(_serialize(tr), fh, indent=1)
        print(f"wrote {path} ({len(tr)} requests)")


if __name__ == "__main__":
    if "--regenerate" in sys.argv:
        _regenerate()
    else:
        print(__doc__)
