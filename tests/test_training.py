"""Training substrate tests: optimizer, data, checkpoints, fault
tolerance, gradient compression, accumulation equivalence."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.model import init_params, loss_fn
from repro.training.adamw import adamw_init, adamw_update
from repro.training.checkpoint import (
    latest_step, restore_checkpoint, save_checkpoint,
)
from repro.training.compress import (
    make_error_feedback_compressor, quantize_int8, simulate_int8,
)
from repro.training.data import SyntheticCorpus, make_pipeline
from repro.training.fault import RestartableLoop, StepWatchdog
from repro.training.step import make_train_step


@pytest.fixture(scope="module")
def small():
    cfg = get_reduced("granite-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    data = make_pipeline(cfg.vocab, 4, 32, seed=1)
    return cfg, params, data


def test_adamw_decreases_loss(small):
    cfg, params, data = small
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_grad_accumulation_matches_full_batch(small):
    cfg, params, data = small
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    opt = adamw_init(params)
    full = jax.jit(make_train_step(cfg, lr=1e-3))
    acc = jax.jit(make_train_step(cfg, lr=1e-3, accum_steps=2))
    p1, _, m1 = full(params, opt, batch)
    p2, _, m2 = acc(params, opt, batch)
    # same gradient (up to microbatch loss weighting on equal-sized
    # microbatches with no padding) => same update
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_data_pipeline_deterministic():
    a = make_pipeline(128, 2, 16, seed=7)
    b = make_pipeline(128, 2, 16, seed=7)
    for _ in range(3):
        xa, xb = next(a), next(b)
        np.testing.assert_array_equal(xa["tokens"], xb["tokens"])
        np.testing.assert_array_equal(xa["labels"], xb["labels"])
    # labels are next-token shifted
    corpus = SyntheticCorpus(128, seed=3)
    toks = corpus.tokens(100)
    assert toks.min() >= 0 and toks.max() < 128


def test_checkpoint_roundtrip_and_atomicity(tmp_path, small):
    cfg, params, _ = small
    opt = adamw_init(params)
    save_checkpoint(tmp_path, 5, (params, opt))
    assert latest_step(tmp_path) == 5
    (restored_p, restored_o), meta = restore_checkpoint(
        tmp_path, 5, (params, opt))
    assert meta["step"] == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a stale .tmp dir must not be visible as a checkpoint
    os.makedirs(tmp_path / "step_00000009.tmp" / "arrays")
    assert latest_step(tmp_path) == 5


def test_checkpoint_elastic_reshard(tmp_path, small):
    """Save unsharded, restore under an explicit 2-device sharding."""
    cfg, params, _ = small
    save_checkpoint(tmp_path, 1, params)
    n = jax.device_count()
    if n < 2:
        mesh = jax.make_mesh((1,), ("data",))
    else:
        mesh = jax.make_mesh((2,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda p: NamedSharding(mesh, P()), params)
    restored, _ = restore_checkpoint(tmp_path, 1, params, shardings=sh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_compression_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    q, scale = quantize_int8(g)
    back = np.asarray(q, np.float32) * float(scale)
    err = np.abs(back - np.asarray(g)).max()
    assert err <= float(scale) * 0.5 + 1e-6
    ghat = simulate_int8({"g": g})["g"]
    assert np.abs(np.asarray(ghat) - np.asarray(g)).max() <= \
        float(scale) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback the average of compressed grads converges to
    the true gradient (residual accumulation)."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(32,)).astype(np.float32) * 1e-3)
    compress = make_error_feedback_compressor()
    state = None
    acc = np.zeros(32, np.float32)
    n = 64
    for _ in range(n):
        ghat, state = compress({"g": g}, state if state is None
                               else state)
        state = state if isinstance(state, dict) else state
        ghat, state = (ghat, state)
        acc += np.asarray(ghat["g"])
    mean_err = np.abs(acc / n - np.asarray(g)).max()
    one_shot = np.abs(np.asarray(simulate_int8({"g": g})["g"])
                      - np.asarray(g)).max()
    assert mean_err <= one_shot + 1e-7


def test_compression_in_train_step_still_converges(small):
    cfg, params, data = small
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=1e-3,
                                   compress_fn=simulate_int8))
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_restartable_loop_recovers_from_failure(tmp_path):
    """Inject a failure mid-run; the loop restarts from the latest
    checkpoint and completes with identical final state."""
    calls = {"n": 0}

    def step_fn(step, state):
        calls["n"] += 1
        if calls["n"] == 7:  # one-time fault
            raise RuntimeError("injected node failure")
        return state + 1

    import json as _json

    def save(step, state):
        (tmp_path / f"s{step}.json").write_text(_json.dumps(
            {"step": step, "state": int(state)}))

    def latest():
        steps = sorted(int(p.stem[1:]) for p in tmp_path.glob("s*.json"))
        return steps[-1] if steps else None

    def restore(step):
        d = _json.loads((tmp_path / f"s{step}.json").read_text())
        return d["step"], d["state"]

    loop = RestartableLoop(step_fn=step_fn, make_state=lambda: 0,
                           save=save, restore=restore, latest=latest,
                           ckpt_every=2, max_restarts=2)
    step, state, stats = loop.run(10)
    assert step == 10 and state == 10
    assert stats.restarts == 1


def test_watchdog_flags_stragglers():
    import time
    wd = StepWatchdog(soft_deadline_s=0.01, hard_deadline_s=10.0)
    wd.run(lambda: time.sleep(0.02))
    wd.run(lambda: None)
    assert wd.stats.slow_steps == 1
    assert wd.stats.steps == 2


def test_shard_map_int8_allreduce_multipod():
    """The explicit cross-pod int8 all-reduce averages correctly."""
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices")
    from repro.training.compress import shard_map_int8_allreduce
    mesh = jax.make_mesh((2,), ("pod",))
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    out = shard_map_int8_allreduce({"g": g}, mesh, axis="pod")["g"]
    # both pods hold the same g -> average == g up to quantization error
    _, scale = quantize_int8(g)
    assert np.abs(np.asarray(out) - np.asarray(g)).max() <= \
        float(scale) + 1e-6
