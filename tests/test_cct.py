"""Unit tests for the Calling Context Tree (paper §IV-A.2, TC-2)."""

import pytest

from repro.core.profiler.cct import CCT, Frame, path_is_initialization

APP = Frame("app/handler.py", 10, "handler")
ORCH = Frame("libs/lib1/core.py", 5, "orchestrate")
HEAVY = Frame("libs/lib2/work.py", 99, "crunch")
HEAVY2 = Frame("libs/lib2/work.py", 120, "crunch_more")
INIT = Frame("libs/lib4/__init__.py", 1, "<module>")


def test_add_and_escalate_propagates_to_ancestors():
    cct = CCT()
    # orchestrator (1 self sample) delegates to heavy lib (99 samples)
    cct.add_path([APP, ORCH], count=1)
    cct.add_path([APP, ORCH, HEAVY], count=99)
    cct.escalate()
    app_node = cct.root.children[APP]
    orch_node = app_node.children[ORCH]
    assert orch_node.self_samples == 1
    # Escalation credits the orchestrator with its callees' activity
    # (paper Fig. 5, Lib-1 case).
    assert orch_node.inclusive_samples == 100
    assert app_node.inclusive_samples == 100
    assert cct.total_samples == 100


def test_multiple_call_paths_stay_distinct():
    cct = CCT()
    direct = (APP, HEAVY)
    indirect = (APP, ORCH, HEAVY)
    cct.add_path(direct, count=3)
    cct.add_path(indirect, count=7)
    cct.escalate()
    # Same function, two contexts, two nodes (paper Lib-6 case).
    app_node = cct.root.children[APP]
    assert app_node.children[HEAVY].self_samples == 3
    assert app_node.children[ORCH].children[HEAVY].self_samples == 7
    agg = cct.leaf_self_samples()
    assert agg[HEAVY] == 10


def test_init_samples_separated_from_runtime():
    cct = CCT()
    cct.add_path([APP, INIT, HEAVY], count=5)  # during lib4 import
    cct.add_path([APP, ORCH, HEAVY], count=5)  # runtime
    cct.escalate()
    assert cct.total_init_samples == 5
    runtime = cct.runtime_self_samples_by(
        lambda fr: "lib2" if "lib2" in fr.filename else None)
    # Only the runtime path contributes to utilization (Lib-4 case).
    assert runtime == {"lib2": 5}


def test_path_is_initialization_detects_module_frames():
    assert path_is_initialization((APP, INIT))
    assert not path_is_initialization((APP, ORCH, HEAVY))
    frozen = Frame("<frozen importlib._bootstrap>", 1, "_find_and_load")
    assert path_is_initialization((APP, frozen, HEAVY))


def test_merge_accumulates_across_invocations():
    a, b = CCT(), CCT()
    a.add_path([APP, HEAVY], count=2)
    b.add_path([APP, HEAVY], count=3)
    b.add_path([APP, ORCH], count=1)
    a.merge(b)
    a.escalate()
    assert a.total_samples == 6
    assert a.root.children[APP].children[HEAVY].self_samples == 5


def test_serialization_roundtrip():
    cct = CCT()
    cct.add_path([APP, ORCH, HEAVY], count=4)
    cct.add_path([APP, INIT], count=2)
    s = cct.dumps()
    back = CCT.loads(s)
    back.escalate()
    assert back.total_samples == 6
    assert back.total_init_samples == 2
    assert back.root.children[APP].children[ORCH].children[HEAVY].self_samples == 4


def test_paths_to_finds_call_paths():
    cct = CCT()
    cct.add_path([APP, ORCH, HEAVY], count=1)
    cct.add_path([APP, HEAVY2], count=1)
    paths = cct.paths_to(lambda fr: "lib2" in fr.filename)
    assert len(paths) == 2
    assert all(p[0] == APP for p in paths)
