#!/usr/bin/env python3
"""CI perf smoke gate for the shared-base two-tier fleet.

Replays one small deterministic Azure-style trace through the simulated
fleet twice — one-zygote-per-app (PR 2 shape) and ``--shared-base``
(PR 5 two-tier) — via the real ``python -m repro fleet replay`` CLI,
then fails (exit 1) if shared-base *regresses* cold-start ratio or
memory GB-s beyond the checked-in tolerances in
``tools/perf_tolerance.json``.  The simulation is deterministic, so a
failure is a code regression, not noise.

Synthetic per-app report artifacts (one hot lib shared fleet-wide, one
private) are generated into a temp reports-dir so the profile-guided
policy actually admits zygotes — without reports the sweep would run
zygote-less and the gate would compare nothing.

Usage::

    python tools/perf_smoke.py [--keep out-dir] [--tolerance FILE]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

APPS = ["alpha", "beta", "gamma"]
# budget sized so BOTH fleets reach the same (zero) cold-start ratio:
# the memory check then compares GB-s at equal service quality, the
# tentpole's claim.  (Tighter budgets make shared-base trade memory for
# a much lower cold ratio, which a scalar memory gate would misread as
# a regression.)
REPLAY_ARGS = ["--minutes", "8", "--peak-rpm", "40", "--seed", "7",
               "--budget-mb", "420", "--policy", "profile",
               "--zygote-rss-mb", "96", "--shared-base-mb", "64"]


def _write_reports(reports_dir: str) -> None:
    from repro.api import save_report
    from repro.core.profiler.report import OptimizationReport
    from repro.core.profiler.utilization import LibraryStats

    def stat(name: str) -> LibraryStats:
        return LibraryStats(name=name, utilization=0.9, init_s=0.12,
                            init_share=0.5, runtime_samples=60,
                            file="<perf-smoke>")

    for app in APPS:
        rep = OptimizationReport(
            application=app, e2e_s=0.25, total_init_s=0.2,
            qualifies=True,
            stats=[stat("fakelib_shared"), stat(f"fakelib_{app}")],
            defer_targets=[])
        save_report(rep, os.path.join(reports_dir, f"{app}.json"))


def _replay(out_path: str, reports_dir: str, *extra: str) -> None:
    cmd = [sys.executable, "-m", "repro", "fleet", "replay",
           "--apps", ",".join(APPS), "--reports-dir", reports_dir,
           "--out", out_path, *REPLAY_ARGS, *extra]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=REPO, timeout=300)
    if proc.returncode != 0:
        raise RuntimeError(
            f"fleet replay failed ({proc.returncode}):\n"
            f"{proc.stderr[-2000:]}")


def _tracer_overhead(n: int = 2000, runs: int = 3):
    """Wall time of an in-process sim replay, tracing off vs on.

    min-of-N runs each way so scheduler noise doesn't trip the gate;
    the simulation itself is deterministic.
    """
    import time

    from repro.obs.tracing import configure_tracing, get_tracer
    from repro.pool import (
        AppProfile, FleetDaemon, FleetManager, IdleTimeoutPolicy,
        QueueConfig, SimFleetBackend,
    )
    from repro.pool.trace import Request

    def one() -> float:
        profiles = {a: AppProfile(app=a, cold_init_ms=400.0,
                                  warm_init_ms=20.0, invoke_ms=30.0,
                                  rss_mb=100.0) for a in APPS}
        manager = FleetManager(
            profiles, IdleTimeoutPolicy(timeout_s=60.0),
            budget_mb=2048.0,
            queue=QueueConfig(depth=64, max_concurrency=4))
        daemon = FleetDaemon(SimFleetBackend(manager))
        daemon.start("perf-smoke")
        t0 = time.perf_counter()
        for i in range(n):
            daemon.submit(Request(t=i * 0.01, app=APPS[i % len(APPS)]))
        dt = time.perf_counter() - t0
        daemon.shutdown(end_t=n * 0.01 + 120.0)
        get_tracer().clear()
        return dt

    configure_tracing(enabled=False)
    off_s = min(one() for _ in range(runs))
    configure_tracing(enabled=True)
    on_s = min(one() for _ in range(runs))
    configure_tracing(enabled=False)
    return off_s, on_s


def _adaptive_overhead(n: int = 4000, runs: int = 3):
    """Wall time of an in-process sim replay, adaptive loop off vs on.

    The closed loop promises the serving path pays only the per-arrival
    drift-detector bookkeeping (the child-side sampler rides sampled
    *forked* execs, which the sim doesn't fork); this holds the
    end-to-end submit loop to the <=3 % p50 budget, min-of-N runs.
    Window size is chosen so several windows actually close (and score)
    inside the run — the gate covers the window-close path too.
    """
    import time

    from repro.core.adaptive import AdaptiveConfig, DriftConfig
    from repro.pool import (
        AppProfile, FleetDaemon, FleetManager, IdleTimeoutPolicy,
        QueueConfig, SimFleetBackend,
    )
    from repro.pool.daemon import make_sim_adaptive_loop
    from repro.pool.trace import Request

    def one(adaptive: bool) -> float:
        profiles = {a: AppProfile(app=a, cold_init_ms=400.0,
                                  warm_init_ms=20.0, invoke_ms=30.0,
                                  rss_mb=100.0) for a in APPS}
        manager = FleetManager(
            profiles, IdleTimeoutPolicy(timeout_s=60.0),
            budget_mb=2048.0,
            queue=QueueConfig(depth=64, max_concurrency=4))
        loop = None
        if adaptive:
            loop = make_sim_adaptive_loop(
                manager, config=AdaptiveConfig(
                    drift=DriftConfig(window_s=5.0)))
        daemon = FleetDaemon(SimFleetBackend(manager, adaptive=loop))
        daemon.start("perf-smoke-adaptive")
        t0 = time.perf_counter()
        for i in range(n):
            daemon.submit(Request(t=i * 0.01, app=APPS[i % len(APPS)]))
        dt = time.perf_counter() - t0
        daemon.shutdown(end_t=n * 0.01 + 120.0)
        return dt

    off_s = min(one(False) for _ in range(runs))
    on_s = min(one(True) for _ in range(runs))
    return off_s, on_s


def _fault_hook_overhead(n: int = 4000, runs: int = 3):
    """Dispatch wall time with the chaos ``fault_hook`` unset vs a
    no-op hook installed.

    The serving path promises that a disabled hook costs one
    ``is not None`` check; this measures an EnginePool dispatch loop
    (every request a cold start, the hook's hottest placement) both
    ways, min-of-N runs.  Fake duck-typed engines keep the loop pure
    dispatch machinery — no real model builds.
    """
    import time

    from repro.serving.engine import EnginePool

    class _FakeEngine:
        cold_start_s = 0.0   # read by the eviction amortizer
        registry = {}        # no components to drop on eviction

        def cold_start(self):
            return 0.0

        def serve(self, entry, tokens, **kw):
            return None, 0.0

    models = ["m0", "m1"]

    def one(hook) -> float:
        # max_warm=1 with two alternating models: every dispatch
        # evicts + cold-starts, so the hook site runs per request
        pool = EnginePool({m: _FakeEngine for m in models},
                          max_warm=1, fault_hook=hook)
        t0 = time.perf_counter()
        for i in range(n):
            pool.dispatch(models[i % 2], "generate", None)
        return time.perf_counter() - t0

    off_s = min(one(None) for _ in range(runs))
    on_s = min(one(lambda site, **ctx: None) for _ in range(runs))
    return off_s, on_s


def _ha_overhead(n: int = 1500, runs: int = 3):
    """Routing-path cost of the HA machinery (ISSUE 10).

    Same socket-fed router + one sim node agent both ways; the "on"
    arm additionally enables ledger replication with ZERO standbys
    attached — the promised idle cost is one ``is not None`` check
    plus an entry publish into an empty connection list per route.
    Every call already runs under :class:`RetryPolicy` (that IS the
    plain path now); this bounds what replication adds on top,
    min-of-N runs over a socket round-trip baseline.
    """
    import time

    from repro.cluster import (ClusterRouter, NodeAgent, NodeClient,
                               RetryPolicy)
    from repro.pool import (
        AppProfile, FleetManager, IdleTimeoutPolicy, QueueConfig,
        SimFleetBackend,
    )

    def one(replicate: bool) -> float:
        profiles = {a: AppProfile(app=a, cold_init_ms=400.0,
                                  warm_init_ms=20.0, invoke_ms=30.0,
                                  rss_mb=100.0) for a in APPS}
        manager = FleetManager(
            profiles, IdleTimeoutPolicy(timeout_s=60.0),
            budget_mb=2048.0,
            queue=QueueConfig(depth=64, max_concurrency=4))
        agent = NodeAgent(SimFleetBackend(manager), node_id="perf",
                          port=0)
        agent.start()
        try:
            router = ClusterRouter(
                {"perf": NodeClient("perf", agent.host, agent.port,
                                    retry=RetryPolicy(seed=7))},
                strategy="hash", seed=7, retry=RetryPolicy(seed=7))
            router.connect()
            if replicate:
                router.enable_replication()
            t0 = time.perf_counter()
            for i in range(n):
                router.route(APPS[i % len(APPS)])
            dt = time.perf_counter() - t0
            router.shutdown()
        finally:
            agent.result()
        return dt

    off_s = min(one(False) for _ in range(runs))
    on_s = min(one(True) for _ in range(runs))
    return off_s, on_s


def _cluster_check(tol: dict, check) -> None:
    """In-process cluster placement gate: sharing vs hash at equal
    budgets on a deterministic Zipf workload, plus conservation and a
    wall-clock bound on the replay (the cluster simulator's
    scale-out promise)."""
    import time

    from repro.cluster import compare_strategies, synthetic_cluster_workload

    wl = synthetic_cluster_workload(16, n_families=4, seed=7,
                                    minutes=10, peak_rpm=80.0)
    t0 = time.perf_counter()
    results = compare_strategies(wl, n_nodes=4, node_budget_mb=512.0,
                                 strategies=("sharing", "hash"), seed=7)
    replay_s = time.perf_counter() - t0
    sharing, hashed = results["sharing"], results["hash"]
    dr = sharing["cold_start_ratio"] - hashed["cold_start_ratio"]
    check("cluster placement",
          dr <= tol["max_cold_ratio_vs_hash"],
          f"sharing {sharing['cold_start_ratio']:.4f} vs hash "
          f"{hashed['cold_start_ratio']:.4f} cold ratio "
          f"(delta {dr:+.4f}, allowed "
          f"+{tol['max_cold_ratio_vs_hash']})")
    check("cluster conservation",
          all(p["conservation"]["holds"] for p in results.values()),
          f"sharing={sharing['conservation']['holds']} "
          f"hash={hashed['conservation']['holds']}")
    n_req = sharing["requests"] + hashed["requests"]
    check("cluster replay throughput",
          n_req >= tol["min_replay_requests"]
          and replay_s <= tol["max_replay_s"],
          f"{n_req} arrivals through 2 x 4 simulated nodes in "
          f"{replay_s:.2f} s (need >= {tol['min_replay_requests']} "
          f"within {tol['max_replay_s']} s)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance",
                    default=os.path.join(REPO, "tools",
                                         "perf_tolerance.json"))
    ap.add_argument("--keep", default=None,
                    help="directory to keep the two fleet_summary "
                         "artifacts in (default: temp)")
    args = ap.parse_args(argv)

    with open(args.tolerance) as fh:
        all_tol = json.load(fh)
    tol = all_tol["shared_base"]

    from repro.api import load_fleet_summary

    out_dir = args.keep or tempfile.mkdtemp(prefix="perf-smoke-")
    os.makedirs(out_dir, exist_ok=True)
    reports_dir = os.path.join(out_dir, "reports")
    os.makedirs(reports_dir, exist_ok=True)
    _write_reports(reports_dir)

    base_path = os.path.join(out_dir, "one-per-app.json")
    shared_path = os.path.join(out_dir, "shared-base.json")
    _replay(base_path, reports_dir)
    _replay(shared_path, reports_dir, "--shared-base")

    base = load_fleet_summary(base_path)
    shared = load_fleet_summary(shared_path)

    checks = []

    def check(name: str, ok: bool, detail: str) -> None:
        checks.append(ok)
        print(f"  [{'ok' if ok else 'FAIL'}] {name}: {detail}")

    print(f"perf smoke: {base['requests']} requests, "
          f"budget {base.get('budget_mb')} MB")
    dr = shared["cold_start_ratio"] - base["cold_start_ratio"]
    check("cold-start ratio",
          dr <= tol["max_cold_ratio_regression"],
          f"one-per-app {base['cold_start_ratio']:.4f} vs shared-base "
          f"{shared['cold_start_ratio']:.4f} (delta {dr:+.4f}, "
          f"allowed +{tol['max_cold_ratio_regression']})")
    mem_b, mem_s = base["memory_gb_s"], shared["memory_gb_s"]
    limit = mem_b * (1.0 + tol["max_memory_regression_frac"])
    check("memory GB-s", mem_s <= limit,
          f"one-per-app {mem_b} vs shared-base {mem_s} "
          f"(limit {limit:.3f})")
    check("two-tier actually on",
          shared.get("shared_base_mb", 0) > 0
          and shared.get("pool_starts", 0) > 0,
          f"shared_base_mb={shared.get('shared_base_mb')} "
          f"pool_starts={shared.get('pool_starts')} (zygotes admitted "
          f"and serving forks)")

    ttol = all_tol["tracer"]
    n_req = 2000
    off_s, on_s = _tracer_overhead(n=n_req)
    frac = (on_s - off_s) / off_s if off_s else 0.0
    per_req_us = (on_s - off_s) / n_req * 1e6
    check("tracer overhead",
          frac <= ttol["max_overhead_frac"]
          or per_req_us <= ttol["max_per_request_us"],
          f"sim replay off {off_s * 1e3:.1f} ms vs on "
          f"{on_s * 1e3:.1f} ms ({frac * 100:+.1f}%, "
          f"{per_req_us:+.1f} us/req; allowed "
          f"{ttol['max_overhead_frac'] * 100:.0f}% or "
          f"{ttol['max_per_request_us']} us/req)")

    ftol = all_tol["fault_hook"]
    n_disp = 4000
    off_s, on_s = _fault_hook_overhead(n=n_disp)
    frac = (on_s - off_s) / off_s if off_s else 0.0
    per_req_us = (on_s - off_s) / n_disp * 1e6
    check("fault_hook overhead",
          frac <= ftol["max_overhead_frac"]
          or per_req_us <= ftol["max_per_request_us"],
          f"hook unset {off_s * 1e3:.1f} ms vs no-op hook "
          f"{on_s * 1e3:.1f} ms over {n_disp} dispatches "
          f"({frac * 100:+.1f}%, {per_req_us:+.2f} us/req; allowed "
          f"{ftol['max_overhead_frac'] * 100:.0f}% or "
          f"{ftol['max_per_request_us']} us/req)")

    atol = all_tol["adaptive"]
    n_sub = 4000
    off_s, on_s = _adaptive_overhead(n=n_sub)
    frac = (on_s - off_s) / off_s if off_s else 0.0
    per_req_us = (on_s - off_s) / n_sub * 1e6
    check("adaptive-loop overhead",
          frac <= atol["max_overhead_frac"]
          or per_req_us <= atol["max_per_request_us"],
          f"sim replay static {off_s * 1e3:.1f} ms vs adaptive "
          f"{on_s * 1e3:.1f} ms over {n_sub} submits "
          f"({frac * 100:+.1f}%, {per_req_us:+.2f} us/req; allowed "
          f"{atol['max_overhead_frac'] * 100:.0f}% or "
          f"{atol['max_per_request_us']} us/req)")

    htol = all_tol["cluster_ha"]
    n_route = 1500
    off_s, on_s = _ha_overhead(n=n_route)
    frac = (on_s - off_s) / off_s if off_s else 0.0
    per_req_us = (on_s - off_s) / n_route * 1e6
    check("ha routing overhead",
          frac <= htol["max_overhead_frac"]
          or per_req_us <= htol["max_per_request_us"],
          f"replication off {off_s * 1e3:.1f} ms vs on (zero "
          f"standbys) {on_s * 1e3:.1f} ms over {n_route} routes "
          f"({frac * 100:+.1f}%, {per_req_us:+.2f} us/req; allowed "
          f"{htol['max_overhead_frac'] * 100:.0f}% or "
          f"{htol['max_per_request_us']} us/req)")

    _cluster_check(all_tol["cluster"], check)

    if all(checks):
        print("perf smoke: PASS — shared-base does not regress the "
              "one-zygote-per-app fleet")
        return 0
    print("perf smoke: FAIL — shared-base regressed beyond "
          f"{args.tolerance}", file=sys.stderr)
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
