#!/usr/bin/env python3
"""Relative-link checker for the markdown docs (stdlib only).

Walks the given files/directories for ``*.md``, extracts inline
markdown links, and verifies every **relative** target resolves to an
existing file (anchors are stripped; ``http(s):``/``mailto:`` links
are ignored — CI must not flake on the network).  Exit 1 with one line
per broken link.

    python tools/check_doc_links.py README.md docs src/repro/pool
"""

from __future__ import annotations

import os
import re
import sys

# inline links [text](target); images ![alt](target) match too.
# Skips autolinks/code spans by construction (no markdown parser, but
# the docs stick to plain inline links).
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files(paths: list[str]) -> tuple[list[str], list[str]]:
    """Expand args to markdown files; a named path that is missing or
    not markdown is an error (a typo in CI must not silently shrink
    the gate's coverage)."""
    out: list[str] = []
    bad: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, _dirs, files in os.walk(p):
                out.extend(os.path.join(dirpath, f) for f in files
                           if f.endswith(".md"))
        elif p.endswith(".md") and os.path.isfile(p):
            out.append(p)
        else:
            bad.append(p)
    return sorted(set(out)), bad


def broken_links(path: str) -> list[tuple[int, str]]:
    bad: list[tuple[int, str]] = []
    base = os.path.dirname(os.path.abspath(path))
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            for target in LINK_RE.findall(line):
                if target.startswith(SKIP_PREFIXES):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                if not os.path.exists(os.path.join(base, rel)):
                    bad.append((lineno, target))
    return bad


def main(argv: list[str]) -> int:
    paths = argv or ["README.md", "docs"]
    files, bad_args = md_files(paths)
    for p in bad_args:
        print(f"check_doc_links: no such markdown file or directory: "
              f"{p}", file=sys.stderr)
    if bad_args:
        return 1
    if not files:
        print("check_doc_links: no markdown files found", file=sys.stderr)
        return 1
    failures = 0
    for f in files:
        for lineno, target in broken_links(f):
            print(f"{f}:{lineno}: broken relative link -> {target}")
            failures += 1
    if failures:
        print(f"check_doc_links: {failures} broken link(s) in "
              f"{len(files)} file(s)", file=sys.stderr)
        return 1
    print(f"check_doc_links: OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
