#!/usr/bin/env python3
"""Append a perf-trajectory row to BENCH_POOL.json.

The fleet benchmarks (``benchmarks.bench_fleet``,
``benchmarks.bench_pool_policies``) print rich tables per run but left
no durable trend line: a regression in cold-start ratio or zygote boot
latency only showed up if someone diffed nightly artifacts by hand.
This tool snapshots the key metrics out of the latest ``bench_result``
artifacts into ``BENCH_POOL.json`` — a checked-in, append-only list of
schema-versioned rows — so the trajectory (PR 5 seeds it with the first
shared-base point) is reviewable in-repo and the nightly job extends
it as an uploaded artifact.

Usage::

    python tools/record_bench.py [--out BENCH_POOL.json] [--label L]

Reads ``benchmarks/results/bench_fleet.json`` (required) and
``bench_pool_policies.json`` (optional).  Exit 2 when no bench result
exists yet.
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)

SCHEMA_VERSION = 1


def _git_commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _fleet_metrics(data: dict) -> dict:
    """The trend-worthy numbers out of one bench_fleet payload."""
    sim = {r["policy"]: r for r in data.get("sim_rows", [])}
    pg = sim.get("profile-guided", {})
    queue = {r["policy"]: r for r in data.get("queue_rows", [])}
    qpg = queue.get("profile-guided", {})
    out = {
        "budget_mb": data.get("budget_mb"),
        "requests": data.get("trace", {}).get("requests"),
        "profile_guided": {
            "cold_ratio": pg.get("cold_ratio"),
            "p99_ms": pg.get("p99_ms"),
            "mean_ms": pg.get("mean_ms"),
            "memory_gb_s": pg.get("memory_gb_s"),
        },
        "bounded_queue": {
            "cold_ratio": qpg.get("cold_ratio"),
            "shed_rate": qpg.get("shed_rate"),
            "queue_wait_p99_ms": qpg.get("queue_wait_p99_ms"),
        },
        "beats_fixed": data.get("profile_guided_beats_fixed"),
        "beats_idle_timeout": data.get(
            "profile_guided_beats_idle_timeout"),
    }
    two_tier = data.get("two_tier_boot")
    if two_tier:
        rows = data.get("shared_base_rows", [])

        def row(prefix: str) -> dict:
            return next((r for r in rows
                         if r["fleet"].startswith(prefix)), {})

        one = row("one-zygote-per-app (PR 2)")
        # the budget-grown PR 2 run matching the two-tier cold ratio
        # (absent when both already serve equally at the same budget)
        eq = row("one-zygote-per-app @ equal service") or one
        two = row("shared-base two-tier")
        out["shared_base"] = {
            "min_boot_speedup": two_tier.get("min_boot_speedup"),
            "base_boot_ms": two_tier.get("base_boot_ms"),
            "base_rss_mb": two_tier.get("base_rss_mb"),
            "shared_modules": two_tier.get("shared_modules"),
            "one_per_app_memory_gb_s": one.get("memory_gb_s"),
            "one_per_app_equal_service_memory_gb_s":
                eq.get("memory_gb_s"),
            "two_tier_memory_gb_s": two.get("memory_gb_s"),
            "one_per_app_cold_ratio": one.get("cold_ratio"),
            "two_tier_cold_ratio": two.get("cold_ratio"),
            "wins": data.get("shared_base_wins"),
        }
    adaptive = data.get("adaptive_comparison")
    if adaptive:
        out["adaptive"] = {
            "static_cold_ratio": adaptive.get("static_cold_ratio"),
            "adaptive_cold_ratio": adaptive.get("adaptive_cold_ratio"),
            "static_p99_init_ms": adaptive.get("static_p99_init_ms"),
            "adaptive_p99_init_ms":
                adaptive.get("adaptive_p99_init_ms"),
            "drift_fires": adaptive.get("drift_fires"),
            "beats_static": adaptive.get("adaptive_beats_static"),
        }
    handoff = data.get("handoff_rows")
    if handoff:
        out["handoff"] = {
            "min_speedup": data.get("handoff_min_speedup"),
            "warm_first_ms": {r["app"]: r.get("warm_first_ms")
                              for r in handoff},
            "cold_first_ms": {r["app"]: r.get("cold_first_ms")
                              for r in handoff},
            "warm_beats_cold": data.get("handoff_warm_beats_cold"),
        }
    cluster = {r["placement"]: r for r in data.get("cluster_rows", [])}
    if cluster:
        sharing = cluster.get("sharing", {})
        hashed = cluster.get("hash", {})
        out["cluster"] = {
            "nodes": data.get("cluster_nodes"),
            "sharing_cold_ratio": sharing.get("cold_ratio"),
            "hash_cold_ratio": hashed.get("cold_ratio"),
            "sharing_p99_ms": sharing.get("p99_ms"),
            "sharing_memory_gb_s": sharing.get("memory_gb_s"),
            "conserves": all(r.get("conserves")
                             for r in cluster.values()),
            "sharing_beats_hash": data.get(
                "cluster_sharing_beats_hash"),
        }
    return out


def _pool_metrics(data: dict) -> dict:
    return {
        "min_speedup_hot": data.get("min_speedup_hot"),
        "min_boot_speedup": data.get("min_boot_speedup"),
        "shared_modules": data.get("shared_modules"),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="snapshot bench_fleet/bench_pool_policies metrics "
                    "into the BENCH_POOL.json trajectory")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_POOL.json"))
    ap.add_argument("--label", default="",
                    help="free-form row label (e.g. 'nightly', 'pr5')")
    args = ap.parse_args(argv)

    from benchmarks.common import load_result

    fleet = load_result("bench_fleet")
    if fleet is None:
        print("record_bench: no benchmarks/results/bench_fleet.json — "
              "run `python -m benchmarks.bench_fleet --smoke` first",
              file=sys.stderr)
        return 2
    row = {
        "schema_version": SCHEMA_VERSION,
        "recorded_at": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "commit": _git_commit(),
        "label": args.label,
        "bench_fleet": _fleet_metrics(fleet),
    }
    pool = load_result("bench_pool_policies")
    if pool is not None:
        row["bench_pool_policies"] = _pool_metrics(pool)

    rows = []
    if os.path.exists(args.out):
        with open(args.out) as fh:
            rows = json.load(fh)
        if not isinstance(rows, list):
            print(f"record_bench: {args.out} is not a JSON list",
                  file=sys.stderr)
            return 2
    rows.append(row)
    from repro.api import atomic_write_json
    atomic_write_json(args.out, rows)
    print(f"recorded trajectory point #{len(rows)} "
          f"({row['commit']}) -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
