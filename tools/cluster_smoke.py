#!/usr/bin/env python3
"""Cluster HA smoke: three nodes, a warm standby, a leader kill.

Boots three ``python -m repro cluster serve --sim`` node agents as
subprocesses on ephemeral localhost ports, reads their ready lines for
the bound ports, then runs ``cluster route --ha`` against all three
with a chaos ``router_loss`` injected mid-replay and checks:

* the leader router died abruptly and the standby won the epoch-bumped
  lease election and finished the replay (``ha.failovers == 1``),
* the merged ``cluster_summary`` conserves requests per node AND
  globally (``requests == served + sheds + flushed + errors +
  abandoned``, router ledger == node ledgers) ACROSS the failover,
* all three node agents exited 0 after their drain.

This is the CI fast-tier gate for the replicated-router serving path
(the pytest suite covers the same path in-process; this exercises the
actual CLI entrypoints and process lifecycle).  Exit 0 on success, 1
on any failure, with the evidence printed.

    python tools/cluster_smoke.py [--n-apps 8] [--limit 300] [--seed 7]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), env.get("PYTHONPATH", "")]).rstrip(
        os.pathsep)
    return env


def _spawn_node(node_id: str, apps: list[str],
                args: argparse.Namespace) -> tuple:
    """Start one node agent; block until its ready line, return
    (process, port)."""
    cmd = [sys.executable, "-m", "repro", "cluster", "serve", "--sim",
           "--node-id", node_id, "--port", "0",
           "--apps", ",".join(apps),
           "--n-apps", str(args.n_apps),
           "--families", str(args.families),
           "--seed", str(args.seed),
           "--minutes", str(args.minutes)]
    proc = subprocess.Popen(cmd, cwd=REPO, env=_env(),
                            stdout=subprocess.PIPE,
                            stderr=subprocess.DEVNULL, text=True)
    line = proc.stdout.readline()
    try:
        ready = json.loads(line)
        assert ready.get("event") == "ready"
    except (json.JSONDecodeError, AssertionError):
        proc.kill()
        raise RuntimeError(
            f"{node_id}: bad ready line {line!r}") from None
    return proc, int(ready["port"])


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-apps", type=int, default=8)
    ap.add_argument("--families", type=int, default=2)
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--minutes", type=int, default=3)
    ap.add_argument("--limit", type=int, default=300,
                    help="arrivals to route (keeps the smoke fast)")
    ap.add_argument("--kill-leader-at", type=int, default=None,
                    help="0-based route call at which the chaos "
                         "router_loss fires (default: limit // 2)")
    ap.add_argument("--timeout", type=float, default=120.0)
    args = ap.parse_args()
    kill_at = (args.limit // 2 if args.kill_leader_at is None
               else args.kill_leader_at)

    # every node deploys every app: the leader kill must not strand an
    # app without an advertiser, and the spread still exercises the
    # sharing-aware placement across all three
    apps = [f"app{i:02d}" for i in range(args.n_apps)]
    node_ids = ["nodeA", "nodeB", "nodeC"]
    nodes: list = []
    failures: list[str] = []
    out = os.path.join(tempfile.mkdtemp(prefix="cluster-smoke-"),
                       "cluster_summary.json")
    try:
        ports: dict[str, int] = {}
        for node_id in node_ids:
            proc, port = _spawn_node(node_id, apps, args)
            nodes.append((node_id, proc))
            ports[node_id] = port
        print("cluster-smoke: "
              + " ".join(f"{n}:{p}" for n, p in ports.items())
              + " up")

        route = subprocess.run(
            [sys.executable, "-m", "repro", "cluster", "route",
             "--nodes", ",".join(f"{n}=127.0.0.1:{p}"
                                 for n, p in ports.items()),
             "--n-apps", str(args.n_apps),
             "--families", str(args.families),
             "--seed", str(args.seed),
             "--minutes", str(args.minutes),
             "--limit", str(args.limit),
             "--ha", "--kill-leader-at", str(kill_at),
             "--check", "--out", out],
            cwd=REPO, env=_env(), capture_output=True, text=True,
            timeout=args.timeout)
        if route.returncode != 0:
            failures.append(f"route exited {route.returncode}:\n"
                            f"{route.stdout}\n{route.stderr}")

        for name, proc in nodes:
            try:
                proc.wait(timeout=args.timeout)
            except subprocess.TimeoutExpired:
                proc.kill()
                failures.append(f"{name}: did not exit after drain")
                continue
            if proc.returncode != 0:
                failures.append(f"{name}: exited {proc.returncode}")

        if not os.path.exists(out):
            failures.append("no cluster_summary artifact written")
        else:
            with open(out) as fh:
                payload = json.load(fh)  # flat artifact envelope
            requests = payload.get("requests", 0)
            conserve = payload.get("conservation", {})
            ha = payload.get("ha", {})
            print(f"cluster-smoke: requests={requests} "
                  f"served={payload.get('served')} "
                  f"failovers={ha.get('failovers')} "
                  f"leader={ha.get('leader')} "
                  f"epoch={ha.get('epoch')} "
                  f"conservation="
                  f"{'holds' if conserve.get('holds') else 'BROKEN'}")
            if requests <= 0:
                failures.append("router admitted zero requests")
            if not conserve.get("holds"):
                failures.append(f"conservation broken: {conserve}")
            if ha.get("failovers") != 1:
                failures.append(
                    f"expected exactly one leader failover, got "
                    f"{ha.get('failovers')!r}")
            elections = ha.get("elections", [])
            if not any(e.get("won") and e.get("epoch", 0) > 1
                       for e in elections):
                failures.append(
                    f"no epoch-bumped election won after the leader "
                    f"kill: {elections}")
    finally:
        for _name, proc in nodes:
            if proc.poll() is None:
                proc.kill()

    if failures:
        print("cluster-smoke: FAIL", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("cluster-smoke: OK — standby finished a leader-killed "
          "replay over three nodes with global conservation")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
