#!/usr/bin/env python3
"""CI smoke for the observability surface (fast tier).

Boots ``python -m repro fleet serve --sim --stdin --metrics-port 0``,
feeds it a handful of invocations over the JSONL control channel, then
checks the whole exported surface end to end:

* the ready line carries a ``metrics_url``;
* ``GET /metrics`` parses as Prometheus text format 0.0.4 and passes
  :func:`repro.obs.metrics.validate_exposition` (TYPE lines, +Inf
  buckets, monotone cumulative histogram counts);
* the scraped ``repro_requests_total`` total matches the requests the
  daemon's own ``stats`` reply reports;
* the ``stats`` reply carries a ``repro.metrics/1`` registry snapshot;
* the drain summary keeps the conservation invariant
  (``requests == served + sheds + flushed + errors``) and its
  ``shed_reasons`` breakdown sums to ``sheds``.

Exit 0 on success, 1 on any failure (with a named check per line).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import urllib.request

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

FAILURES: list[str] = []


def check(name: str, ok: bool, detail: str = "") -> None:
    status = "ok" if ok else "FAIL"
    print(f"[{status}] {name}" + (f": {detail}" if detail else ""))
    if not ok:
        FAILURES.append(name)


def main() -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro", "fleet", "serve", "--sim",
         "--stdin", "--apps", "alpha,beta", "--metrics-port", "0",
         "--log-json"],
        stdin=subprocess.PIPE, stdout=subprocess.PIPE,
        stderr=subprocess.PIPE, text=True, env=env, cwd=REPO)
    try:
        # stderr carries structured log lines too — scan for the ready
        # event rather than assuming it comes first
        ready = {}
        for _ in range(20):
            line = proc.stderr.readline()
            if not line:
                break
            try:
                evt = json.loads(line)
            except ValueError:
                continue
            if evt.get("event") == "ready":
                ready = evt
                break
        check("ready-event", ready.get("event") == "ready",
              json.dumps(ready))
        url = ready.get("metrics_url", "")
        check("metrics-url", url.startswith("http://"), url)

        for i in range(10):
            proc.stdin.write(json.dumps(
                {"app": "alpha" if i % 2 else "beta"}) + "\n")
        proc.stdin.write(json.dumps({"cmd": "stats"}) + "\n")
        proc.stdin.flush()
        replies = [json.loads(proc.stdout.readline())
                   for _ in range(11)]
        stats_reply = replies[-1]
        check("submits-acked",
              all(r.get("ok") for r in replies[:-1]),
              f"{sum(bool(r.get('ok')) for r in replies[:-1])}/10")
        snap = stats_reply.get("metrics", {})
        check("stats-carries-metrics",
              snap.get("schema") == "repro.metrics/1",
              f"schema={snap.get('schema')!r}")

        with urllib.request.urlopen(url, timeout=5) as resp:
            ctype = resp.headers.get("Content-Type", "")
            text = resp.read().decode()
        check("content-type", "version=0.0.4" in ctype, ctype)

        from repro.obs.metrics import parse_exposition, validate_exposition
        problems = validate_exposition(text)
        check("exposition-valid", not problems, "; ".join(problems[:3]))
        parsed = parse_exposition(text)
        total = sum(v for n, labels, v in parsed["samples"]
                    if n == "repro_requests_total")
        daemon_requests = stats_reply["stats"]["requests"]
        check("requests-counter", total == daemon_requests == 10,
              f"scraped={total} daemon={daemon_requests}")

        proc.stdin.write(json.dumps({"cmd": "shutdown"}) + "\n")
        proc.stdin.flush()
        proc.stdin.close()
        summary = None
        for line in proc.stdout:
            evt = json.loads(line)
            if evt.get("event") == "summary":
                summary = evt["summary"]
        check("summary-emitted", summary is not None)
        if summary is not None:
            lhs = summary["requests"]
            rhs = (summary["served"] + summary["sheds"]
                   + summary["flushed"] + summary.get("errors", 0))
            check("conservation", lhs == rhs, f"{lhs} == {rhs}")
            reasons = summary.get("shed_reasons", {})
            check("shed-breakdown",
                  sum(reasons.values()) == summary["sheds"],
                  f"{reasons} vs sheds={summary['sheds']}")
        proc.wait(timeout=20)
        check("clean-exit", proc.returncode == 0,
              f"rc={proc.returncode}")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()
    if FAILURES:
        print(f"obs smoke: FAIL ({', '.join(FAILURES)})")
        return 1
    print("obs smoke: PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
