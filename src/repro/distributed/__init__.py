"""Distribution substrate: logical-axis sharding rules, mesh helpers."""

from repro.distributed.sharding import (  # noqa: F401
    DEFAULT_RULES, ShardingRules, batch_pspec, cache_pspecs, opt_pspecs,
    param_pspecs, param_shardings, resolve_axes,
)
