"""Logical-axis sharding rules (MaxText-style) for the whole zoo.

Parameters carry *logical* axis names from their ``ParamSpec`` (see
``repro.models.layers``); this module resolves them to mesh axes under a
rule table, with two safety properties:

* a mesh axis is used at most once per array (first logical dim wins);
* a dim is only sharded if its size divides the mesh-axis extent —
  otherwise it silently falls back to replication (e.g. granite-moe's
  vocab 49155 and whisper's 51866 are not 16-divisible and replicate,
  while qwen/gemma vocabs row-shard).

This keeps every assigned config compilable on the production meshes
without per-arch special cases, while giving TP/EP/DP/SP where shapes
allow.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.model import logical_axes, layer_layout

MeshAxes = Union[None, str, tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes)."""
    rules: dict[str, MeshAxes]

    def get(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        return self.rules.get(name)


# Default TP-over-"model", DP-over-("pod","data") layout.
DEFAULT_RULES = ShardingRules({
    "batch": ("pod", "data"),
    "vocab": "model",       # row-sharded embeddings / logits
    "embed": None,          # d_model replicated
    "heads": "model",       # fused H*hd projections (always divisible)
    "kv_heads": "model",    # fused K*hd projections
    "ff": "model",          # MLP inner dim
    "experts": "model",     # expert parallelism
    "layers": None,         # scan dim
    "seq": "model",         # sequence-parallel KV caches (decode)
})


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def _present(mesh: Mesh, axes: MeshAxes) -> Optional[MeshAxes]:
    """Drop mesh axes that don't exist in this mesh (e.g. 'pod' on the
    single-pod mesh)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.shape else None
    kept = tuple(a for a in axes if a in mesh.shape)
    return kept if kept else None


def resolve_axes(shape, log_axes, rules: ShardingRules, mesh: Mesh) -> P:
    """Resolve one array's logical axes to a PartitionSpec."""
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, log_axes):
        axes = _present(mesh, rules.get(name))
        if axes is None:
            out.append(None)
            continue
        tup = (axes,) if isinstance(axes, str) else axes
        if any(a in used for a in tup):
            out.append(None)
            continue
        size = _axis_size(mesh, tup)
        if size <= 1 or dim % size != 0:
            out.append(None)
            continue
        used.update(tup)
        out.append(axes if isinstance(axes, str) else tuple(tup))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_pspecs(cfg: ArchConfig, mesh: Mesh,
                 rules: ShardingRules = DEFAULT_RULES):
    """PartitionSpec pytree mirroring ``init_params``."""
    from repro.models.model import model_template
    from repro.models.layers import ParamSpec

    def spec(s: ParamSpec) -> P:
        return resolve_axes(s.shape, s.axes, rules, mesh)

    return jax.tree.map(spec, model_template(cfg),
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_shardings(cfg: ArchConfig, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES):
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        param_pspecs(cfg, mesh, rules),
                        is_leaf=lambda x: isinstance(x, P))


def opt_pspecs(cfg: ArchConfig, mesh: Mesh,
               rules: ShardingRules = DEFAULT_RULES):
    """ZeRO-1 sharding for AdamW state (mirrors AdamWState).

    Each fp32 master/mu/nu tensor takes its parameter's spec plus the
    "data" axis on the first still-replicated dim that divides — so the
    3x-fp32 optimizer memory scales with the whole mesh, not just TP.
    """
    from repro.training.adamw import AdamWState

    data = _present(mesh, "data")
    dsize = _axis_size(mesh, data)

    def zero1(spec: P, shape) -> P:
        if data is None or dsize <= 1:
            return spec
        out = list(spec) + [None] * (len(shape) - len(spec))
        for i, (dim, cur) in enumerate(zip(shape, out)):
            if cur is None and dim % dsize == 0:
                out[i] = data
                break
        return P(*out)

    from repro.models.model import model_template
    from repro.models.layers import ParamSpec as PS

    tmpl = model_template(cfg)
    pspecs = param_pspecs(cfg, mesh, rules)
    flat_t = jax.tree.leaves(tmpl, is_leaf=lambda x: isinstance(x, PS))
    flat_p, tdef = jax.tree.flatten(pspecs,
                                    is_leaf=lambda x: isinstance(x, P))
    z = tdef.unflatten([zero1(p, t.shape)
                        for p, t in zip(flat_p, flat_t)])
    return AdamWState(step=P(), master=z, mu=z, nu=z)


def batch_pspec(mesh: Mesh, rules: ShardingRules = DEFAULT_RULES,
                batch_size: Optional[int] = None,
                extra_dims: int = 1) -> P:
    """Batch-leading activation spec: (batch, ...) -> P(dp_axes, ...)."""
    axes = _present(mesh, rules.get("batch"))
    if axes is not None and batch_size is not None:
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        # fall back to fewer axes (or none) when batch doesn't divide
        while tup and batch_size % _axis_size(mesh, tup) != 0:
            tup = tup[1:]
        axes = tup if tup else None
    return P(axes, *([None] * extra_dims))


def cache_pspecs(cfg: ArchConfig, mesh: Mesh, batch: int, cache_len: int,
                 rules: ShardingRules = DEFAULT_RULES,
                 stacked: bool = True):
    """PartitionSpec pytree mirroring ``init_cache``.

    KV caches are sharded over batch plus — for the long-context decode
    cells — one more axis: kv_heads when divisible by the model axis,
    otherwise the sequence dim (XLA inserts the softmax/psum collectives
    for sequence-parallel attention).  Recurrent states shard over batch
    and, where divisible, the channel dim.
    """
    from repro.models.model import init_cache

    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))
    model_size = _axis_size(mesh, _present(mesh, "model"))
    dp = batch_pspec(mesh, rules, batch_size=batch, extra_dims=0)
    dp_axes = dp[0] if len(dp) else None
    kv_on_heads = cfg.n_kv_heads % model_size == 0 if model_size > 1 \
        else False

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(leaf.shape)
        # leading layer-stack dim (scan groups) is never a mesh axis
        lead = (None,) if (stacked and nd and _is_scan_path(path)) else ()
        body: list = [None] * (nd - len(lead))
        # batch dim is always right after the optional layer-stack dim
        if body:
            body[0] = dp_axes
        if name in ("k", "v", "cross_k", "cross_v") and nd - len(lead) == 4:
            if kv_on_heads:
                body[2] = "model"
            else:
                body[1] = "model" if leaf.shape[len(lead) + 1] % \
                    max(model_size, 1) == 0 and model_size > 1 else body[1]
        elif name in ("k_scale", "v_scale") and nd - len(lead) == 3:
            if not kv_on_heads and leaf.shape[len(lead) + 1] % \
                    max(model_size, 1) == 0 and model_size > 1:
                body[1] = "model"  # follow the seq-sharded codes
        elif name == "pos":
            pass  # (B, S) int32 — replicate the tiny position index
        elif name in ("h", "conv") and nd - len(lead) >= 2:
            if leaf.shape[-1] % max(model_size, 1) == 0 and model_size > 1:
                body[-1] = "model"
        return P(*(list(lead) + body)) if lead else P(*body)

    return jax.tree_util.tree_map_with_path(spec_for, shapes)


def _n_periods(cfg: ArchConfig) -> int:
    return layer_layout(cfg)[1]


def _is_scan_path(path) -> bool:
    return any(getattr(p, "key", None) in ("scan", "rem_scan")
               for p in path)
