"""Cluster HA: replicated routers, lease elections, retry policy.

PR 8's :class:`~repro.cluster.router.ClusterRouter` is a single point
of failure: when the router process dies, its ledger (placement map,
per-node admission counts, migration history) dies with it and the
conservation invariant ``requests == served + sheds + flushed + errors
+ abandoned`` can no longer be *demonstrated*, even though the node
agents kept every count.  This module closes that gap with three
pieces, all over the existing frame protocol — no new wire format, no
external coordination service:

**RetryPolicy** — one object for every remote call the cluster tier
makes: bounded attempts, per-call socket timeout, an overall deadline,
and jittered exponential backoff between attempts.  Errors are classed
retryable (connection resets, timeouts, clean EOFs — the transient
family) vs terminal (:class:`FrameError` desyncs, logic errors).
Invocation frames are deliberately **not** resent by the policy: a
lost *reply* after the node admitted the request would double-admit on
resend and silently break conservation — the router's failover loop
(re-place, route to the new owner) is the only retry an invocation
gets.  Idempotent control commands (``hello``, ``stats``, ``lease``,
``rewarm``) may opt in to transparent resend.

**Lease election** — node agents double as stdlib-only lease
witnesses (:class:`LeaseWitness`, served under the ``lease`` command).
A router holds leadership while a majority of witnesses grant it the
lease for the current epoch; a standby takes over by bumping the epoch
and winning a majority (:func:`elect`).  Epochs fence zombies: once a
witness has granted epoch *e*, it rejects acquires and renews for any
epoch below *e*, so a partitioned old leader cannot win its lease back
after a successor is elected.

**Ledger replication** — the leader streams its ledger to standbys:
one snapshot frame on connect, then an incremental entry per state
change (:class:`LedgerReplicator` serving, :class:`StandbyRouter`
tailing).  Promotion (:meth:`StandbyRouter.promote`) wins the
election, rebuilds live node clients, and *reconciles* the replicated
``routed_by_node`` counts against each node's own admission counters
(shipped in the extended ``hello`` reply) — node ledgers are ground
truth, so an entry lost in flight at the instant the leader died
cannot leave the promoted router's ledger out of step.

:class:`ReplicatedRouter` packages the whole arrangement (leader +
warm standby + lease heartbeat) behind the plain router surface and
gives the chaos tier its ``election`` site: a ``router_loss`` fault
halts the leader abruptly mid-replay and the standby must finish the
replay with conservation intact.
"""

from __future__ import annotations

import random
import socket
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.obs.log import get_logger
from repro.pool.chaos import RouterLossFault
from repro.cluster.protocol import (FrameClosed, FrameError, recv_frame,
                                    send_frame)

_LOG = get_logger("cluster.ha")

__all__ = [
    "ElectionLost",
    "LeaseWitness",
    "LedgerReplicator",
    "ReplicatedRouter",
    "RetryExhausted",
    "RetryPolicy",
    "StandbyRouter",
    "add_retry_flags",
    "elect",
    "empty_ledger",
    "lease_call",
]


def _reg():
    from repro.obs.metrics import default_registry
    return default_registry()


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------

class RetryExhausted(ConnectionError):
    """Every allowed attempt failed with a retryable error (the last
    one is chained as ``__cause__``).  A :class:`ConnectionError`
    subclass so existing failover paths treat exhaustion like any
    other dead connection."""


@dataclass(frozen=True)
class RetryPolicy:
    """Retry/timeout/backoff policy for the cluster's remote calls.

    ``attempts`` bounds tries per operation; ``deadline_s`` bounds the
    operation's total wall time including backoff sleeps (whichever is
    hit first ends the retry loop).  Backoff is exponential from
    ``backoff_base_s``, capped at ``backoff_cap_s``, with a
    multiplicative jitter of ±``jitter``/2 (seedable for deterministic
    tests).  ``call_timeout_s`` is the per-frame socket timeout,
    ``connect_timeout_s`` the per-attempt connect timeout.
    """

    attempts: int = 3
    call_timeout_s: float = 10.0
    connect_timeout_s: float = 5.0
    deadline_s: float = 30.0
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    jitter: float = 0.5
    seed: Optional[int] = None

    def __post_init__(self) -> None:
        if self.attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {self.attempts}")
        for name in ("call_timeout_s", "connect_timeout_s", "deadline_s",
                     "backoff_base_s", "backoff_cap_s"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")

    # ------------------------------------------------------------ classing
    @staticmethod
    def retryable(exc: BaseException) -> bool:
        """Transient transport failures retry; protocol desyncs
        (:class:`FrameError`) and logic errors are terminal."""
        if isinstance(exc, FrameError):
            return False
        return isinstance(exc, (OSError, FrameClosed))

    # ------------------------------------------------------------- backoff
    def backoff_s(self, attempt: int,
                  rng: Optional[random.Random] = None) -> float:
        """Sleep before retry number ``attempt`` (0-based)."""
        base = min(self.backoff_base_s * (2 ** attempt),
                   self.backoff_cap_s)
        if base <= 0 or self.jitter <= 0:
            return base
        r = (rng or random).random()
        return base * (1.0 - self.jitter / 2.0 + self.jitter * r)

    def rng(self) -> Optional[random.Random]:
        return random.Random(self.seed) if self.seed is not None else None

    # ----------------------------------------------------------- execution
    def run(self, fn: Callable[[], dict], *, what: str = "call",
            sleep: Callable[[float], None] = time.sleep):
        """Call ``fn`` under the policy: retry retryable failures with
        backoff until ``attempts`` or ``deadline_s`` runs out, then
        raise :class:`RetryExhausted` chained to the last error.
        Terminal errors propagate immediately."""
        rng = self.rng()
        deadline = time.monotonic() + self.deadline_s
        last: Optional[BaseException] = None
        for attempt in range(self.attempts):
            try:
                return fn()
            except Exception as exc:
                if not self.retryable(exc):
                    raise
                last = exc
                if attempt + 1 >= self.attempts:
                    break
                delay = self.backoff_s(attempt, rng)
                if time.monotonic() + delay >= deadline:
                    break
                sleep(delay)
        raise RetryExhausted(
            f"{what} failed after {self.attempts} attempt(s): "
            f"{last!r}") from last

    # ---------------------------------------------------------------- CLI
    def to_dict(self) -> dict:
        return {"attempts": self.attempts,
                "call_timeout_s": self.call_timeout_s,
                "connect_timeout_s": self.connect_timeout_s,
                "deadline_s": self.deadline_s,
                "backoff_base_s": self.backoff_base_s,
                "backoff_cap_s": self.backoff_cap_s,
                "jitter": self.jitter}

    @classmethod
    def from_args(cls, args) -> "RetryPolicy":
        """Build from the ``--retry-*`` namespace attributes installed
        by :func:`add_retry_flags` (missing attributes keep their
        defaults)."""
        d = cls()
        return cls(
            attempts=getattr(args, "retry_attempts", d.attempts),
            call_timeout_s=getattr(args, "retry_call_timeout_s",
                                   d.call_timeout_s),
            connect_timeout_s=getattr(args, "retry_connect_timeout_s",
                                      d.connect_timeout_s),
            deadline_s=getattr(args, "retry_deadline_s", d.deadline_s),
            backoff_base_s=getattr(args, "retry_backoff_s",
                                   d.backoff_base_s),
            backoff_cap_s=getattr(args, "retry_backoff_cap_s",
                                  d.backoff_cap_s),
        )


def add_retry_flags(parser) -> None:
    """Install the ``--retry-*`` flags mirroring
    :class:`RetryPolicy`'s fields on an argparse parser."""
    d = RetryPolicy()
    parser.add_argument("--retry-attempts", type=int,
                        default=d.attempts, metavar="N",
                        help="max attempts per remote call "
                             f"(default {d.attempts})")
    parser.add_argument("--retry-call-timeout-s", type=float,
                        default=d.call_timeout_s, metavar="S",
                        help="per-call socket timeout "
                             f"(default {d.call_timeout_s})")
    parser.add_argument("--retry-connect-timeout-s", type=float,
                        default=d.connect_timeout_s, metavar="S",
                        help="per-attempt connect timeout "
                             f"(default {d.connect_timeout_s})")
    parser.add_argument("--retry-deadline-s", type=float,
                        default=d.deadline_s, metavar="S",
                        help="overall per-operation deadline "
                             f"(default {d.deadline_s})")
    parser.add_argument("--retry-backoff-s", type=float,
                        default=d.backoff_base_s, metavar="S",
                        help="base backoff between attempts "
                             f"(default {d.backoff_base_s})")
    parser.add_argument("--retry-backoff-cap-s", type=float,
                        default=d.backoff_cap_s, metavar="S",
                        help="backoff ceiling "
                             f"(default {d.backoff_cap_s})")


# ---------------------------------------------------------------------------
# Lease witness + election
# ---------------------------------------------------------------------------

class LeaseWitness:
    """One node agent's vote in the leader election.

    Pure stdlib state machine over the monotonic clock: at most one
    live (holder, epoch) at a time; a grant lasts ``ttl_s`` unless
    renewed.  Epochs fence: once epoch *e* is granted, acquires and
    renews below *e* are rejected forever — a deposed leader cannot
    talk its way back in with its stale epoch.
    """

    def __init__(self, node_id: str,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.node_id = node_id
        self._clock = clock
        self._lock = threading.Lock()
        self.holder: Optional[str] = None
        self.epoch = 0
        self.expires_t = 0.0
        self.grants = 0
        self.rejections = 0

    def _expired(self, now: float) -> bool:
        return self.holder is None or now >= self.expires_t

    def handle(self, evt: dict) -> dict:
        """Serve one ``{"cmd": "lease", ...}`` frame body."""
        op = evt.get("op", "acquire")
        router = str(evt.get("router", ""))
        epoch = int(evt.get("epoch", 0))
        ttl_s = float(evt.get("ttl_s", 5.0))
        now = self._clock()
        with self._lock:
            if op == "release":
                if self.holder == router and epoch >= self.epoch:
                    self.holder = None
                    self.expires_t = now
                return self._state(now, granted=True)
            if epoch < self.epoch:  # fenced: a newer epoch was granted
                self.rejections += 1
                return self._state(now, granted=False)
            if op == "renew":
                ok = (self.holder == router and epoch == self.epoch
                      and not self._expired(now))
            else:  # acquire
                ok = (self._expired(now) or self.holder == router
                      or epoch > self.epoch)
            if ok:
                self.holder = router
                self.epoch = epoch
                self.expires_t = now + ttl_s
                self.grants += 1
            else:
                self.rejections += 1
            return self._state(now, granted=ok)

    def _state(self, now: float, *, granted: bool) -> dict:
        return {"granted": granted, "holder": self.holder,
                "epoch": self.epoch,
                "expires_in_s": round(max(self.expires_t - now, 0.0), 3)}

    def state(self) -> dict:
        with self._lock:
            return self._state(self._clock(), granted=False) | {
                "grants": self.grants, "rejections": self.rejections}


class ElectionLost(RuntimeError):
    """A majority of lease witnesses did not grant the epoch."""


def lease_call(client, *, op: str, router_id: str, epoch: int,
               ttl_s: float) -> dict:
    """One lease RPC against a node agent's witness; transport errors
    surface to the caller (an unreachable witness is an abstention)."""
    return client.call({"cmd": "lease", "op": op, "router": router_id,
                        "epoch": epoch, "ttl_s": ttl_s},
                       idempotent=True)


def elect(clients: dict, *, router_id: str, epoch: int,
          ttl_s: float = 5.0, op: str = "acquire") -> dict:
    """Ask every witness for the lease at ``epoch``; leadership needs
    a strict majority of the *configured* witness set (unreachable
    witnesses count against, not for — a partitioned minority cannot
    elect itself)."""
    granted, voters = 0, len(clients)
    replies: dict[str, dict] = {}
    for node_id, client in sorted(clients.items()):
        try:
            reply = lease_call(client, op=op, router_id=router_id,
                               epoch=epoch, ttl_s=ttl_s)
        except (OSError, FrameClosed, FrameError) as exc:
            replies[node_id] = {"granted": False, "error": repr(exc)}
            continue
        replies[node_id] = reply
        if reply.get("granted"):
            granted += 1
    won = granted > voters // 2
    _reg().counter("repro_cluster_ha_elections_total",
                   "lease elections held, by outcome",
                   labels=("outcome",)).labels(
        outcome="won" if won else "lost").inc()
    _LOG.info("election", router=router_id, epoch=epoch, op=op,
              granted=granted, witnesses=voters, won=won)
    return {"router": router_id, "epoch": epoch, "op": op,
            "granted": granted, "witnesses": voters, "won": won,
            "replies": replies}


# ---------------------------------------------------------------------------
# Ledger replication (leader side)
# ---------------------------------------------------------------------------

def empty_ledger(epoch: int = 0) -> dict:
    """The replicated-ledger shape (what a snapshot frame carries)."""
    return {"epoch": epoch, "placement": {}, "routed_by_node": {},
            "router_sheds": 0, "migrations": [], "lost_nodes": [],
            "departed": [], "node_payloads": {}, "node_samples": {}}


def apply_ledger_entry(ledger: dict, entry: dict) -> None:
    """Fold one replicated entry into a ledger dict (shared by the
    standby tail and tests so the two sides cannot drift)."""
    k = entry.get("k")
    if k == "route":
        n = entry["node"]
        ledger["routed_by_node"][n] = \
            ledger["routed_by_node"].get(n, 0) + 1
    elif k == "shed":
        ledger["router_sheds"] += 1
    elif k == "migration":
        m = entry["m"]
        ledger["migrations"].append(dict(m))
        ledger["placement"][m["app"]] = m["to"]
    elif k == "place":
        ledger["placement"][entry["app"]] = entry["node"]
    elif k == "unplace":
        ledger["placement"].pop(entry["app"], None)
    elif k == "lost":
        if entry["node"] not in ledger["lost_nodes"]:
            ledger["lost_nodes"].append(entry["node"])
    elif k == "departed":
        if entry["node"] not in ledger["departed"]:
            ledger["departed"].append(entry["node"])
    elif k == "harvest":
        ledger["node_payloads"][entry["node"]] = entry.get("summary") or {}
        ledger["node_samples"][entry["node"]] = [
            float(x) for x in entry.get("samples") or []]
    elif k == "epoch":
        ledger["epoch"] = int(entry["epoch"])
    # unknown kinds are ignored: replication is forward-compatible


class LedgerReplicator:
    """The leader's replication server: every connecting standby first
    gets a snapshot frame (cut under the publish lock, so no entry can
    fall between snapshot and stream), then the live entry stream.
    Slow standbys never block routing: entries go through a per-
    connection queue drained by a writer thread, and a standby that
    stops reading is dropped, not waited on."""

    _STOP = object()

    def __init__(self, snapshot_fn: Callable[[], dict], *,
                 host: str = "127.0.0.1", port: int = 0) -> None:
        self._snapshot_fn = snapshot_fn
        self._lock = threading.Lock()
        self._seq = 0
        self._conns: list[tuple[socket.socket, "_Queue"]] = []
        self._stopped = False
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen(8)
        self.host, self.port = self._srv.getsockname()[:2]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="ledger-replicator",
            daemon=True)
        self._accept_thread.start()

    @property
    def standbys(self) -> int:
        with self._lock:
            return len(self._conns)

    def _accept_loop(self) -> None:
        while True:
            try:
                sock, _peer = self._srv.accept()
            except OSError:
                return  # server socket closed: replicator stopping
            q = _Queue()
            with self._lock:
                if self._stopped:
                    sock.close()
                    return
                # snapshot under the lock: publishes are serialized
                # against it, so the stream resumes exactly after seq
                try:
                    send_frame(sock, {"event": "snapshot",
                                      "seq": self._seq,
                                      "ledger": self._snapshot_fn()})
                except OSError:
                    sock.close()
                    continue
                self._conns.append((sock, q))
            threading.Thread(target=self._writer, args=(sock, q),
                             name="ledger-writer", daemon=True).start()
            _LOG.info("standby-attached", port=self.port,
                      standbys=self.standbys)

    def publish(self, entry: dict) -> None:
        with self._lock:
            if self._stopped:
                return
            self._seq += 1
            frame = {"event": "entry", "seq": self._seq, **entry}
            for _sock, q in self._conns:
                q.put(frame)

    def _writer(self, sock: socket.socket, q: "_Queue") -> None:
        while True:
            item = q.get()
            if item is self._STOP:
                break
            try:
                send_frame(sock, item)
            except OSError:
                break  # standby gone; drop it
        with self._lock:
            self._conns = [(s, cq) for s, cq in self._conns
                           if s is not sock]
        try:
            sock.close()
        except OSError:
            pass

    def stop(self, *, abrupt: bool = False) -> None:
        """``abrupt=True`` models leader death: sockets die mid-stream
        with no goodbye, which is exactly what a tailing standby must
        treat as leader loss."""
        with self._lock:
            self._stopped = True
            conns = list(self._conns)
        try:
            self._srv.close()
        except OSError:
            pass
        for sock, q in conns:
            if abrupt:
                try:
                    sock.close()
                except OSError:
                    pass
            q.put(self._STOP)


class _Queue:
    """Tiny unbounded thread-safe FIFO (condvar + list); avoids
    importing queue for two methods."""

    def __init__(self) -> None:
        self._items: list = []
        self._cond = threading.Condition()

    def put(self, item) -> None:
        with self._cond:
            self._items.append(item)
            self._cond.notify()

    def get(self):
        with self._cond:
            while not self._items:
                self._cond.wait()
            return self._items.pop(0)


# ---------------------------------------------------------------------------
# Standby router (tail + promote)
# ---------------------------------------------------------------------------

class StandbyRouter:
    """A warm standby: tails the leader's ledger stream and can be
    promoted to a live :class:`~repro.cluster.router.ClusterRouter`
    when the leader dies.

    ``node_addrs`` maps node id -> ``(host, port)`` — the full witness
    set.  Promotion wins a majority lease election at ``last seen
    epoch + 1``, rebuilds node clients, and reconciles the replicated
    ``routed_by_node`` against each live node's admission counters
    from the extended ``hello`` reply (node ledgers are ground truth
    for anything that was in flight when the leader died).
    """

    def __init__(self, router_id: str, leader_addr: tuple,
                 node_addrs: dict[str, tuple], *,
                 strategy: str = "sharing",
                 hot_sets: Optional[dict[str, list[str]]] = None,
                 seed: int = 0,
                 retry: Optional[RetryPolicy] = None,
                 lease_ttl_s: float = 5.0,
                 fault_hook=None) -> None:
        self.router_id = router_id
        self.leader_addr = tuple(leader_addr)
        self.node_addrs = {n: tuple(a) for n, a in node_addrs.items()}
        self.strategy = strategy
        self.hot_sets = dict(hot_sets or {})
        self.seed = seed
        self.retry = retry or RetryPolicy()
        self.lease_ttl_s = lease_ttl_s
        self.fault_hook = fault_hook
        self.ledger = empty_ledger()
        self.seq = 0
        self.gaps = 0
        self.synced = threading.Event()
        self.leader_lost = threading.Event()
        self.last_election: Optional[dict] = None
        self._lock = threading.Lock()
        self._sock: Optional[socket.socket] = None
        self._thread: Optional[threading.Thread] = None

    # --------------------------------------------------------------- tail
    def start(self) -> "StandbyRouter":
        host, port = self.leader_addr
        self._sock = self.retry.run(
            lambda: socket.create_connection(
                (host, port), timeout=self.retry.connect_timeout_s),
            what=f"standby {self.router_id} connect to leader")
        self._sock.settimeout(None)  # the tail blocks until frames come
        self._thread = threading.Thread(
            target=self._tail, name=f"standby-{self.router_id}",
            daemon=True)
        self._thread.start()
        return self

    def wait_synced(self, timeout_s: float = 10.0) -> bool:
        return self.synced.wait(timeout=timeout_s)

    def _tail(self) -> None:
        sock = self._sock
        while True:
            try:
                frame = recv_frame(sock)
            except (OSError, FrameClosed, FrameError):
                self.leader_lost.set()
                _LOG.warning("leader-lost", standby=self.router_id,
                             seq=self.seq)
                return
            with self._lock:
                if frame.get("event") == "snapshot":
                    self.ledger = frame.get("ledger") or empty_ledger()
                    self.seq = int(frame.get("seq", 0))
                    self.synced.set()
                elif frame.get("event") == "entry":
                    seq = int(frame.get("seq", 0))
                    if seq != self.seq + 1:
                        self.gaps += 1
                    self.seq = seq
                    apply_ledger_entry(self.ledger, frame)

    def ledger_copy(self) -> dict:
        import copy
        with self._lock:
            return copy.deepcopy(self.ledger)

    def stop(self) -> None:
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    # ------------------------------------------------------------ promote
    def promote(self, *, epoch: Optional[int] = None):
        """Win the election and resume routing from the replica.
        Returns a live :class:`ClusterRouter`; raises
        :class:`ElectionLost` without touching placement if a majority
        of witnesses refuses (a newer epoch is already out there)."""
        from repro.cluster.router import ClusterRouter, NodeClient
        self.stop()
        ledger = self.ledger_copy()
        epoch = (int(ledger.get("epoch", 0)) + 1
                 if epoch is None else epoch)
        gone = set(ledger.get("lost_nodes", ())) \
            | set(ledger.get("departed", ()))
        clients = {
            node_id: NodeClient(node_id, host, port, retry=self.retry)
            for node_id, (host, port) in sorted(self.node_addrs.items())
            if node_id not in gone}
        result = elect(clients, router_id=self.router_id, epoch=epoch,
                       ttl_s=self.lease_ttl_s)
        self.last_election = result
        if not result["won"]:
            for c in clients.values():
                c.close()
            raise ElectionLost(
                f"standby {self.router_id} lost the election for "
                f"epoch {epoch}: {result['granted']}/"
                f"{result['witnesses']} grants")
        router = ClusterRouter.resume(
            clients, ledger=ledger, router_id=self.router_id,
            epoch=epoch, strategy=self.strategy,
            hot_sets=self.hot_sets, seed=self.seed, retry=self.retry,
            fault_hook=self.fault_hook)
        _reg().counter("repro_cluster_ha_promotions_total",
                       "standby routers promoted to leader").inc()
        _LOG.info("promoted", router=self.router_id, epoch=epoch,
                  nodes=len(clients), seq=self.seq)
        return router


# ---------------------------------------------------------------------------
# The HA coordinator: leader + warm standby behind the router surface
# ---------------------------------------------------------------------------

class ReplicatedRouter:
    """Leader + warm standby packaged behind the plain router surface.

    ``connect()`` elects the leader (epoch 1) against the node-agent
    witnesses, starts ledger replication, and attaches the standby.
    ``route()`` heartbeats the lease on a ``lease_ttl_s / 3`` cadence
    and exposes the chaos ``election`` site: an injected
    ``router_loss`` fault halts the leader abruptly (dead sockets, no
    drain, no goodbye) and promotes the standby before the arrival is
    routed — so the arrival that observed the crash is also the first
    one the new leader serves.
    """

    def __init__(self, node_addrs: dict[str, tuple], *,
                 strategy: str = "sharing",
                 hot_sets: Optional[dict[str, list[str]]] = None,
                 seed: int = 0,
                 retry: Optional[RetryPolicy] = None,
                 router_id: str = "router-a",
                 standby_id: str = "router-b",
                 lease_ttl_s: float = 5.0,
                 fault_hook=None) -> None:
        self.node_addrs = {n: tuple(a) for n, a in node_addrs.items()}
        self.strategy = strategy
        self.hot_sets = dict(hot_sets or {})
        self.seed = seed
        self.retry = retry or RetryPolicy()
        self.router_id = router_id
        self.standby_id = standby_id
        self.lease_ttl_s = lease_ttl_s
        self.fault_hook = fault_hook
        self.leader = None
        self.standby: Optional[StandbyRouter] = None
        self.failovers = 0
        self.elections: list[dict] = []
        self.lease_renewals = 0
        self.lease_denials = 0
        self._last_renew_t = time.monotonic()

    # ------------------------------------------------------------ topology
    def connect(self) -> dict[str, str]:
        from repro.cluster.router import ClusterRouter, NodeClient
        clients = {
            node_id: NodeClient(node_id, host, port, retry=self.retry)
            for node_id, (host, port)
            in sorted(self.node_addrs.items())}
        self.leader = ClusterRouter(
            clients, strategy=self.strategy, hot_sets=self.hot_sets,
            seed=self.seed, fault_hook=self.fault_hook,
            retry=self.retry, router_id=self.router_id, epoch=1)
        placement = self.leader.connect()
        result = elect(self.leader.clients, router_id=self.router_id,
                       epoch=1, ttl_s=self.lease_ttl_s)
        self.elections.append(result)
        if not result["won"]:
            raise ElectionLost(
                f"leader {self.router_id} could not win epoch 1: "
                f"{result['granted']}/{result['witnesses']} grants")
        addr = self.leader.enable_replication()
        self.standby = StandbyRouter(
            self.standby_id, addr, self.node_addrs,
            strategy=self.strategy, hot_sets=self.hot_sets,
            seed=self.seed, retry=self.retry,
            lease_ttl_s=self.lease_ttl_s, fault_hook=self.fault_hook)
        self.standby.start()
        if not self.standby.wait_synced():
            raise RuntimeError(
                f"standby {self.standby_id} never received the "
                f"ledger snapshot")
        return placement

    # ------------------------------------------------------------- serving
    def route(self, app: str, handler: Optional[str] = None) -> dict:
        if self.fault_hook is not None:
            try:
                self.fault_hook("election", router=self.leader.router_id,
                                epoch=self.leader.epoch)
            except RouterLossFault:
                self.failover()
        self._maybe_renew()
        return self.leader.route(app, handler)

    def failover(self) -> dict:
        """Kill the leader abruptly and promote the standby (the
        ``router_loss`` reaction, callable directly by tests)."""
        old = self.leader.router_id
        self.leader.halt()
        standby, self.standby = self.standby, None
        # the tail sees the dead stream on its own; promotion does not
        # wait for it — election fencing is what makes takeover safe
        self.leader = standby.promote()
        self.failovers += 1
        if standby.last_election is not None:
            self.elections.append(standby.last_election)
        _LOG.warning("failover", from_router=old,
                     to_router=self.leader.router_id,
                     epoch=self.leader.epoch)
        return {"from": old, "to": self.leader.router_id,
                "epoch": self.leader.epoch}

    def _maybe_renew(self) -> None:
        now = time.monotonic()
        if now - self._last_renew_t < self.lease_ttl_s / 3.0:
            return
        self._last_renew_t = now
        result = elect(self.leader.clients,
                       router_id=self.leader.router_id,
                       epoch=self.leader.epoch,
                       ttl_s=self.lease_ttl_s, op="renew")
        self.lease_renewals += 1
        if not result["won"]:
            self.lease_denials += 1
            _LOG.warning("lease-denied", router=self.leader.router_id,
                         epoch=self.leader.epoch,
                         granted=result["granted"])

    # ---------------------------------------------------------- delegation
    def plan_leave(self, node_id: str, **kw) -> dict:
        out = self.leader.plan_leave(node_id, **kw)
        self.node_addrs.pop(node_id, None)
        if self.standby is not None:
            self.standby.node_addrs.pop(node_id, None)
        return out

    def node_leave(self, node_id: str, **kw) -> dict:
        return self.leader.node_leave(node_id, **kw)

    @property
    def placement(self) -> dict:
        return self.leader.placement

    @property
    def router_sheds(self) -> int:
        return self.leader.router_sheds

    # -------------------------------------------------------------- finish
    def ha_summary(self) -> dict:
        return {"leader": self.leader.router_id,
                "epoch": self.leader.epoch,
                "standby": (self.standby.router_id
                            if self.standby is not None else None),
                "failovers": self.failovers,
                "lease_ttl_s": self.lease_ttl_s,
                "lease_renewals": self.lease_renewals,
                "lease_denials": self.lease_denials,
                "elections": [
                    {k: e[k] for k in ("router", "epoch", "op",
                                       "granted", "witnesses", "won")}
                    for e in self.elections]}

    def shutdown(self, *, flush: bool = False) -> dict:
        if self.standby is not None:
            self.standby.stop()
        payload = self.leader.shutdown(flush=flush)
        payload["ha"] = self.ha_summary()
        return payload
