"""Cluster-scale simulator: N simulated nodes, one global router.

Scales the PR 2 :class:`~repro.pool.fleet.FleetManager` simulation
from one host to a cluster: each :class:`SimNode` runs its own manager
(incremental ``begin -> offer -> finish``, exactly what the daemon
drives) under a per-node memory budget and a per-node shared base
zygote, and the router in :class:`ClusterSimulator` feeds every trace
arrival to the node owning its app.  Because each offer touches only
one node's state, a replay is O(requests x apps-per-node) — millions
of synthetic invocations run in seconds, which is the point: placement
quality only shows at fleet scale.

Why placement matters here: a node's shared base covers the modules
hot for >= 2 of *its* apps (:func:`repro.pool.sharing
.intersect_hot_sets`), and each resident app-zygote is charged only
its private delta above that base.  Sharing-aware placement packs
library families onto the same node, so the base covers more pages,
the per-app deltas shrink, more zygotes fit the node budget, and cold
starts fall — at the *same* total memory as plain consistent hashing,
which scatters families and pays full-fat zygotes everywhere.

Topology is dynamic: :meth:`ClusterSimulator.lose_node` (also wired to
the chaos ``node_loss`` fault) finalizes the lost node's fleet —
flushing its queued work into its summary, so nothing disappears — and
re-places its apps on the survivors; :meth:`join_node` migrates the
ring-owned app set onto a fresh node.  The conservation invariant
``requests == served + sheds + flushed + errors + abandoned`` is
checked per node and globally (router ledger vs node ledgers) in the
emitted ``cluster_summary`` payload.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.log import get_logger
from repro.obs.tracing import get_tracer, new_id, now_ms
from repro.pool.chaos import NodeLossFault
from repro.pool.fleet import FleetManager, QueueConfig
from repro.pool.policies import ProfileGuidedPolicy
from repro.pool.sharing import intersect_hot_sets
from repro.pool.simulator import PercentilePool
from repro.pool.trace import Request, Trace
from repro.cluster.ring import (ConsistentHashRing, hot_set_affinity,
                                plan_placement)
from repro.cluster.summary import make_cluster_summary_payload
from repro.cluster.workload import BASE_PROC_MB, ClusterWorkload

_LOG = get_logger("cluster.sim")


def _reg():
    from repro.obs.metrics import default_registry
    return default_registry()


class SimNode:
    """One simulated node: a FleetManager + per-node base zygote.

    ``base_modules`` (modules hot for >= 2 resident apps) size the
    node's shared base; each app's zygote is charged its private delta
    above that base — the two-tier accounting from PR 5, now computed
    *per node* from whatever placement put here.
    """

    def __init__(self, node_id: str, workload: ClusterWorkload, *,
                 apps: list[str], budget_mb: float,
                 queue: Optional[QueueConfig] = None,
                 rate_hint_per_s: float = 0.5) -> None:
        self.node_id = node_id
        self.workload = workload
        self.rate_hint_per_s = rate_hint_per_s
        self.base_modules = intersect_hot_sets(
            {a: workload.hot_sets[a] for a in apps}, min_members=2)
        self.shared_base_mb = (
            BASE_PROC_MB + sum(workload.module_mb[m]
                               for m in self.base_modules)
            if self.base_modules else 0.0)
        self.policy = ProfileGuidedPolicy(
            rate_hint_per_s=rate_hint_per_s)
        profiles = {a: self._node_profile(a) for a in apps}
        for app in apps:
            self.policy.add_report(workload.reports[app])
        self.manager = FleetManager(
            profiles, self.policy, budget_mb=budget_mb,
            queue=queue or QueueConfig(),
            shared_base_mb=self.shared_base_mb)
        self.alive = True
        self.summary = None  # FleetSummary once finished

    def _node_profile(self, app: str):
        """The app's profile *on this node*: private zygote pages are
        whatever its hot set adds above this node's base."""
        prof = self.workload.profiles[app]
        base = set(self.base_modules)
        private = sum(self.workload.module_mb[m]
                      for m in self.workload.hot_sets[app]
                      if m not in base)
        if self.shared_base_mb <= 0:
            return prof  # single-tier node: full-fat zygote
        return dataclasses.replace(
            prof, zygote_private_mb=max(private, 1.0))

    @property
    def apps(self) -> list[str]:
        return sorted(self.manager.profiles)

    def begin(self, trace_name: str) -> None:
        self.manager.begin(trace_name)

    def offer(self, req: Request) -> str:
        return self.manager.offer(req)

    def add_app(self, app: str) -> None:
        """Migration target: the app joins with a profile derived
        against *this* node's (already booted) base."""
        self.policy.add_report(self.workload.reports[app])
        self.manager.add_app(self._node_profile(app))

    def retire_app(self, app: str, now: float) -> dict:
        return self.manager.retire_app(app, now)

    def finish(self, end_t: float):
        if self.summary is None:
            self.summary = self.manager.finish(end_t)
        return self.summary


class ClusterSimulator:
    """Router + N simulated nodes over one synthetic workload."""

    def __init__(self, workload: ClusterWorkload, *,
                 n_nodes: int = 4, node_budget_mb: float = 512.0,
                 strategy: str = "sharing", seed: int = 0,
                 queue: Optional[QueueConfig] = None,
                 fault_hook=None) -> None:
        if n_nodes < 1:
            raise ValueError("n_nodes must be >= 1")
        self.workload = workload
        self.strategy = strategy
        self.seed = seed
        self.node_budget_mb = node_budget_mb
        self.queue = queue or QueueConfig()
        self.fault_hook = fault_hook
        self.ring = ConsistentHashRing(
            (f"node{i}" for i in range(n_nodes)), seed=seed)
        self.placement = plan_placement(
            workload.apps, self.ring, strategy=strategy,
            hot_sets=workload.hot_sets, seed=seed)
        self.nodes: dict[str, SimNode] = {}
        for node_id in self.ring.nodes:
            assigned = sorted(a for a, n in self.placement.items()
                              if n == node_id)
            self.nodes[node_id] = SimNode(
                node_id, workload, apps=assigned,
                budget_mb=node_budget_mb, queue=self.queue)
        self.migrations: list[dict] = []
        self.lost_nodes: list[str] = []
        self.routed_by_node: dict[str, int] = {
            n: 0 for n in self.ring.nodes}
        self._began = False
        self._trace_name = "cluster"

    # ------------------------------------------------------------- serving
    def begin(self, trace_name: str = "cluster") -> None:
        self._trace_name = trace_name
        for node in self.nodes.values():
            node.begin(trace_name)
        self._began = True

    def route(self, req: Request) -> str:
        """Feed one arrival to the node owning its app.  The chaos
        ``route`` site fires *before* admission, so an injected
        :class:`NodeLossFault` loses the node but never the request —
        it is re-placed and admitted on a survivor."""
        node_id = self.placement[req.app]
        if self.fault_hook is not None:
            try:
                self.fault_hook("route", app=req.app, node=node_id)
            except NodeLossFault:
                self.lose_node(node_id, req.t)
                node_id = self.placement[req.app]
        self.routed_by_node[node_id] = \
            self.routed_by_node.get(node_id, 0) + 1
        return self.nodes[node_id].offer(req)

    def replay(self, trace: Optional[Trace] = None, *,
               limit: Optional[int] = None,
               source: str = "cluster-sim") -> dict:
        """Route a whole trace and return the ``cluster_summary``
        payload.  ``limit`` truncates the trace (smoke runs)."""
        trace = trace if trace is not None else self.workload.trace
        tracer = get_tracer()
        t0 = now_ms()
        self.begin(trace.name)
        last_t = 0.0
        for i, req in enumerate(trace):
            if limit is not None and i >= limit:
                break
            last_t = req.t
            self.route(req)
        end_t = max(trace.duration_s, last_t)
        payload = self.finish(end_t, source=source)
        if tracer.enabled:
            tracer.add("cluster.replay", trace_id=new_id(),
                       t_start_ms=t0, duration_ms=now_ms() - t0,
                       attrs={"strategy": self.strategy,
                              "nodes": len(self.nodes),
                              "requests": payload["requests"],
                              "lost_nodes": len(self.lost_nodes)})
        return payload

    # ------------------------------------------------------------ topology
    def _alive(self) -> list[str]:
        return [n for n, node in self.nodes.items() if node.alive]

    def _replace_app(self, app: str, t: float, *, reason: str,
                     from_node: str) -> str:
        """Choose a surviving owner for ``app`` and migrate it there."""
        survivors = self._alive()
        if not survivors:
            raise RuntimeError("no surviving nodes to re-place "
                               f"{app!r} on")
        if self.strategy == "sharing":
            # affinity against what each survivor currently hosts,
            # ring score as tiebreak — same scoring as initial
            # placement, evaluated over the live topology
            hs = self.workload.hot_sets[app]
            ring_scores = {n: self.ring.score(n, app)
                           for n in survivors}
            top = max(ring_scores.values())
            scores = {
                n: hot_set_affinity(
                    hs, [self.workload.hot_sets[a]
                         for a in self.nodes[n].apps])
                + 0.01 * (ring_scores[n] / top)
                for n in survivors
            }
            target = max(survivors, key=lambda n: (scores[n], n))
        else:
            target = self.ring.place(app)
        self.nodes[target].add_app(app)
        self.placement[app] = target
        self.migrations.append({"app": app, "from": from_node,
                                "to": target, "at": round(t, 3),
                                "reason": reason})
        _reg().counter("repro_cluster_migrations_total",
                       "app migrations between nodes, by reason",
                       labels=("reason",)).labels(reason=reason).inc()
        return target

    def lose_node(self, node_id: str, t: float) -> dict:
        """Node failure: finalize its fleet (queued work flushes into
        its summary — conservation survives the loss) and re-place its
        apps on the survivors."""
        node = self.nodes[node_id]
        if not node.alive:
            return {"node": node_id, "already_lost": True}
        tracer = get_tracer()
        t0 = now_ms() if tracer.enabled else 0.0
        node.alive = False
        node.finish(t)
        self.ring.remove(node_id)
        self.lost_nodes.append(node_id)
        moved = []
        for app in node.apps:
            moved.append(self._replace_app(app, t, reason="node_loss",
                                           from_node=node_id))
        _reg().counter("repro_cluster_node_lost_total",
                       "nodes declared lost").inc()
        _LOG.warning("node-lost", node=node_id, at=round(t, 3),
                     moved=len(moved))
        if tracer.enabled:
            tracer.add("cluster.rebalance", trace_id=new_id(),
                       t_start_ms=t0, duration_ms=now_ms() - t0,
                       attrs={"node": node_id, "event": "node_loss",
                              "moved": len(moved)})
        return {"node": node_id, "moved": len(moved)}

    def join_node(self, node_id: str, t: float) -> dict:
        """Node join: the ring decides which apps the newcomer owns
        (rendezvous hashing moves only *onto* the new node, ~K/N of
        them); those apps are retired from their old nodes — still-
        queued work flushes there — and admitted on the new one."""
        if node_id in self.nodes and self.nodes[node_id].alive:
            return {"node": node_id, "already_joined": True}
        self.ring.add(node_id)
        movers = [app for app in self._placed_on_alive()
                  if self.ring.place(app) == node_id]
        node = SimNode(node_id, self.workload, apps=movers,
                       budget_mb=self.node_budget_mb,
                       queue=self.queue)
        node.begin(self._trace_name)
        self.nodes[node_id] = node
        self.routed_by_node.setdefault(node_id, 0)
        for app in movers:
            old = self.placement[app]
            self.nodes[old].retire_app(app, t)
            self.placement[app] = node_id
            self.migrations.append({"app": app, "from": old,
                                    "to": node_id, "at": round(t, 3),
                                    "reason": "node_join"})
        _LOG.info("node-joined", node=node_id, at=round(t, 3),
                  moved=len(movers))
        return {"node": node_id, "moved": len(movers)}

    def _placed_on_alive(self) -> list[str]:
        return [a for a, n in self.placement.items()
                if self.nodes[n].alive]

    # -------------------------------------------------------------- finish
    def finish(self, end_t: float, *,
               source: str = "cluster-sim") -> dict:
        node_payloads: dict[str, dict] = {}
        lat_pools, wait_pools = [], []
        for node_id, node in sorted(self.nodes.items()):
            summary = node.finish(end_t)
            node_payloads[node_id] = summary.artifact_payload(
                source=source)
            lat_pools.append(summary._lat_pool)
            wait_pools.append(summary._wait_pool)
            _reg().gauge("repro_cluster_node_requests",
                         "arrivals per cluster node",
                         labels=("node",)).labels(
                node=node_id).set(summary.n_requests)
            _reg().gauge("repro_cluster_node_cold_starts",
                         "cold starts per cluster node",
                         labels=("node",)).labels(
                node=node_id).set(summary.cold_starts)
        _reg().gauge("repro_cluster_nodes",
                     "live cluster nodes").set(len(self._alive()))
        return make_cluster_summary_payload(
            source=source,
            strategy=self.strategy,
            node_payloads=node_payloads,
            lat_pool=PercentilePool.merge(lat_pools),
            wait_pool=PercentilePool.merge(wait_pools),
            placement=self.placement,
            migrations=self.migrations,
            lost_nodes=self.lost_nodes,
            routed_by_node=self.routed_by_node,
            trace=self._trace_name,
            seed=self.seed,
            node_budget_mb=self.node_budget_mb,
            total_budget_mb=round(
                self.node_budget_mb * len(self.nodes), 1),
            duration_s=round(end_t, 3),
            queue=self.queue.to_dict(),
        )


def compare_strategies(workload: ClusterWorkload, *,
                       n_nodes: int = 4, node_budget_mb: float = 512.0,
                       strategies=("sharing", "hash", "random"),
                       seed: int = 0,
                       queue: Optional[QueueConfig] = None,
                       limit: Optional[int] = None) -> dict[str, dict]:
    """Replay the same trace under each placement strategy at the same
    per-node budget; returns strategy -> cluster_summary payload.  The
    ISSUE-8 acceptance table: sharing-aware must beat plain hashing on
    cold-start ratio at equal total memory."""
    out: dict[str, dict] = {}
    for strategy in strategies:
        sim = ClusterSimulator(workload, n_nodes=n_nodes,
                               node_budget_mb=node_budget_mb,
                               strategy=strategy, seed=seed,
                               queue=queue)
        out[strategy] = sim.replay(limit=limit)
    return out
