"""Synthetic multi-app cluster workloads for the placement comparison.

The cluster simulator's claim — sharing-aware placement beats plain
consistent hashing at equal total memory — needs a workload whose
library-sharing structure is *known*, so the comparison measures
placement quality, not profiling noise.  This module fabricates one:

* ``n_apps`` apps in ``n_families`` library families.  Every app's hot
  set is ``fakelib_runtime`` (fleet-wide, the PR 5 base-zygote floor) +
  its family's ``fakelib_fam<k>`` (the pages worth co-locating) + one
  private ``fakelib_priv_<app>``;
* per-module resident MB and init milliseconds scale together (big
  libraries are slow to import — the SLIMSTART correlation), giving
  each app an :class:`~repro.pool.simulator.AppProfile` and an
  :class:`~repro.core.profiler.report.OptimizationReport` consistent
  with each other;
* arrivals come from the Azure-style Zipf generator
  (:func:`repro.pool.trace.azure_trace`) so a few apps are hot and the
  tail is cold — the regime where zygote residency decisions matter.

Everything is deterministic in ``seed``; the bench, the CLI, the perf
gate and the tests all build workloads here so their numbers agree.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import LibraryStats
from repro.pool.simulator import AppProfile
from repro.pool.trace import Trace, azure_trace

# interpreter + stdlib floor every process pays, beyond library pages
BASE_PROC_MB = 20.0
# import cost per resident MB: the measured SLIMSTART correlation is
# roughly linear for the fakelib benchsuite
INIT_MS_PER_MB = 5.0
FORK_INIT_MS = 9.0      # warm path: fork from a resident zygote
INVOKE_MS = 14.0


@dataclass
class ClusterWorkload:
    """One reproducible multi-app workload: who imports what, how much
    it costs, and when requests arrive."""

    apps: list[str]
    hot_sets: dict[str, list[str]]
    module_mb: dict[str, float]
    profiles: dict[str, AppProfile]
    reports: dict[str, OptimizationReport]
    trace: Trace
    seed: int = 0
    families: dict[str, int] = field(default_factory=dict)

    def app_modules_mb(self, app: str) -> float:
        return sum(self.module_mb[m] for m in self.hot_sets[app])


def _report(app: str, hot_set: list[str],
            module_mb: dict[str, float]) -> OptimizationReport:
    total_init_s = sum(module_mb[m] for m in hot_set) \
        * INIT_MS_PER_MB / 1e3
    stats = []
    for mod in hot_set:
        init_s = module_mb[mod] * INIT_MS_PER_MB / 1e3
        stats.append(LibraryStats(
            name=mod, utilization=0.9, init_s=init_s,
            init_share=init_s / max(total_init_s, 1e-9),
            runtime_samples=50, file="<cluster-workload>"))
    return OptimizationReport(
        application=app, e2e_s=total_init_s + INVOKE_MS / 1e3,
        total_init_s=total_init_s, qualifies=True, stats=stats,
        defer_targets=[])


def synthetic_cluster_workload(
        n_apps: int = 12, *, n_families: int = 4, seed: int = 0,
        minutes: int = 20, peak_rpm: float = 60.0,
        popularity_s: float = 1.2,
        family_mb: float = 64.0, runtime_mb: float = 32.0,
        private_mb: float = 16.0) -> ClusterWorkload:
    """Build the standard placement-comparison workload (see module
    docstring).  ``popularity_s`` is the Zipf skew across apps."""
    if n_apps < 1:
        raise ValueError("n_apps must be >= 1")
    n_families = max(1, min(n_families, n_apps))
    apps = [f"app{i:02d}" for i in range(n_apps)]
    families = {app: i % n_families for i, app in enumerate(apps)}

    module_mb: dict[str, float] = {"fakelib_runtime": runtime_mb}
    for fam in range(n_families):
        module_mb[f"fakelib_fam{fam}"] = family_mb
    hot_sets: dict[str, list[str]] = {}
    for app in apps:
        priv = f"fakelib_priv_{app}"
        module_mb[priv] = private_mb
        hot_sets[app] = ["fakelib_runtime",
                         f"fakelib_fam{families[app]}", priv]

    profiles: dict[str, AppProfile] = {}
    reports: dict[str, OptimizationReport] = {}
    for app in apps:
        lib_mb = sum(module_mb[m] for m in hot_sets[app])
        rss = BASE_PROC_MB + lib_mb
        profiles[app] = AppProfile(
            app=app,
            cold_init_ms=lib_mb * INIT_MS_PER_MB,
            warm_init_ms=FORK_INIT_MS,
            invoke_ms=INVOKE_MS,
            rss_mb=rss,
            zygote_rss_mb=rss,
            # private delta vs a node base is placement-dependent;
            # the simulator derives it per node (see SimNode)
            zygote_private_mb=0.0)
        reports[app] = _report(app, hot_sets[app], module_mb)

    trace = azure_trace(apps, minutes=minutes, peak_rpm=peak_rpm,
                        popularity_s=popularity_s, seed=seed,
                        name=f"cluster-zipf-{seed}")
    return ClusterWorkload(apps=apps, hot_sets=hot_sets,
                           module_mb=module_mb, profiles=profiles,
                           reports=reports, trace=trace, seed=seed,
                           families=families)
