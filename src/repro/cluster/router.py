"""The global router: socket-fed placement over real node agents.

Where :class:`~repro.cluster.sim.ClusterSimulator` routes simulated
arrivals to in-process :class:`FleetManager` nodes, this module routes
*real* invocations to :class:`~repro.cluster.node.NodeAgent` processes
over the frame protocol.  Same placement brain
(:mod:`repro.cluster.ring` — sharing-weighted when hot sets are known,
plain rendezvous hashing otherwise), same ledger discipline: the
router counts every admission per node, and at shutdown the per-node
``fleet_summary`` payloads must account for exactly those requests
(``requests == served + sheds + flushed + errors + abandoned`` per
node and globally) — checked in the emitted ``cluster_summary``.

Real nodes deploy a fixed app set (a :class:`ZygoteFleet` boots from
on-disk app dirs), so placement is constrained to nodes advertising
the app; when several do, the strategy picks.  Node loss (a dead
connection, or the chaos ``node_loss`` fault) re-places the lost
node's apps across surviving advertisers; requests the router already
handed to the dead node stay in *its* ledger — its last summary (or
the router's shed accounting when none was obtainable) keeps the
global invariant intact.

Global percentiles are merged from the capped raw latency samples each
agent ships back with its summary
(:meth:`repro.pool.simulator.PercentilePool.merge` — true quantiles,
not averaged per-node ones).
"""

from __future__ import annotations

import socket
import threading
import time
from typing import Optional

from repro.obs.log import get_logger
from repro.obs.tracing import get_tracer, new_id, now_ms
from repro.pool.chaos import NodeLossFault
from repro.pool.simulator import PercentilePool
from repro.cluster.protocol import (FrameClosed, FrameError,
                                    recv_frame, send_frame)
from repro.cluster.ring import (ConsistentHashRing, hot_set_affinity,
                                plan_placement)
from repro.cluster.summary import make_cluster_summary_payload

_LOG = get_logger("cluster.router")


def _reg():
    from repro.obs.metrics import default_registry
    return default_registry()


class NodeClient:
    """Blocking frame-RPC client to one node agent (thread-safe: one
    in-flight call at a time per client)."""

    def __init__(self, node_id: str, host: str, port: int, *,
                 timeout_s: float = 30.0) -> None:
        self.node_id = node_id
        self.host = host
        self.port = port
        self.timeout_s = timeout_s
        self._lock = threading.Lock()
        self._sock: Optional[socket.socket] = None

    def connect(self) -> dict:
        with self._lock:
            if self._sock is None:
                self._sock = socket.create_connection(
                    (self.host, self.port), timeout=self.timeout_s)
        return self.call({"cmd": "hello"})

    def call(self, obj: dict) -> dict:
        with self._lock:
            if self._sock is None:
                raise ConnectionError(
                    f"node {self.node_id} is not connected")
            try:
                send_frame(self._sock, obj)
                return recv_frame(self._sock)
            except (OSError, FrameClosed, FrameError):
                self.close()
                raise

    def close(self) -> None:
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    def __enter__(self) -> "NodeClient":
        self.connect()
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ClusterRouter:
    """Places apps on live node agents and feeds them invocations."""

    def __init__(self, clients: dict[str, NodeClient], *,
                 strategy: str = "sharing",
                 hot_sets: Optional[dict[str, list[str]]] = None,
                 seed: int = 0, fault_hook=None) -> None:
        if not clients:
            raise ValueError("router needs at least one node")
        self.clients = dict(clients)
        self.strategy = strategy
        self.hot_sets = dict(hot_sets or {})
        self.seed = seed
        self.fault_hook = fault_hook
        self.ring = ConsistentHashRing(self.clients, seed=seed)
        self.node_apps: dict[str, list[str]] = {}
        self.placement: dict[str, str] = {}
        self.routed_by_node: dict[str, int] = {
            n: 0 for n in self.clients}
        self.router_sheds = 0  # arrivals no live node could take
        self.migrations: list[dict] = []
        self.lost_nodes: list[str] = []
        self._node_payloads: dict[str, dict] = {}
        self._node_samples: dict[str, list[float]] = {}
        self._t0 = time.monotonic()

    # ----------------------------------------------------------- topology
    def connect(self) -> dict[str, str]:
        """Hello every node, learn who deploys what, compute the
        placement.  Returns the app -> node map."""
        for node_id, client in sorted(self.clients.items()):
            hello = client.connect()
            self.node_apps[node_id] = list(hello.get("apps", []))
        self._place_all()
        _reg().gauge("repro_cluster_nodes",
                     "live cluster nodes").set(len(self.clients))
        return dict(self.placement)

    def _advertisers(self, app: str) -> list[str]:
        return sorted(n for n, apps in self.node_apps.items()
                      if app in apps and n in self.clients)

    def _place_all(self) -> None:
        apps = sorted({a for apps in self.node_apps.values()
                       for a in apps})
        # place over the full ring first (pure strategy), then clamp
        # each app to the nodes that actually deploy it
        ideal = plan_placement(apps, self.ring,
                               strategy=self.strategy,
                               hot_sets=self.hot_sets, seed=self.seed)
        for app in apps:
            nodes = self._advertisers(app)
            if not nodes:
                continue
            self.placement[app] = (ideal[app] if ideal[app] in nodes
                                   else self.ring.place(app,
                                                        among=nodes))

    def node_leave(self, node_id: str, *,
                   reason: str = "node_loss") -> dict:
        """A node died (connection gone or chaos): collect what it
        already reported if possible, re-place its apps."""
        client = self.clients.pop(node_id, None)
        if client is None:
            return {"node": node_id, "already_lost": True}
        tracer = get_tracer()
        t0 = now_ms() if tracer.enabled else 0.0
        # best-effort last summary so its admitted requests stay
        # accounted; a dead socket means the ledger keeps the router's
        # own count with zero served — conservation then *visibly*
        # breaks in the report rather than silently dropping traffic
        if node_id not in self._node_payloads:
            try:
                reply = client.call({"cmd": "shutdown", "flush": True})
                self._harvest(node_id, reply)
            except (ConnectionError, OSError, FrameClosed, FrameError):
                pass
        client.close()
        self.ring.remove(node_id)
        self.lost_nodes.append(node_id)
        moved = []
        for app, owner in sorted(self.placement.items()):
            if owner != node_id:
                continue
            nodes = self._advertisers(app)
            if not nodes:
                del self.placement[app]  # nobody left deploys it
                continue
            target = self._choose(app, nodes)
            self.placement[app] = target
            moved.append(app)
            self.migrations.append({
                "app": app, "from": node_id, "to": target,
                "at": round(time.monotonic() - self._t0, 3),
                "reason": reason})
            _reg().counter("repro_cluster_migrations_total",
                           "app migrations between nodes, by reason",
                           labels=("reason",)).labels(
                reason=reason).inc()
        _reg().counter("repro_cluster_node_lost_total",
                       "nodes declared lost").inc()
        _reg().gauge("repro_cluster_nodes",
                     "live cluster nodes").set(len(self.clients))
        _LOG.warning("node-lost", node=node_id, moved=len(moved))
        if tracer.enabled:
            tracer.add("cluster.rebalance", trace_id=new_id(),
                       t_start_ms=t0, duration_ms=now_ms() - t0,
                       attrs={"node": node_id, "event": reason,
                              "moved": len(moved)})
        return {"node": node_id, "moved": moved}

    def node_join(self, node_id: str, client: NodeClient) -> dict:
        """A node came up: hello it, hand it the apps the ring says it
        now owns (among its advertised set)."""
        hello = client.connect()
        self.clients[node_id] = client
        self.node_apps[node_id] = list(hello.get("apps", []))
        self.ring.add(node_id)
        self.routed_by_node.setdefault(node_id, 0)
        moved = []
        for app in self.node_apps[node_id]:
            old = self.placement.get(app)
            target = self.ring.place(app, among=self._advertisers(app))
            if target == node_id and old != node_id:
                self.placement[app] = node_id
                moved.append(app)
                if old is not None:
                    self.migrations.append({
                        "app": app, "from": old, "to": node_id,
                        "at": round(time.monotonic() - self._t0, 3),
                        "reason": "node_join"})
        _reg().gauge("repro_cluster_nodes",
                     "live cluster nodes").set(len(self.clients))
        _LOG.info("node-joined", node=node_id, moved=len(moved))
        return {"node": node_id, "moved": moved}

    def _choose(self, app: str, nodes: list[str]) -> str:
        if self.strategy == "sharing" and self.hot_sets.get(app):
            hs = self.hot_sets[app]
            ring_scores = {n: self.ring.score(n, app) for n in nodes}
            top = max(ring_scores.values())
            resident = {
                n: [self.hot_sets.get(a, [])
                    for a, o in self.placement.items() if o == n]
                for n in nodes}
            return max(nodes, key=lambda n: (
                hot_set_affinity(hs, resident[n])
                + 0.01 * (ring_scores[n] / top), n))
        return self.ring.place(app, among=nodes)

    # ------------------------------------------------------------- serving
    def route(self, app: str, handler: Optional[str] = None) -> dict:
        """Forward one invocation to the app's owner; on a dead node,
        fail over once (the node is declared lost, apps re-place, and
        this invocation goes to the new owner)."""
        tracer = get_tracer()
        t0 = now_ms() if tracer.enabled else 0.0
        for _attempt in (0, 1):
            node_id = self.placement.get(app)
            if node_id is None or node_id not in self.clients:
                self.router_sheds += 1
                return {"ok": False, "outcome": "no-node",
                        "error": f"no live node deploys {app!r}"}
            if self.fault_hook is not None:
                try:
                    self.fault_hook("route", app=app, node=node_id)
                except NodeLossFault:
                    self.node_leave(node_id, reason="node_loss")
                    continue
            try:
                reply = self.clients[node_id].call(
                    {"app": app, "handler": handler})
            except (ConnectionError, OSError, FrameClosed,
                    FrameError):
                self.node_leave(node_id, reason="connection_lost")
                continue
            self.routed_by_node[node_id] = \
                self.routed_by_node.get(node_id, 0) + 1
            _reg().counter("repro_cluster_routed_total",
                           "invocations routed, by node and outcome",
                           labels=("node", "outcome")).labels(
                node=node_id,
                outcome=str(reply.get("outcome", "error"))).inc()
            if tracer.enabled:
                tracer.add("cluster.route", trace_id=new_id(),
                           t_start_ms=t0,
                           duration_ms=now_ms() - t0,
                           attrs={"app": app, "node": node_id,
                                  "outcome": reply.get("outcome")})
            return {**reply, "node": node_id}
        self.router_sheds += 1
        return {"ok": False, "outcome": "no-node",
                "error": f"no surviving owner for {app!r}"}

    # -------------------------------------------------------------- finish
    def _harvest(self, node_id: str, reply: dict) -> None:
        if reply.get("event") == "summary":
            self._node_payloads[node_id] = reply.get("summary") or {}
            self._node_samples[node_id] = [
                float(x) for x in reply.get("latency_samples") or []]

    def shutdown(self, *, flush: bool = False) -> dict:
        """Drain every node, merge ledgers and sample pools, return
        the ``cluster_summary`` payload."""
        for node_id, client in sorted(self.clients.items()):
            if node_id in self._node_payloads:
                continue
            try:
                self._harvest(node_id, client.call(
                    {"cmd": "shutdown", "flush": flush}))
            except (ConnectionError, OSError, FrameClosed,
                    FrameError) as exc:
                _LOG.warning("shutdown-lost", node=node_id,
                             error=repr(exc))
            finally:
                client.close()
        lat_pool = PercentilePool.merge([
            PercentilePool.of_lists([samples])
            for samples in self._node_samples.values()])
        payload = make_cluster_summary_payload(
            source="cluster-route",
            strategy=self.strategy,
            node_payloads=self._node_payloads,
            lat_pool=lat_pool,
            placement=self.placement,
            migrations=self.migrations,
            lost_nodes=self.lost_nodes,
            routed_by_node=self.routed_by_node,
            router={"sheds": self.router_sheds,
                    "nodes": sorted(set(self.clients)
                                    | set(self.lost_nodes))},
            duration_s=round(time.monotonic() - self._t0, 3),
        )
        return payload
