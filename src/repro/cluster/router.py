"""The global router: socket-fed placement over real node agents.

Where :class:`~repro.cluster.sim.ClusterSimulator` routes simulated
arrivals to in-process :class:`FleetManager` nodes, this module routes
*real* invocations to :class:`~repro.cluster.node.NodeAgent` processes
over the frame protocol.  Same placement brain
(:mod:`repro.cluster.ring` — sharing-weighted when hot sets are known,
plain rendezvous hashing otherwise), same ledger discipline: the
router counts every admission per node, and at shutdown the per-node
``fleet_summary`` payloads must account for exactly those requests
(``requests == served + sheds + flushed + errors + abandoned`` per
node and globally) — checked in the emitted ``cluster_summary``.

Real nodes deploy a fixed app set (a :class:`ZygoteFleet` boots from
on-disk app dirs), so placement is constrained to nodes advertising
the app; when several do, the strategy picks.  Node loss (a dead
connection, or the chaos ``node_loss`` fault) re-places the lost
node's apps across surviving advertisers; requests the router already
handed to the dead node stay in *its* ledger — its last summary (or
the router's shed accounting when none was obtainable) keeps the
global invariant intact.

Global percentiles are merged from the capped raw latency samples each
agent ships back with its summary
(:meth:`repro.pool.simulator.PercentilePool.merge` — true quantiles,
not averaged per-node ones).
"""

from __future__ import annotations

import socket
import threading
import time
from typing import Optional

from repro.obs.log import get_logger
from repro.obs.tracing import get_tracer, new_id, now_ms
from repro.pool.chaos import HandoffStallFault, NodeLossFault
from repro.pool.simulator import PercentilePool
from repro.cluster.ha import LedgerReplicator, RetryPolicy, empty_ledger
from repro.cluster.protocol import (FrameClosed, FrameError,
                                    recv_frame, send_frame)
from repro.cluster.ring import (ConsistentHashRing, hot_set_affinity,
                                plan_placement)
from repro.cluster.summary import make_cluster_summary_payload

_LOG = get_logger("cluster.router")


def _reg():
    from repro.obs.metrics import default_registry
    return default_registry()


class NodeClient:
    """Blocking frame-RPC client to one node agent (thread-safe: one
    in-flight call at a time per client).

    ``retry`` (a :class:`~repro.cluster.ha.RetryPolicy`) governs every
    timeout: ``connect()`` retries refused connections with capped
    jittered backoff — a node agent still binding its socket no longer
    fails the whole router bring-up — and each call runs under the
    policy's per-call socket timeout instead of one fixed 30 s knob.
    ``call(..., idempotent=True)`` additionally reconnects and resends
    on transient failures; invocation frames must never set it (a lost
    reply after the node admitted the request would double-admit on
    resend and break conservation).
    """

    def __init__(self, node_id: str, host: str, port: int, *,
                 retry: Optional[RetryPolicy] = None,
                 timeout_s: Optional[float] = None) -> None:
        self.node_id = node_id
        self.host = host
        self.port = port
        if retry is None:
            retry = (RetryPolicy(call_timeout_s=timeout_s)
                     if timeout_s is not None else RetryPolicy())
        self.retry = retry
        self._lock = threading.Lock()
        self._sock: Optional[socket.socket] = None

    def _ensure_sock(self) -> socket.socket:
        # caller holds self._lock
        if self._sock is None:
            sock = self.retry.run(
                lambda: socket.create_connection(
                    (self.host, self.port),
                    timeout=self.retry.connect_timeout_s),
                what=f"connect to node {self.node_id}")
            sock.settimeout(self.retry.call_timeout_s or None)
            self._sock = sock
        return self._sock

    def connect(self) -> dict:
        with self._lock:
            self._ensure_sock()
        return self.call({"cmd": "hello"}, idempotent=True)

    def call(self, obj: dict, *, idempotent: bool = False) -> dict:
        def _once() -> dict:
            with self._lock:
                sock = self._sock
                if sock is None:
                    if not idempotent:
                        raise ConnectionError(
                            f"node {self.node_id} is not connected")
                    sock = self._ensure_sock()
                try:
                    send_frame(sock, obj)
                    return recv_frame(sock)
                except (OSError, FrameClosed, FrameError):
                    self.close()
                    raise

        if not idempotent:
            return _once()
        return self.retry.run(
            _once, what=f"call {obj.get('cmd')!r} on node "
                        f"{self.node_id}")

    def close(self) -> None:
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    def __enter__(self) -> "NodeClient":
        self.connect()
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ClusterRouter:
    """Places apps on live node agents and feeds them invocations."""

    def __init__(self, clients: dict[str, NodeClient], *,
                 strategy: str = "sharing",
                 hot_sets: Optional[dict[str, list[str]]] = None,
                 seed: int = 0, fault_hook=None,
                 retry: Optional[RetryPolicy] = None,
                 router_id: str = "router", epoch: int = 0) -> None:
        if not clients:
            raise ValueError("router needs at least one node")
        self.clients = dict(clients)
        self.strategy = strategy
        self.hot_sets = dict(hot_sets or {})
        self.seed = seed
        self.fault_hook = fault_hook
        self.retry = retry or RetryPolicy()
        self.router_id = router_id
        self.epoch = epoch
        self.ring = ConsistentHashRing(self.clients, seed=seed)
        self.node_apps: dict[str, list[str]] = {}
        self.placement: dict[str, str] = {}
        self.routed_by_node: dict[str, int] = {
            n: 0 for n in self.clients}
        self.router_sheds = 0  # arrivals no live node could take
        self.migrations: list[dict] = []
        self.lost_nodes: list[str] = []
        self.departed: list[str] = []  # left cleanly via plan_leave
        self.handoffs = {"warm": 0, "cold": 0, "stalled": 0,
                         "requeued": 0}
        self._node_payloads: dict[str, dict] = {}
        self._node_samples: dict[str, list[float]] = {}
        self._rep: Optional[LedgerReplicator] = None
        self._halted = False
        self._t0 = time.monotonic()

    @classmethod
    def resume(cls, clients: dict[str, NodeClient], *, ledger: dict,
               router_id: str, epoch: int, strategy: str = "sharing",
               hot_sets: Optional[dict[str, list[str]]] = None,
               seed: int = 0, retry: Optional[RetryPolicy] = None,
               fault_hook=None) -> "ClusterRouter":
        """Bring a promoted standby's replicated ledger back to life:
        restore placement/counts/history from the replica, then
        ``connect(reconcile=True)`` overwrites the per-node admission
        counts with each live node's own ledger (the ground truth for
        whatever was in flight when the old leader died)."""
        router = cls(clients, strategy=strategy, hot_sets=hot_sets,
                     seed=seed, fault_hook=fault_hook, retry=retry,
                     router_id=router_id, epoch=epoch)
        router.placement = dict(ledger.get("placement") or {})
        router.routed_by_node = {
            n: int(c) for n, c
            in (ledger.get("routed_by_node") or {}).items()}
        for n in router.clients:
            router.routed_by_node.setdefault(n, 0)
        router.router_sheds = int(ledger.get("router_sheds", 0))
        router.migrations = [dict(m) for m
                             in ledger.get("migrations") or []]
        router.lost_nodes = list(ledger.get("lost_nodes") or [])
        router.departed = list(ledger.get("departed") or [])
        router._node_payloads = {
            n: dict(p) for n, p
            in (ledger.get("node_payloads") or {}).items()}
        router._node_samples = {
            n: [float(x) for x in s] for n, s
            in (ledger.get("node_samples") or {}).items()}
        router.connect(reconcile=True)
        return router

    # ----------------------------------------------------------- topology
    def connect(self, *, reconcile: bool = False) -> dict[str, str]:
        """Hello every node, learn who deploys what, compute the
        placement.  Returns the app -> node map.

        ``reconcile=True`` (the promoted-standby path) keeps the
        resumed placement instead of recomputing it, overwrites
        ``routed_by_node`` with the admission counters each node ships
        in its ``hello`` reply, and re-places only the apps whose
        owner did not survive the failover."""
        for node_id, client in sorted(self.clients.items()):
            hello = client.connect()
            self.node_apps[node_id] = list(hello.get("apps", []))
            if reconcile:
                counts = hello.get("counts") or {}
                if "requests" in counts:
                    self.routed_by_node[node_id] = \
                        int(counts["requests"])
        if reconcile:
            self._reconcile_placement()
        else:
            self._place_all()
        _reg().gauge("repro_cluster_nodes",
                     "live cluster nodes").set(len(self.clients))
        return dict(self.placement)

    def _reconcile_placement(self) -> None:
        """After a failover: keep every placement whose owner is still
        live, re-place (or drop) the rest."""
        apps = sorted({a for apps in self.node_apps.values()
                       for a in apps} | set(self.placement))
        for app in apps:
            owner = self.placement.get(app)
            if owner in self.clients:
                continue
            nodes = self._advertisers(app)
            if not nodes:
                if owner is not None:
                    del self.placement[app]
                    self._emit({"k": "unplace", "app": app})
                continue
            target = self._choose(app, nodes)
            self.placement[app] = target
            self._emit({"k": "place", "app": app, "node": target})
            if owner is not None:
                mig = {"app": app, "from": owner, "to": target,
                       "at": round(time.monotonic() - self._t0, 3),
                       "reason": "router_failover"}
                self.migrations.append(mig)
                self._emit({"k": "migration", "m": mig})

    # -------------------------------------------------------- replication
    def enable_replication(self, *, host: str = "127.0.0.1",
                           port: int = 0) -> tuple:
        """Start streaming this router's ledger to standbys; returns
        the ``(host, port)`` standbys connect to.  Idle cost when no
        standby is attached: one ``is not None`` check per emit."""
        if self._rep is None:
            self._rep = LedgerReplicator(self.ledger_snapshot,
                                         host=host, port=port)
        return (self._rep.host, self._rep.port)

    def ledger_snapshot(self) -> dict:
        """The replicated state (see :func:`repro.cluster.ha
        .empty_ledger` for the shape)."""
        snap = empty_ledger(self.epoch)
        snap["placement"] = dict(self.placement)
        snap["routed_by_node"] = dict(self.routed_by_node)
        snap["router_sheds"] = self.router_sheds
        snap["migrations"] = [dict(m) for m in self.migrations]
        snap["lost_nodes"] = list(self.lost_nodes)
        snap["departed"] = list(self.departed)
        snap["node_payloads"] = {n: dict(p) for n, p
                                 in self._node_payloads.items()}
        snap["node_samples"] = {n: list(s) for n, s
                                in self._node_samples.items()}
        return snap

    def _emit(self, entry: dict) -> None:
        if self._rep is not None:
            self._rep.publish(entry)

    def halt(self) -> None:
        """Abrupt router death (failover drills): node sockets and the
        replication stream die with no drain and no goodbye.  The
        router is unusable afterwards — that is the point."""
        self._halted = True
        if self._rep is not None:
            self._rep.stop(abrupt=True)
        for client in self.clients.values():
            client.close()
        _LOG.warning("router-halted", router=self.router_id,
                     epoch=self.epoch)

    def _advertisers(self, app: str) -> list[str]:
        return sorted(n for n, apps in self.node_apps.items()
                      if app in apps and n in self.clients)

    def _place_all(self) -> None:
        apps = sorted({a for apps in self.node_apps.values()
                       for a in apps})
        # place over the full ring first (pure strategy), then clamp
        # each app to the nodes that actually deploy it
        ideal = plan_placement(apps, self.ring,
                               strategy=self.strategy,
                               hot_sets=self.hot_sets, seed=self.seed)
        for app in apps:
            nodes = self._advertisers(app)
            if not nodes:
                continue
            self.placement[app] = (ideal[app] if ideal[app] in nodes
                                   else self.ring.place(app,
                                                        among=nodes))

    def node_leave(self, node_id: str, *,
                   reason: str = "node_loss") -> dict:
        """A node died (connection gone or chaos): collect what it
        already reported if possible, re-place its apps."""
        client = self.clients.pop(node_id, None)
        if client is None:
            return {"node": node_id, "already_lost": True}
        tracer = get_tracer()
        t0 = now_ms() if tracer.enabled else 0.0
        # best-effort last summary so its admitted requests stay
        # accounted; a dead socket means the ledger keeps the router's
        # own count with zero served — conservation then *visibly*
        # breaks in the report rather than silently dropping traffic
        if node_id not in self._node_payloads:
            try:
                reply = client.call({"cmd": "shutdown", "flush": True})
                self._harvest(node_id, reply)
            except (ConnectionError, OSError, FrameClosed, FrameError):
                pass
        client.close()
        self.ring.remove(node_id)
        # drop the advertisement too: a ghost entry would keep the
        # dead node in every _advertisers() scan and make the summary
        # unable to tell "left" from "still advertised"
        self.node_apps.pop(node_id, None)
        self.lost_nodes.append(node_id)
        self._emit({"k": "lost", "node": node_id})
        moved = []
        for app, owner in sorted(self.placement.items()):
            if owner != node_id:
                continue
            nodes = self._advertisers(app)
            if not nodes:
                del self.placement[app]  # nobody left deploys it
                self._emit({"k": "unplace", "app": app})
                continue
            target = self._choose(app, nodes)
            self.placement[app] = target
            moved.append(app)
            mig = {"app": app, "from": node_id, "to": target,
                   "at": round(time.monotonic() - self._t0, 3),
                   "reason": reason}
            self.migrations.append(mig)
            self._emit({"k": "migration", "m": mig})
            _reg().counter("repro_cluster_migrations_total",
                           "app migrations between nodes, by reason",
                           labels=("reason",)).labels(
                reason=reason).inc()
        _reg().counter("repro_cluster_node_lost_total",
                       "nodes declared lost").inc()
        _reg().gauge("repro_cluster_nodes",
                     "live cluster nodes").set(len(self.clients))
        _LOG.warning("node-lost", node=node_id, moved=len(moved))
        if tracer.enabled:
            tracer.add("cluster.rebalance", trace_id=new_id(),
                       t_start_ms=t0, duration_ms=now_ms() - t0,
                       attrs={"node": node_id, "event": reason,
                              "moved": len(moved)})
        return {"node": node_id, "moved": moved}

    def plan_leave(self, node_id: str, *, warm: bool = True) -> dict:
        """Planned decommission with **warm-state handoff**: for every
        app the departing node owns, ship its deployed report artifact
        (and sim profile) to the chosen successor, let the successor
        pre-warm its zygote, and only then flip the placement.  The
        departing node then drains — in-flight work finishes, and its
        still-queued requests come back over the wire (counted
        ``flushed`` in its ledger) to be re-admitted at the new owners
        instead of hitting the floor.

        A ``handoff_stall`` chaos fault (or any transport error during
        the prewarm exchange) downgrades that app to today's cold
        re-place — placement still flips, accounting stays intact.
        ``warm=False`` skips the prewarm exchange entirely (the
        cold-baseline arm of the handoff benchmark).
        """
        client = self.clients.get(node_id)
        if client is None:
            return {"node": node_id, "already_lost": True}
        tracer = get_tracer()
        t0 = now_ms() if tracer.enabled else 0.0
        handoffs: list[dict] = []
        for app, owner in sorted(self.placement.items()):
            if owner != node_id:
                continue
            nodes = [n for n in self._advertisers(app)
                     if n != node_id]
            if not nodes:
                del self.placement[app]  # nobody else deploys it
                self._emit({"k": "unplace", "app": app})
                continue
            target = self._choose(app, nodes)
            mode = "cold"
            if warm:
                try:
                    if self.fault_hook is not None:
                        self.fault_hook("handoff", app=app,
                                        node=node_id, target=target)
                    export = client.call(
                        {"cmd": "handoff_export", "app": app},
                        idempotent=True)
                    pre = self.clients[target].call(
                        {"cmd": "prewarm", "app": app,
                         "report": export.get("report"),
                         "profile": export.get("profile")},
                        idempotent=True)
                    if pre.get("warm"):
                        mode = "warm"
                except HandoffStallFault:
                    self.handoffs["stalled"] += 1
                except (ConnectionError, OSError, FrameClosed,
                        FrameError) as exc:
                    _LOG.warning("handoff-degraded", app=app,
                                 node=node_id, target=target,
                                 error=repr(exc))
            self.handoffs[mode] += 1
            self.placement[app] = target
            self._emit({"k": "place", "app": app, "node": target})
            mig = {"app": app, "from": node_id, "to": target,
                   "at": round(time.monotonic() - self._t0, 3),
                   "reason": f"handoff_{mode}"}
            self.migrations.append(mig)
            self._emit({"k": "migration", "m": mig})
            _reg().counter("repro_cluster_migrations_total",
                           "app migrations between nodes, by reason",
                           labels=("reason",)).labels(
                reason=f"handoff_{mode}").inc()
            handoffs.append({"app": app, "to": target, "mode": mode})
        # drain the departing node; queued requests come home with the
        # summary instead of being flushed to the floor
        queued: list[dict] = []
        try:
            reply = client.call({"cmd": "shutdown", "flush": True,
                                 "return_queued": True})
            self._harvest(node_id, reply)
            queued = list(reply.get("queued") or [])
        except (ConnectionError, OSError, FrameClosed,
                FrameError) as exc:
            _LOG.warning("plan-leave-drain-lost", node=node_id,
                         error=repr(exc))
        client.close()
        self.clients.pop(node_id, None)
        self.ring.remove(node_id)
        self.node_apps.pop(node_id, None)
        self.departed.append(node_id)
        self._emit({"k": "departed", "node": node_id})
        requeued = 0
        for item in queued:
            qapp = item.get("app")
            if qapp is None:
                continue
            self.route(qapp, item.get("handler"))
            requeued += 1
        self.handoffs["requeued"] += requeued
        _reg().gauge("repro_cluster_nodes",
                     "live cluster nodes").set(len(self.clients))
        _LOG.info("node-departed", node=node_id,
                  handoffs=len(handoffs), requeued=requeued)
        if tracer.enabled:
            tracer.add("cluster.handoff", trace_id=new_id(),
                       t_start_ms=t0, duration_ms=now_ms() - t0,
                       attrs={"node": node_id,
                              "handoffs": len(handoffs),
                              "requeued": requeued})
        return {"node": node_id, "handoffs": handoffs,
                "requeued": requeued}

    def node_join(self, node_id: str, client: NodeClient) -> dict:
        """A node came up: hello it, hand it the apps the ring says it
        now owns (among its advertised set)."""
        hello = client.connect()
        self.clients[node_id] = client
        self.node_apps[node_id] = list(hello.get("apps", []))
        self.ring.add(node_id)
        self.routed_by_node.setdefault(node_id, 0)
        moved = []
        for app in self.node_apps[node_id]:
            old = self.placement.get(app)
            target = self.ring.place(app, among=self._advertisers(app))
            if target == node_id and old != node_id:
                self.placement[app] = node_id
                moved.append(app)
                self._emit({"k": "place", "app": app, "node": node_id})
                if old is not None:
                    mig = {"app": app, "from": old, "to": node_id,
                           "at": round(time.monotonic() - self._t0, 3),
                           "reason": "node_join"}
                    self.migrations.append(mig)
                    self._emit({"k": "migration", "m": mig})
        _reg().gauge("repro_cluster_nodes",
                     "live cluster nodes").set(len(self.clients))
        _LOG.info("node-joined", node=node_id, moved=len(moved))
        return {"node": node_id, "moved": moved}

    def _choose(self, app: str, nodes: list[str]) -> str:
        if self.strategy == "sharing" and self.hot_sets.get(app):
            hs = self.hot_sets[app]
            ring_scores = {n: self.ring.score(n, app) for n in nodes}
            top = max(ring_scores.values())
            resident = {
                n: [self.hot_sets.get(a, [])
                    for a, o in self.placement.items() if o == n]
                for n in nodes}
            return max(nodes, key=lambda n: (
                hot_set_affinity(hs, resident[n])
                + 0.01 * (ring_scores[n] / top), n))
        return self.ring.place(app, among=nodes)

    # ------------------------------------------------------------- serving
    def route(self, app: str, handler: Optional[str] = None) -> dict:
        """Forward one invocation to the app's owner; on a dead node,
        fail over (the node is declared lost, apps re-place, and this
        invocation goes to the new owner).  The failover loop runs
        under :class:`~repro.cluster.ha.RetryPolicy`: up to
        ``retry.attempts`` owners are tried within ``deadline_s``,
        with jittered backoff between consecutive failures.  The
        invocation frame itself is never resent to the *same* node —
        only re-placed — so a node that admitted the request can never
        be fed it twice."""
        if self._halted:
            raise RuntimeError(
                f"router {self.router_id} was halted")
        tracer = get_tracer()
        t0 = now_ms() if tracer.enabled else 0.0
        retry = self.retry
        rng = retry.rng()
        deadline = time.monotonic() + retry.deadline_s
        for attempt in range(retry.attempts):
            node_id = self.placement.get(app)
            if node_id is None or node_id not in self.clients:
                break  # no live owner: shed below
            if self.fault_hook is not None:
                try:
                    self.fault_hook("route", app=app, node=node_id)
                except NodeLossFault:
                    self.node_leave(node_id, reason="node_loss")
                    continue
            try:
                reply = self.clients[node_id].call(
                    {"app": app, "handler": handler})
            except (ConnectionError, OSError, FrameClosed,
                    FrameError):
                self.node_leave(node_id, reason="connection_lost")
                if attempt + 1 < retry.attempts:
                    delay = retry.backoff_s(attempt, rng)
                    if time.monotonic() + delay >= deadline:
                        break
                    if delay > 0:
                        time.sleep(delay)
                continue
            self.routed_by_node[node_id] = \
                self.routed_by_node.get(node_id, 0) + 1
            self._emit({"k": "route", "node": node_id})
            _reg().counter("repro_cluster_routed_total",
                           "invocations routed, by node and outcome",
                           labels=("node", "outcome")).labels(
                node=node_id,
                outcome=str(reply.get("outcome", "error"))).inc()
            if tracer.enabled:
                tracer.add("cluster.route", trace_id=new_id(),
                           t_start_ms=t0,
                           duration_ms=now_ms() - t0,
                           attrs={"app": app, "node": node_id,
                                  "outcome": reply.get("outcome")})
            return {**reply, "node": node_id}
        self.router_sheds += 1
        self._emit({"k": "shed"})
        return {"ok": False, "outcome": "no-node",
                "error": f"no surviving owner for {app!r}"}

    # -------------------------------------------------------------- finish
    def _harvest(self, node_id: str, reply: dict) -> None:
        if reply.get("event") == "summary":
            self._node_payloads[node_id] = reply.get("summary") or {}
            self._node_samples[node_id] = [
                float(x) for x in reply.get("latency_samples") or []]
            # replicate the harvested ledger: a standby promoted after
            # this node died still owes its counts to the rollup
            self._emit({"k": "harvest", "node": node_id,
                        "summary": self._node_payloads[node_id],
                        "samples": self._node_samples[node_id]})

    def shutdown(self, *, flush: bool = False) -> dict:
        """Drain every node, merge ledgers and sample pools, return
        the ``cluster_summary`` payload."""
        for node_id, client in sorted(self.clients.items()):
            if node_id in self._node_payloads:
                continue
            try:
                self._harvest(node_id, client.call(
                    {"cmd": "shutdown", "flush": flush}))
            except (ConnectionError, OSError, FrameClosed,
                    FrameError) as exc:
                _LOG.warning("shutdown-lost", node=node_id,
                             error=repr(exc))
            finally:
                client.close()
        if self._rep is not None:
            self._rep.stop()
        lat_pool = PercentilePool.merge([
            PercentilePool.of_lists([samples])
            for samples in self._node_samples.values()])
        # "nodes" distinguishes how each node left the topology:
        # live at shutdown, lost (crash / declared dead) or departed
        # (clean plan_leave) — ghosts can no longer masquerade as
        # advertisers (node_apps is scrubbed on both exits)
        router_info = {
            "id": self.router_id,
            "epoch": self.epoch,
            "sheds": self.router_sheds,
            "nodes": sorted(set(self.clients) | set(self.lost_nodes)
                            | set(self.departed)),
            "departed": sorted(self.departed),
            "retry": self.retry.to_dict(),
        }
        extra: dict = {}
        if any(self.handoffs.values()):
            extra["handoffs"] = dict(self.handoffs)
        payload = make_cluster_summary_payload(
            source="cluster-route",
            strategy=self.strategy,
            node_payloads=self._node_payloads,
            lat_pool=lat_pool,
            placement=self.placement,
            migrations=self.migrations,
            lost_nodes=self.lost_nodes,
            routed_by_node=self.routed_by_node,
            router=router_info,
            duration_s=round(time.monotonic() - self._t0, 3),
            **extra,
        )
        return payload
