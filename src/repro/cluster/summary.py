"""Cluster-level rollup: merge per-node fleet summaries honestly.

Every node — simulated :class:`~repro.cluster.sim.SimNode` or a real
socket-fed agent — ultimately produces one ``fleet_summary`` payload
(the PR 3/4 schema).  The cluster summary is the rollup over those:
counts add, but **percentiles do not** — a p99 of per-node p99s is not
the global p99 whenever nodes host different apps.  So the router
merges the nodes' *sample pools*
(:meth:`repro.pool.simulator.PercentilePool.merge`) and reads true
global quantiles, and the per-node payloads ride along under
``per_node`` for drill-down.

Conservation is checked at both scopes and recorded in the payload:
``requests == served + sheds + flushed + errors + abandoned`` must
hold per node (each node's own accounting) and globally (the router
must not have lost a request between nodes, including across
migrations and node loss).
"""

from __future__ import annotations

import math
from typing import Optional

from repro.pool.simulator import PercentilePool

CONSERVATION_EXPR = ("requests == served + sheds + flushed + errors "
                     "+ abandoned")

# the counters that must add up, with their defaults-when-absent
_COUNT_KEYS = ("requests", "served", "cold_starts", "sheds", "flushed",
               "errors", "abandoned")


def node_conserves(payload: dict) -> bool:
    """Does one node's fleet_summary payload conserve requests?"""
    rhs = sum(int(payload.get(k, 0) or 0)
              for k in ("served", "sheds", "flushed", "errors",
                        "abandoned"))
    return int(payload.get("requests", 0) or 0) == rhs


def _num(x: float) -> float:
    return 0.0 if (x is None or math.isnan(x)) else round(x, 3)


def make_cluster_summary_payload(
        *, source: str, strategy: str,
        node_payloads: dict[str, dict],
        lat_pool: Optional[PercentilePool] = None,
        wait_pool: Optional[PercentilePool] = None,
        placement: Optional[dict[str, str]] = None,
        migrations: Optional[list[dict]] = None,
        lost_nodes: Optional[list[str]] = None,
        routed_by_node: Optional[dict[str, int]] = None,
        **optional) -> dict:
    """The one constructor for ``cluster_summary`` artifact payloads
    (mirroring :func:`repro.pool.fleet.make_fleet_summary_payload`).

    ``node_payloads`` maps node id -> that node's ``fleet_summary``
    payload; ``lat_pool``/``wait_pool`` are the merged sample pools for
    true global percentiles (per-node percentiles are *not* averaged —
    absent pools report 0.0 and flag ``percentiles_merged: false``).
    ``routed_by_node`` is the router's own admission count per node;
    when present it must match each node's reported ``requests`` for
    global conservation to hold.
    """
    totals = {k: 0 for k in _COUNT_KEYS}
    per_node = []
    per_node_holds: dict[str, bool] = {}
    lost = set(lost_nodes or ())
    for node_id in sorted(node_payloads):
        payload = node_payloads[node_id]
        holds = node_conserves(payload)
        per_node_holds[node_id] = holds
        row = {"node": node_id, "lost": node_id in lost,
               "conservation_holds": holds}
        for k in _COUNT_KEYS:
            v = int(payload.get(k, 0) or 0)
            row[k] = v
            totals[k] += v
        for k in ("cold_start_ratio", "p50_ms", "p99_ms",
                  "memory_gb_s", "budget_mb", "shared_base_mb"):
            if payload.get(k) is not None:
                row[k] = payload[k]
        if routed_by_node is not None:
            row["routed"] = int(routed_by_node.get(node_id, 0))
        per_node.append(row)

    requests = totals["requests"]
    accounted = sum(totals[k] for k in ("served", "sheds", "flushed",
                                        "errors", "abandoned"))
    holds = requests == accounted and all(per_node_holds.values())
    routed_total = None
    if routed_by_node is not None:
        routed_total = sum(routed_by_node.values())
        # the router-side ledger and the nodes' ledgers must agree,
        # per node and in total — a mismatch means a request was
        # dropped (or double-fed) in flight between router and node
        holds = holds and routed_total == requests and all(
            int(routed_by_node.get(r["node"], 0)) == r["requests"]
            for r in per_node)

    conservation = {
        "expression": CONSERVATION_EXPR,
        "holds": holds,
        "requests": requests,
        "accounted": accounted,
        "per_node": per_node_holds,
    }
    if routed_total is not None:
        conservation["routed"] = routed_total

    payload = {
        "source": source,
        "strategy": strategy,
        "nodes": len(node_payloads),
        "requests": requests,
        "served": totals["served"],
        "cold_starts": totals["cold_starts"],
        "cold_start_ratio": round(
            totals["cold_starts"] / max(requests, 1), 4),
        "p50_ms": _num(lat_pool.percentile(0.50)) if lat_pool else 0.0,
        "p99_ms": _num(lat_pool.percentile(0.99)) if lat_pool else 0.0,
        "sheds": totals["sheds"],
        "flushed": totals["flushed"],
        "errors": totals["errors"],
        "abandoned": totals["abandoned"],
        "conservation": conservation,
        "per_node": per_node,
        "percentiles_merged": lat_pool is not None,
    }
    if wait_pool is not None:
        payload["queue_wait_p50_ms"] = _num(wait_pool.percentile(0.50))
        payload["queue_wait_p99_ms"] = _num(wait_pool.percentile(0.99))
    if placement is not None:
        payload["placement"] = dict(sorted(placement.items()))
    if migrations is not None:
        payload["migrations"] = list(migrations)
    if lost_nodes is not None:
        payload["lost_nodes"] = sorted(lost)
    mem = [p.get("memory_gb_s") for p in node_payloads.values()]
    if any(m is not None for m in mem):
        payload["memory_gb_s"] = round(
            sum(m for m in mem if m is not None), 3)
    payload.update(optional)
    return payload
