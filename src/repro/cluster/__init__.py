"""Sharded cluster controller: multi-node fleets, sharing-aware
placement, and a socket-fed router (ISSUE 8).

Layers, bottom up:

* :mod:`repro.cluster.protocol` — length-prefixed JSON frames (sync
  and asyncio codecs) replacing the daemon's stdin JSONL feed;
* :mod:`repro.cluster.ring` — weighted rendezvous hashing plus the
  sharing-aware placement planner built on ``repro.pool.sharing``;
* :mod:`repro.cluster.workload` — deterministic synthetic multi-app
  workloads with known library-sharing structure;
* :mod:`repro.cluster.sim` — the cluster-scale simulator (millions of
  synthetic invocations, strategy comparison);
* :mod:`repro.cluster.node` — the node agent: a ``FleetDaemon`` served
  over an asyncio socket to many concurrent feeders;
* :mod:`repro.cluster.router` — the global router driving real node
  agents over sockets;
* :mod:`repro.cluster.summary` — the ``cluster_summary`` payload
  constructor and the per-node/global conservation check;
* :mod:`repro.cluster.ha` — router high availability (ISSUE 10):
  lease-based leader election over node-agent witnesses, ledger
  replication to hot standbys, promotion with reconciliation, and the
  unified :class:`~repro.cluster.ha.RetryPolicy` for every socket hop.
"""

from repro.cluster.protocol import (MAX_FRAME, FrameClosed, FrameError,
                                    encode_frame, read_frame,
                                    recv_frame, send_frame,
                                    write_frame)
from repro.cluster.ring import (STRATEGIES, ConsistentHashRing,
                                hot_set_affinity, plan_placement)
from repro.cluster.workload import (ClusterWorkload,
                                    synthetic_cluster_workload)
from repro.cluster.summary import (CONSERVATION_EXPR, node_conserves,
                                   make_cluster_summary_payload)
from repro.cluster.sim import (ClusterSimulator, SimNode,
                               compare_strategies)
from repro.cluster.node import PROTOCOL_VERSION, NodeAgent
from repro.cluster.router import ClusterRouter, NodeClient
from repro.cluster.ha import (ElectionLost, LeaseWitness,
                              LedgerReplicator, ReplicatedRouter,
                              RetryExhausted, RetryPolicy,
                              StandbyRouter, elect)

__all__ = [
    "MAX_FRAME", "FrameClosed", "FrameError", "encode_frame",
    "read_frame", "recv_frame", "send_frame", "write_frame",
    "STRATEGIES", "ConsistentHashRing", "hot_set_affinity",
    "plan_placement",
    "ClusterWorkload", "synthetic_cluster_workload",
    "CONSERVATION_EXPR", "node_conserves",
    "make_cluster_summary_payload",
    "ClusterSimulator", "SimNode", "compare_strategies",
    "PROTOCOL_VERSION", "NodeAgent",
    "ClusterRouter", "NodeClient",
    "ElectionLost", "LeaseWitness", "LedgerReplicator",
    "ReplicatedRouter", "RetryExhausted", "RetryPolicy",
    "StandbyRouter", "elect",
]
