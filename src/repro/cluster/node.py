"""The cluster node agent: a socket-served fleet daemon.

Promotes the single-host :class:`~repro.pool.daemon.FleetDaemon` from
its stdin JSONL feed (one feeder, process lifetime = feed lifetime) to
a TCP server speaking the length-prefixed frame protocol
(:mod:`repro.cluster.protocol`):

* **many concurrent feeders** — each connection is an independent
  request/reply stream served by its own asyncio task; a router, a
  load generator and an operator polling ``stats`` can all talk to the
  node at once.  Admission itself stays thread-safe in the backend
  (the same bounded queues as the daemon), the event loop only does
  framing;
* **graceful drain on disconnect** — a feeder vanishing mid-stream
  never strands requests: everything it admitted is already in the
  bounded queues and drains normally.  With ``drain_on_disconnect``
  (the CLI smoke's mode) the agent additionally treats "last feeder
  gone" as the drain signal, mirroring the stdin daemon's EOF
  semantics;
* the full daemon surface rides over the wire: ``hello`` (node
  identity + apps), per-invocation frames, ``stats``, ``rewarm``,
  ``drain``/``shutdown`` (replies with the final ``fleet_summary``
  payload plus capped raw latency samples so the router can merge
  *true* global percentiles instead of averaging per-node ones).

The agent runs its asyncio loop on a dedicated thread so synchronous
callers (tests, the CLI) drive it like any other component:
``start() -> ... -> result()``.
"""

from __future__ import annotations

import asyncio
import threading
import time
from typing import Optional

from repro.obs.log import get_logger
from repro.pool.daemon import FleetDaemon
from repro.pool.trace import Request
from repro.cluster.ha import LeaseWitness
from repro.cluster.protocol import (FrameClosed, FrameError,
                                    read_frame, write_frame)

_LOG = get_logger("cluster.node")

PROTOCOL_VERSION = 1


def _reg():
    from repro.obs.metrics import default_registry
    return default_registry()


class NodeAgent:
    """One node: a :class:`FleetDaemon` behind a frame-protocol socket.

    ``backend`` is any daemon backend (sim or real zygote fleet); the
    agent owns the daemon shell around it (rewarm timer, drain
    semantics, summary artifact).
    """

    def __init__(self, backend, *, node_id: str,
                 host: str = "127.0.0.1", port: int = 0,
                 rewarm_interval_s: float = 0.0,
                 summary_path: Optional[str] = None,
                 drain_timeout_s: Optional[float] = 30.0,
                 drain_on_disconnect: bool = False,
                 latency_sample_cap: int = 50_000,
                 fault_hook=None) -> None:
        self.node_id = node_id
        self.host = host
        self.port = port  # 0 = ephemeral; real port known after start()
        self.drain_on_disconnect = drain_on_disconnect
        self.latency_sample_cap = latency_sample_cap
        self.daemon = FleetDaemon(
            backend, rewarm_interval_s=rewarm_interval_s,
            summary_path=summary_path,
            drain_timeout_s=drain_timeout_s, fault_hook=fault_hook)
        # HA: this agent is one vote in the router leader election
        # (stdlib lease state machine served under the "lease" cmd)
        self.lease = LeaseWitness(node_id)
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.base_events.Server] = None
        self._thread: Optional[threading.Thread] = None
        self._ready = threading.Event()
        self._done = threading.Event()
        self._boot: dict = {}
        self._result: Optional[dict] = None
        self._start_exc: Optional[BaseException] = None
        self._t0 = 0.0
        self._conns = 0
        self._ever_connected = False
        self._conn_lock = threading.Lock()

    # ----------------------------------------------------------- lifecycle
    def start(self) -> dict:
        """Boot the backend, bind the socket, start serving.  Returns
        ``{"node": ..., "host": ..., "port": ..., "apps": [...]}``."""
        self._boot = self.daemon.start(f"node-{self.node_id}")
        self._t0 = time.monotonic()
        self._thread = threading.Thread(
            target=self._run_loop, name=f"node-agent-{self.node_id}",
            daemon=True)
        self._thread.start()
        self._ready.wait(timeout=10.0)
        if self._start_exc is not None:
            raise RuntimeError(
                f"node agent {self.node_id} failed to bind "
                f"{self.host}:{self.port}") from self._start_exc
        if not self._ready.is_set():
            raise RuntimeError(
                f"node agent {self.node_id} did not come up")
        return {"node": self.node_id, "host": self.host,
                "port": self.port, "protocol": PROTOCOL_VERSION,
                **self._boot}

    def _run_loop(self) -> None:
        asyncio.run(self._serve())

    async def _serve(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._stop_evt = asyncio.Event()
        if self.daemon.draining:  # shutdown won the race with startup
            self._stop_evt.set()
        try:
            self._server = await asyncio.start_server(
                self._handle_conn, self.host, self.port)
        except OSError as exc:
            self._start_exc = exc
            self._ready.set()
            return
        self.port = self._server.sockets[0].getsockname()[1]
        self._ready.set()
        _LOG.info("listening", node=self.node_id, host=self.host,
                  port=self.port)
        async with self._server:
            await self._stop_evt.wait()
        # out of the loop thread: sockets are closed, drain the fleet
        # synchronously from stop()/result() callers

    def request_shutdown(self) -> None:
        """Idempotent, callable from any thread (signal handlers too):
        stop accepting, end the serve loop; drain happens in
        :meth:`result`."""
        self.daemon.request_shutdown()
        loop = self._loop
        stop_evt = getattr(self, "_stop_evt", None)
        if loop is not None and stop_evt is not None:
            try:
                loop.call_soon_threadsafe(stop_evt.set)
            except RuntimeError:
                pass  # loop already closed

    def _final_payload(self, *, end_t: Optional[float] = None,
                       flush: Optional[bool] = None) -> dict:
        """Drain the daemon (idempotent) and cache the final
        ``fleet_summary`` payload.  Does NOT stop the serve loop —
        callers decide when the socket goes away, so the summary reply
        always reaches the feeder that asked for it."""
        payload = self.daemon.shutdown(
            end_t=(time.monotonic() - self._t0
                   if end_t is None else end_t),
            flush=flush)
        self._result = payload
        self._done.set()
        return payload

    def result(self, *, end_t: Optional[float] = None,
               flush: Optional[bool] = None) -> dict:
        """Drain and return the node's final ``fleet_summary`` payload
        (the daemon's graceful-drain semantics: in-flight work
        finishes, queued work flushes)."""
        if self._result is None:
            self.request_shutdown()
            if (self._thread is not None
                    and self._thread is not threading.current_thread()):
                self._thread.join(timeout=30.0)
            self._final_payload(end_t=end_t, flush=flush)
        return self._result

    def serve_forever(self) -> dict:
        """Block until a shutdown frame / signal ends the agent, then
        drain (the ``repro cluster serve`` foreground path)."""
        if self._thread is not None:
            while self._thread.is_alive():
                self._thread.join(timeout=0.2)
        return self.result()

    # ------------------------------------------------------------ protocol
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        peer = writer.get_extra_info("peername")
        with self._conn_lock:
            self._conns += 1
            self._ever_connected = True
        _reg().gauge("repro_cluster_node_feeders",
                     "open feeder connections per node",
                     labels=("node",)).labels(
            node=self.node_id).set(self._conns)
        try:
            while not self.daemon.draining:
                try:
                    frame = await read_frame(reader)
                except FrameClosed:
                    break
                except FrameError as exc:
                    # a desynced peer cannot be resynchronized: answer
                    # once, then drop the connection
                    await self._safe_reply(writer, {
                        "ok": False, "node": self.node_id,
                        "error": f"protocol: {exc}"})
                    break
                reply = self._dispatch(frame)
                await self._safe_reply(writer, reply)
                if reply.get("event") == "summary":
                    # the summary is on the wire; now the loop may end
                    self.request_shutdown()
                    break
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass
            last = False
            with self._conn_lock:
                self._conns -= 1
                last = self._conns == 0 and self._ever_connected
            _reg().gauge("repro_cluster_node_feeders",
                         "open feeder connections per node",
                         labels=("node",)).labels(
                node=self.node_id).set(self._conns)
            _LOG.debug("feeder-closed", node=self.node_id,
                       peer=str(peer))
            if last and self.drain_on_disconnect:
                # stdin-EOF semantics over sockets: last feeder gone =
                # end of feed -> graceful drain
                self.request_shutdown()

    async def _safe_reply(self, writer: asyncio.StreamWriter,
                          obj: dict) -> None:
        try:
            await write_frame(writer, obj)
        except (ConnectionError, OSError):
            pass  # feeder vanished mid-reply; its work still drains

    def _dispatch(self, evt: dict) -> dict:
        """One frame in, one reply out — the stdin JSONL command
        surface, framed."""
        cmd = evt.get("cmd")
        if cmd == "hello":
            # "counts" extends the reply with this node's own
            # admission ledger so a promoted standby can reconcile its
            # replicated routed_by_node against ground truth
            counts: dict = {}
            try:
                snap = self.daemon.backend.snapshot()
                counts = {"requests": int(snap.get("requests", 0)),
                          "served": int(snap.get("served", 0))}
            except Exception:  # counts are best-effort extras
                counts = {}
            return {"ok": True, "node": self.node_id,
                    "protocol": PROTOCOL_VERSION,
                    "mode": self._boot.get("mode"),
                    "apps": self._boot.get("apps", []),
                    "counts": counts}
        if cmd == "stats":
            return {"ok": True, "node": self.node_id,
                    "stats": self.daemon.backend.snapshot(),
                    "rewarm_ticks": self.daemon.rewarm_ticks,
                    "lease": self.lease.state(),
                    "metrics": _reg().snapshot()}
        if cmd == "lease":
            # leader-election witness: grant/renew/release one lease
            return {"ok": True, "node": self.node_id,
                    **self.lease.handle(evt)}
        if cmd == "handoff_export":
            # warm handoff, departing side: ship the app's deployed
            # report artifact (and sim profile) to the router
            try:
                export = self.daemon.backend.export_app(evt.get("app"))
            except KeyError as exc:
                return {"ok": False, "node": self.node_id,
                        "error": str(exc)}
            return {"ok": True, "node": self.node_id, **export}
        if cmd == "prewarm":
            # warm handoff, receiving side: boot the app's zygote from
            # the shipped state *before* the placement flips
            try:
                out = self.daemon.backend.prewarm_app(
                    evt.get("app"), report=evt.get("report"),
                    profile=evt.get("profile"))
            except KeyError as exc:
                return {"ok": False, "node": self.node_id,
                        "error": str(exc)}
            return {"ok": True, "node": self.node_id, **out,
                    "warm": bool(out.get("warm"))}
        if cmd == "rewarm":
            return {"ok": True, "node": self.node_id,
                    "rewarm": self.daemon.rewarm_now()}
        if cmd in ("drain", "shutdown"):
            # flush=False: end-of-feed semantics — queued work is
            # served before the summary is cut (the router asked us to
            # finish, not to abandon).  return_queued=True (planned
            # handoff): queued requests are counted flushed here AND
            # returned in the reply so the router re-admits them at
            # the new owners instead of dropping them.
            queued: list = []
            if evt.get("return_queued"):
                try:
                    queued = self.daemon.backend.collect_queued()
                except Exception:
                    queued = []
            payload = self._final_payload(
                flush=bool(evt.get("flush", False)))
            samples = []
            try:
                samples = self.daemon.backend.latency_samples(
                    self.latency_sample_cap)
            except Exception:  # samples are best-effort extras
                samples = []
            reply = {"ok": True, "node": self.node_id,
                     "event": "summary", "summary": payload,
                     "latency_samples": samples}
            if evt.get("return_queued"):
                reply["queued"] = queued
            return reply
        if cmd is not None:
            return {"ok": False, "node": self.node_id,
                    "error": f"unknown cmd {cmd!r}"}
        if "app" not in evt:
            return {"ok": False, "node": self.node_id,
                    "error": "need 'app' or 'cmd'"}
        req = Request(t=time.monotonic() - self._t0, app=evt["app"],
                      handler=evt.get("handler"))
        try:
            outcome = self.daemon.submit(req)
        except KeyError as exc:
            return {"ok": False, "node": self.node_id,
                    "error": str(exc)}
        return {"ok": outcome not in ("shed", "draining"),
                "node": self.node_id, "outcome": outcome}
