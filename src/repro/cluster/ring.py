"""Consistent hashing and sharing-aware app placement.

The router must answer one question deterministically on every node:
*which node owns app X right now?* — and keep the answer as stable as
possible when the node set changes.  We use **rendezvous (highest-
random-weight) hashing**, the consistent-hashing variant with provably
minimal churn: every ``(app, node)`` pair gets a pseudo-random score
``h = sha256(seed, node, app)`` mapped to ``(0, 1]``, and the app lives
on the node maximizing ``-weight / ln(h)`` (the standard weighted-HRW
transform, so a node's capacity weight scales its expected share
linearly).  Consequences the property tests pin down:

* **leave**: exactly the departed node's apps move (everyone else's
  argmax is unchanged);
* **join**: the only possible move is *onto* the new node, and each app
  moves independently with probability ``w_new / w_total`` — expected
  churn ~K/N of K apps on N equal nodes;
* **determinism**: placement is a pure function of (seed, node set,
  weights, app) — every router replica computes the same map with no
  coordination.

Sharing-aware placement (:func:`plan_placement` with
``strategy="sharing"``) layers the SLIMSTART affinity signal on top:
apps whose measured hot sets overlap (scored with
:mod:`repro.pool.sharing`) are pulled onto the same node so the PR 5
base zygote actually shares their library pages, with the ring score as
tiebreak and a load cap so affinity cannot pile every app onto one
node.  It trades a little of plain hashing's churn optimality for
memory locality; the router's rebalance keeps its moves bounded by
re-placing only affected apps (sticky placement).
"""

from __future__ import annotations

import hashlib
import math
import random
from typing import Iterable, Optional

from repro.pool.sharing import intersect_hot_sets

STRATEGIES = ("sharing", "hash", "random")


def _hash01(seed: int, node: str, key: str) -> float:
    """Pseudo-random in (0, 1], deterministic across processes (never
    Python's salted ``hash``)."""
    digest = hashlib.sha256(
        f"{seed}\x00{node}\x00{key}".encode()).digest()
    # 53 bits -> exact float; +1 keeps it off 0 so ln() is finite
    n = int.from_bytes(digest[:8], "big") >> 11
    return (n + 1) / float(1 << 53)


class ConsistentHashRing:
    """Weighted rendezvous-hashing ring over named nodes."""

    def __init__(self, nodes: Iterable[str] = (), *, seed: int = 0,
                 weights: Optional[dict[str, float]] = None) -> None:
        self.seed = seed
        self._weights: dict[str, float] = {}
        for node in nodes:
            self.add(node, (weights or {}).get(node, 1.0))

    # ------------------------------------------------------------ topology
    @property
    def nodes(self) -> tuple[str, ...]:
        return tuple(sorted(self._weights))

    def __len__(self) -> int:
        return len(self._weights)

    def __contains__(self, node: str) -> bool:
        return node in self._weights

    def add(self, node: str, weight: float = 1.0) -> None:
        if weight <= 0:
            raise ValueError(f"node weight must be > 0, got {weight}")
        self._weights[node] = float(weight)

    def remove(self, node: str) -> None:
        self._weights.pop(node, None)

    # ----------------------------------------------------------- placement
    def score(self, node: str, key: str) -> float:
        """Weighted-HRW score; the owning node maximizes it."""
        w = self._weights[node]
        return -w / math.log(_hash01(self.seed, node, key))

    def place(self, key: str,
              among: Optional[Iterable[str]] = None) -> str:
        """The node owning ``key`` (optionally restricted to ``among``,
        e.g. the real-mode nodes that actually deploy the app).  Ties
        are impossible in practice (sha256), but break by node name so
        the map stays a pure function regardless."""
        candidates = self.nodes if among is None else tuple(
            sorted(n for n in among if n in self._weights))
        if not candidates:
            raise ValueError(f"no candidate nodes for {key!r}")
        return max(candidates, key=lambda n: (self.score(n, key), n))

    def place_all(self, keys: Iterable[str]) -> dict[str, str]:
        return {k: self.place(k) for k in keys}


# ---------------------------------------------------------------------------
# sharing-aware planner
# ---------------------------------------------------------------------------

def hot_set_affinity(hot_set: list[str],
                     node_hot_sets: list[list[str]]) -> float:
    """How much of ``hot_set`` the node's resident apps already keep
    paged in: |modules shared with the node| / |hot_set|, prefix-aware
    (``fakelib_x`` covers ``fakelib_x.core``) via
    :func:`repro.pool.sharing.intersect_hot_sets`.  0 for an empty node
    or a disjoint app; 1 when every hot module is already resident."""
    if not hot_set or not node_hot_sets:
        return 0.0
    union: set[str] = set()
    for hs in node_hot_sets:
        union.update(hs)
    shared = intersect_hot_sets(
        {"app": list(hot_set), "node": sorted(union)}, min_members=2)
    return len(shared) / len(set(hot_set))


def plan_placement(apps: Iterable[str], ring: ConsistentHashRing, *,
                   strategy: str = "sharing",
                   hot_sets: Optional[dict[str, list[str]]] = None,
                   seed: int = 0,
                   max_load_factor: float = 1.0) -> dict[str, str]:
    """Assign every app to a node.

    * ``hash`` — pure weighted rendezvous hashing (minimal churn).
    * ``random`` — seeded uniform choice (the comparison baseline).
    * ``sharing`` — greedy affinity packing: apps are visited in
      hot-set-signature order, which walks library families
      contiguously (siblings share their family module, so their
      sorted hot sets are lexicographic neighbours).  Each app goes to
      the node maximizing measured hot-set overlap with the apps
      already placed there; the ring score breaks ties (and places
      apps with no profile).  The load cap — ``max_load_factor`` times
      the balanced share K/N, default balanced — closes full nodes,
      because modules shared fleet-wide (a common runtime) give
      *every* non-empty node positive affinity and pure affinity
      packing would collapse the fleet onto one hot node.

    Deterministic for a fixed (seed, app set, hot sets, node set).
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r} "
                         f"(one of {STRATEGIES})")
    apps = sorted(set(apps))
    if not len(ring):
        raise ValueError("cannot place apps on an empty ring")
    if strategy == "hash":
        return ring.place_all(apps)
    if strategy == "random":
        rng = random.Random(seed)
        nodes = ring.nodes
        return {app: rng.choice(nodes) for app in apps}

    hot_sets = hot_sets or {}
    cap = max(1, math.ceil(max_load_factor * len(apps) / len(ring)))
    by_node: dict[str, list[list[str]]] = {n: [] for n in ring.nodes}
    placement: dict[str, str] = {}
    # signature order: sorted hot-set tuples put family siblings next
    # to each other, so each family seeds a node before the next one
    # starts; name tiebreak keeps the order total
    order = sorted(apps,
                   key=lambda a: (tuple(sorted(hot_sets.get(a, []))),
                                  a))
    # ring scores span orders of magnitude; affinity is in [0, 1].
    # Normalizing the ring score per-app into [0, 1) and weighting it
    # down keeps it a tiebreak: any real overlap dominates.
    for app in order:
        hs = hot_sets.get(app, [])
        open_nodes = tuple(n for n in ring.nodes
                           if len(by_node[n]) < cap) or ring.nodes
        ring_scores = {n: ring.score(n, app) for n in open_nodes}
        top = max(ring_scores.values())
        scores = {
            node: (hot_set_affinity(hs, by_node[node]) if hs else 0.0)
            + 0.01 * (ring_scores[node] / top)
            for node in open_nodes
        }
        best = max(open_nodes, key=lambda n: (scores[n], n))
        placement[app] = best
        by_node[best].append(list(hs))
    return placement
