"""Length-prefixed JSON frames: the cluster's wire protocol.

The single-host daemon reads newline-delimited JSON from *stdin* —
exactly one feeder, no framing, no concurrency.  The cluster node
agent (:mod:`repro.cluster.node`) instead listens on a TCP socket that
many feeders (routers, load generators, operators running ``stats``)
share concurrently, so the protocol needs real framing:

* every message is ``[4-byte big-endian unsigned length][UTF-8 JSON]``;
* length counts the JSON bytes only (the prefix excluded) and must be
  ``0 < length <= MAX_FRAME`` — a peer announcing more is protocol
  abuse (or desync) and the connection is dropped rather than letting
  one feeder balloon the agent's memory;
* requests and replies alternate per connection (simple RPC); separate
  connections are fully independent, which is how concurrent feeders
  multiplex — per-connection ordering, no cross-connection ordering.

Why not keep JSONL over the socket?  Newline framing breaks the moment
a payload embeds a newline (pretty-printed summaries, tracebacks) and
gives a desynced reader no way to resynchronize; a length prefix makes
message boundaries explicit and cheap to validate.

Both a blocking codec (for :class:`NodeClient`-style callers and
tests) and asyncio stream helpers (for the agent's server loop) are
provided so the two sides cannot drift.
"""

from __future__ import annotations

import asyncio
import json
import socket
import struct

MAX_FRAME = 16 * 1024 * 1024  # 16 MiB: a full fleet summary is ~KBs

_LEN = struct.Struct(">I")


class FrameError(RuntimeError):
    """Protocol violation: bad length prefix, oversized frame, or a
    frame whose body is not valid JSON."""


class FrameClosed(EOFError):
    """The peer closed the connection cleanly between frames."""


def encode_frame(obj: dict) -> bytes:
    """Serialize one message to its on-wire bytes."""
    body = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    if len(body) > MAX_FRAME:
        raise FrameError(f"frame of {len(body)} bytes exceeds "
                         f"MAX_FRAME={MAX_FRAME}")
    return _LEN.pack(len(body)) + body


def _check_length(n: int) -> None:
    if n == 0 or n > MAX_FRAME:
        raise FrameError(f"invalid frame length {n} "
                         f"(must be 1..{MAX_FRAME})")


def _decode_body(body: bytes) -> dict:
    try:
        obj = json.loads(body.decode("utf-8"))
    except (UnicodeDecodeError, ValueError) as exc:
        raise FrameError(f"undecodable frame body: {exc}") from exc
    if not isinstance(obj, dict):
        raise FrameError(f"frame body must be a JSON object, "
                         f"got {type(obj).__name__}")
    return obj


# ---------------------------------------------------------------------------
# blocking side (clients, tests)
# ---------------------------------------------------------------------------

def _recv_exact(sock: socket.socket, n: int, *,
                header: bool) -> bytes:
    chunks = []
    remaining = n
    while remaining:
        chunk = sock.recv(remaining)
        if not chunk:
            if header and remaining == n:
                raise FrameClosed("peer closed between frames")
            raise FrameError(
                f"peer closed mid-frame ({n - remaining}/{n} bytes)")
        chunks.append(chunk)
        remaining -= len(chunk)
    return b"".join(chunks)


def send_frame(sock: socket.socket, obj: dict) -> None:
    sock.sendall(encode_frame(obj))


def recv_frame(sock: socket.socket) -> dict:
    """Read one frame; raises :class:`FrameClosed` on clean EOF at a
    frame boundary, :class:`FrameError` on truncation or garbage."""
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size, header=True))
    _check_length(n)
    return _decode_body(_recv_exact(sock, n, header=False))


def request(sock: socket.socket, obj: dict) -> dict:
    """One blocking RPC round-trip: send a frame, read the reply.
    The building block for one-shot control calls (lease grants,
    handshake probes) that do not want a :class:`NodeClient`'s
    connection lifecycle."""
    send_frame(sock, obj)
    return recv_frame(sock)


# ---------------------------------------------------------------------------
# asyncio side (the node agent's server loop)
# ---------------------------------------------------------------------------

async def read_frame(reader: asyncio.StreamReader) -> dict:
    try:
        head = await reader.readexactly(_LEN.size)
    except asyncio.IncompleteReadError as exc:
        if not exc.partial:
            raise FrameClosed("peer closed between frames") from exc
        raise FrameError("peer closed mid-length-prefix") from exc
    (n,) = _LEN.unpack(head)
    _check_length(n)
    try:
        body = await reader.readexactly(n)
    except asyncio.IncompleteReadError as exc:
        raise FrameError(
            f"peer closed mid-frame ({len(exc.partial)}/{n} bytes)"
        ) from exc
    return _decode_body(body)


async def write_frame(writer: asyncio.StreamWriter, obj: dict) -> None:
    writer.write(encode_frame(obj))
    await writer.drain()
