"""Assigned architecture registry.

Each module defines ``CONFIG`` (the exact assigned configuration) and
``reduced()`` (a small same-family config for CPU smoke tests).  Look
ups accept the public dashed ids (``--arch granite-moe-1b-a400m``).
"""

from importlib import import_module

ARCH_IDS = [
    "granite-moe-1b-a400m",
    "olmoe-1b-7b",
    "xlstm-350m",
    "qwen2.5-32b",
    "gemma2-9b",
    "gemma3-27b",
    "granite-8b",
    "pixtral-12b",
    "recurrentgemma-2b",
    "whisper-large-v3",
]


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return import_module(f"repro.configs.{mod}")


def get_config(arch_id: str):
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _module(arch_id).CONFIG


def get_reduced(arch_id: str):
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _module(arch_id).reduced()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
