"""whisper-large-v3 [audio] — enc-dec, 32L decoder d1280 20H (kv=20)
d_ff=5120 V=51866, 32L encoder over 1500 audio frames.
[arXiv:2212.04356; unverified]

The conv audio frontend is a STUB per the assignment: ``input_specs``
feeds precomputed frame embeddings (B, 1500, d_model) into the
transformer encoder; every decoder block cross-attends to its output.
Decoder uses learned absolute positions (no RoPE) and QKV biases.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    qkv_bias=True,
    use_rope=False,
    learned_pos_embed=4096,
    encoder_layers=32,
    encoder_seq=1500,
    tie_embeddings=True,
    loss_chunk=65_536,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, learned_pos_embed=64, encoder_layers=2,
        encoder_seq=24, dtype="float32", loss_chunk=0)
