"""xlstm-350m [ssm] — 24L d1024 4H (kv=4) d_ff=0 V=50304,
alternating mLSTM / sLSTM blocks.  [arXiv:2405.04517; unverified]

Sub-quadratic: constant-size recurrent state -> runs long_500k.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,  # xLSTM blocks carry their own projections; no separate MLP
    vocab=50304,
    block_pattern=("mlstm", "slstm"),
    lru_heads=4,
    tie_embeddings=True,
    loss_chunk=65_536,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        lru_heads=4, vocab=256, dtype="float32", loss_chunk=0)
