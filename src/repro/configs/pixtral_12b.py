"""pixtral-12b [vlm] — 40L d5120 32H (GQA kv=8) d_ff=14336 V=131072,
pixtral-ViT frontend + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]

The vision frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed patch embeddings (B, vision_tokens, d_model) which fill the
first ``vision_tokens`` sequence positions through ``vision_proj``.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    vision_tokens=256,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    loss_chunk=32_768,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, vision_tokens=8, dtype="float32",
        loss_chunk=0)
