"""recurrentgemma-2b [hybrid] — 26L d2560 10H (MQA kv=1) d_ff=7680
V=256000, RG-LRU + local attention at 1:2 (period: rglru, rglru, local).
[arXiv:2402.19427; hf]

Sub-quadratic: RG-LRU state + windowed attention -> runs long_500k.
26 layers = 8 full periods + 2 remainder (rglru, rglru).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    block_pattern=("rglru", "rglru", "attn_local"),
    window_size=2048,
    rglru_dim=2560,
    conv_width=4,
    scale_embed=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    loss_chunk=32_768,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=5,  # 1 full period + 2 remainder
        d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, window_size=16, rglru_dim=64,
        dtype="float32", loss_chunk=0)
