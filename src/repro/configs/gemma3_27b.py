"""gemma3-27b [dense] — 62L d5376 32H (GQA kv=16) d_ff=21504 V=262144,
5:1 local:global attention, 128k context, QK-norm (no softcaps).
[hf:google/gemma-3-1b-pt; unverified]

62 layers = 10 full (local*5, global) periods + 2 remainder layers.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    window_pattern=("local",) * 5 + ("global",),
    window_size=1024,
    qk_norm=True,
    sandwich_norm=True,
    scale_embed=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    loss_chunk=32_768,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=8,  # 1 full period + 2 remainder, keeps the rem path hot
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window_size=16, dtype="float32",
        loss_chunk=0)
