"""granite-8b [dense] — 36L d4096 32H (GQA kv=8) d_ff=14336 V=49152,
llama-arch code model.  [arXiv:2405.04324; hf]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=49152,
    tie_embeddings=True,
    rope_theta=10_000_000.0,
    loss_chunk=65_536,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, dtype="float32", loss_chunk=0)
