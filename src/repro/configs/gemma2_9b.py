"""gemma2-9b [dense] — 42L d3584 16H (GQA kv=8) d_ff=14336 V=256000,
local/global alternating attention, logit softcaps, sandwich norms.
[arXiv:2408.00118; hf]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    window_pattern=("local", "global"),
    window_size=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    sandwich_norm=True,
    scale_embed=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    loss_chunk=32_768,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window_size=16, dtype="float32",
        loss_chunk=0)
