"""qwen2.5-32b [dense] — 64L d5120 40H (GQA kv=8) d_ff=27648 V=152064,
QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab=152064,
    qkv_bias=True,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    loss_chunk=32_768,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, dtype="float32", loss_chunk=0)
