"""olmoe-1b-7b [moe] — 16L d2048 16H (kv=16) d_ff=1024 V=50304,
MoE 64 experts top-8.  [arXiv:2409.02060; hf]"""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert_ff=1024),
    qk_norm=True,  # OLMoE uses QK-norm
    tie_embeddings=False,
    rope_theta=10_000.0,
    loss_chunk=65_536,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=32,
                      capacity_factor=8.0),  # dropless (see granite_moe)
        dtype="float32", loss_chunk=0)
