"""granite-moe-1b-a400m [moe] — 24L d1024 16H (kv=8) d_ff=512 V=49155,
MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(n_experts=32, top_k=8, d_expert_ff=512),
    tie_embeddings=True,
    rope_theta=10_000.0,
    loss_chunk=65_536,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab=256,
        # capacity 8.0: dropless in smoke tests so batched prefill and
        # per-token decode dispatch identically (capacity ordering is the
        # only nondeterminism between the two paths)
        moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=32,
                      capacity_factor=8.0),
        dtype="float32", loss_chunk=0)
