"""Serving driver: SLIMSTART-instrumented serverless model server.

Simulates the paper's full CI/CD loop on a real (reduced) model:
  1. cold start under a policy (eager | lazy | slimstart),
  2. serve a skewed multi-entry workload (the paper's Fig. 3 shape),
  3. emit the SLIMSTART report; --optimize re-derives the policy from
     the profile and re-measures the cold start (the Level-B analogue of
     the AST deferred-import rewrite).

    PYTHONPATH=src python -m repro.launch.serve --arch whisper-large-v3 \
        --requests 20 --policy slimstart
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_reduced
from repro.serving import LoadPolicy, ServingEngine


def skewed_workload(entries, n, seed=0, alpha=0.85):
    """Zipf-skewed entry mix: the top handler dominates (Obs. 3)."""
    rng = np.random.default_rng(seed)
    p = np.array([alpha ** i for i in range(len(entries))], np.float64)
    p /= p.sum()
    # make the skew strong: square and renormalize
    p = p ** 3
    p /= p.sum()
    return [entries[i] for i in rng.choice(len(entries), size=n, p=p)]


def run_service(cfg, policy, requests, *, seed=0, max_new=4):
    eng = ServingEngine(cfg, policy=policy, batch_size=1, prefill_len=8,
                        max_len=32)
    cold = eng.cold_start()
    rng = np.random.default_rng(seed)
    lat = {}
    for entry in requests:
        toks = rng.integers(0, cfg.vocab, (1, 8))
        _, dt = eng.serve(entry, toks, max_new_tokens=max_new)
        lat.setdefault(entry, []).append(dt)
    return eng, cold, lat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--policy", default="slimstart",
                    choices=["eager", "lazy", "slimstart"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    probe = ServingEngine(cfg, batch_size=1)
    entries = probe.entries()
    workload = skewed_workload(entries, args.requests, seed=args.seed)

    if args.policy == "eager":
        policy = LoadPolicy.eager_all()
    elif args.policy == "lazy":
        policy = LoadPolicy(lazy_groups=frozenset(
            {"compile", "frontend", "experts"}))
    else:
        # profile-guided: run an eager profiling pass first, then build
        # the policy from the report (the paper's CI/CD loop)
        prof_eng, _, _ = run_service(cfg, LoadPolicy.eager_all(),
                                     workload, seed=args.seed)
        policy = LoadPolicy.from_report(prof_eng.report())

    eng, cold, lat = run_service(cfg, policy, workload, seed=args.seed)
    rep = eng.report()
    out = {
        "arch": cfg.name,
        "policy": args.policy,
        "cold_start_s": round(cold, 4),
        "entry_latency_mean_s": {
            k: round(float(np.mean(v)), 4) for k, v in lat.items()},
        "total_init_s": rep["total_init_s"],
        "by_group": rep["by_group"],
        "entry_counts": rep["entry_counts"],
    }
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
