"""Training driver: data pipeline -> fused train step -> checkpoints.

Runs for real on CPU with reduced configs (examples/train_100m.py) and
lowers unchanged on the production meshes (launch/dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models.model import init_params
from repro.training.adamw import adamw_init
from repro.training.checkpoint import (
    latest_step, restore_checkpoint, save_checkpoint,
)
from repro.training.data import make_pipeline
from repro.training.fault import StepWatchdog
from repro.training.step import make_train_step


def train(cfg, *, steps: int, batch: int, seq: int, lr: float = 3e-4,
          accum: int = 1, ckpt_dir=None, ckpt_every: int = 50,
          seed: int = 0, log_every: int = 10, compress_fn=None,
          soft_deadline_s: float = 300.0):
    params = jax.jit(lambda: init_params(cfg, jax.random.PRNGKey(seed)))()
    opt = adamw_init(params)
    step0 = 0
    if ckpt_dir:
        last = latest_step(ckpt_dir)
        if last is not None:
            (params, opt), meta = restore_checkpoint(
                ckpt_dir, last, (params, opt))
            step0 = meta["step"]
            print(f"[train] resumed from step {step0}")

    step_fn = jax.jit(make_train_step(cfg, lr=lr, accum_steps=accum,
                                      compress_fn=compress_fn),
                      donate_argnums=(0, 1))
    data = make_pipeline(cfg.vocab, batch, seq, seed=seed)
    watchdog = StepWatchdog(soft_deadline_s=soft_deadline_s)
    losses = []
    t_start = time.time()
    for step in range(step0, steps):
        batch_np = next(data)
        params, opt, metrics = watchdog.run(
            step_fn, params, opt,
            {k: jax.numpy.asarray(v) for k, v in batch_np.items()})
        loss = float(metrics["loss"])
        losses.append(loss)
        if (step + 1) % log_every == 0:
            dt = time.time() - t_start
            tps = (step + 1 - step0) * batch * seq / max(dt, 1e-9)
            print(f"[train] step {step+1}/{steps} loss={loss:.4f} "
                  f"tok/s={tps:,.0f}")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, (params, opt))
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, (params, opt))
    return params, opt, {"losses": losses,
                         "straggler": watchdog.stats.as_dict()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    _, _, summary = train(cfg, steps=args.steps, batch=args.batch,
                          seq=args.seq, lr=args.lr, accum=args.accum,
                          ckpt_dir=args.ckpt_dir, seed=args.seed)
    first = np.mean(summary["losses"][:10])
    lastl = np.mean(summary["losses"][-10:])
    print(json.dumps({"first10_loss": round(float(first), 4),
                      "last10_loss": round(float(lastl), 4),
                      "straggler": summary["straggler"]}))


if __name__ == "__main__":
    main()
