import os
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline terms.

For each cell this builds ShapeDtypeStruct inputs (``input_specs`` — no
allocation), resolves in/out shardings from the logical rules, then::

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...,
                           donate_argnums=...).lower(*specs)
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves it fits per-device
        compiled.cost_analysis()     # FLOPs / bytes for the roofline

and parses the post-SPMD HLO for collective operand bytes.  Results are
written incrementally to benchmarks/results/dryrun/<cell>.json so the
sweep is resumable.  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
        --shape train_4k [--multi-pod] [--all] [--sp|--dp] [--accum N]
"""

import argparse
import json
import re
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed import (
    DEFAULT_RULES, batch_pspec, cache_pspecs, opt_pspecs, param_pspecs,
)
from repro.launch.mesh import HW, make_production_mesh
from repro.models import SHAPES, applicable_shapes, input_specs
from repro.models.config import ArchConfig, ShapeSpec
from repro.models.model import init_params, param_count
from repro.models.partition import use_act_mode
from repro.training.adamw import adamw_init
from repro.training.step import make_serve_steps, make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo_text: str):
    """[(name, start, end)] spans of computation bodies in the text."""
    headers = [(m.start(), m.group(1)) for m in re.finditer(
        r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*?\)\s*->\s*[^{]+\{",
        hlo_text, re.M)]
    spans = []
    for i, (pos, name) in enumerate(headers):
        end = headers[i + 1][0] if i + 1 < len(headers) else len(hlo_text)
        spans.append((name, pos, end))
    return spans


def _line_collective(line: str):
    """(op, result_bytes) if this instruction is a collective.

    Result-shape bytes are the per-device traffic proxy: a ring
    all-gather delivers ~result bytes to each device; an all-reduce
    moves ~2x its (equal-shaped) operand.  Async -start/-done pairs are
    counted at -start only.
    """
    m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", line)
    if not m:
        return None
    rest = m.group(1)
    for c in _COLLECTIVES:
        if re.search(rf"[\]\}}]\s{c}-done\(", rest):
            return c, 0
        if re.search(rf"[\]\}}]\s{c}(-start)?\(", rest):
            res = _SHAPE_RE.findall(rest)[:1]
            return c, _shape_bytes(*res[0]) if res else 0
    return None


def _trip_count(cond_text: str) -> int:
    """Loop bound from the condition: the constant in its compare
    (double-buffered 'wide' loops carry a halved bound against a doubled
    body, so bound x body stays consistent)."""
    best = 1
    for line in cond_text.splitlines():
        if "compare" in line:
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
    if best == 1:
        for m in re.finditer(r"constant\((\d+)\)", cond_text):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes(hlo_text: str) -> dict:
    """Loop-aware per-device collective traffic for one step.

    The compiled module is the per-partition program; collectives inside
    while bodies are multiplied by the loop trip count (parsed from the
    loop condition), recursively for nested loops — XLA's cost analysis
    counts loop bodies once, which would undercount e.g. a 21-period
    layer scan under 4-way grad accumulation by ~84x.
    """
    spans = _split_computations(hlo_text)
    span_of = {name: (s, e) for name, s, e in spans}

    whiles = []  # (parent, cond, body)
    for m in re.finditer(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)",
                         hlo_text):
        parent = None
        for name, s, e in spans:
            if s <= m.start() < e:
                parent = name
                break
        if parent is not None:
            whiles.append((parent, m.group(1), m.group(2)))

    def direct(name):
        out = {k: 0 for k in _COLLECTIVES}
        counts = {k: 0 for k in _COLLECTIVES}
        s, e = span_of.get(name, (0, 0))
        for line in hlo_text[s:e].splitlines():
            r = _line_collective(line)
            if r and r[1]:
                out[r[0]] += r[1]
                counts[r[0]] += 1
        return out, counts

    def total(name, depth=0):
        if depth > 10:
            return {k: 0 for k in _COLLECTIVES}
        out, _ = direct(name)
        for parent, cond, body in whiles:
            if parent == name:
                s, e = span_of.get(cond, (0, 0))
                trips = _trip_count(hlo_text[s:e])
                sub = total(body, depth + 1)
                for k in _COLLECTIVES:
                    out[k] += trips * sub[k]
        return out

    entry = next((n for n, _, _ in spans if n.startswith("main")), None)
    if entry is None and spans:
        bodies = {b for _, _, b in whiles} | {c for _, c, _ in whiles}
        entry = next((n for n, _, _ in spans if n not in bodies), None)

    out = total(entry) if entry else {k: 0 for k in _COLLECTIVES}
    _, entry_counts = direct(entry) if entry else ({}, {})
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts_entry"] = entry_counts
    out["n_while_loops"] = len(whiles)
    return out


def _sharding_tree(mesh, pspec_tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
               accum_steps: int = 1):
    """Returns (fn, arg_structs, in_shardings, out_shardings, donate)."""
    specs = input_specs(cfg, shape)
    dp = batch_pspec(mesh, batch_size=shape.global_batch, extra_dims=0)
    dp_axes = dp[0] if len(dp) else None

    if shape.kind == "train":
        params_s = jax.eval_shape(
            lambda: init_params(cfg, jax.random.PRNGKey(0)))
        opt_s = jax.eval_shape(adamw_init, params_s)
        p_sh = _sharding_tree(mesh, param_pspecs(cfg, mesh))
        o_pspecs = opt_pspecs(cfg, mesh)
        o_sh = _sharding_tree(mesh, o_pspecs)
        batch_sh = jax.tree.map(
            lambda s: NamedSharding(
                mesh, P(*([dp_axes] + [None] * (len(s.shape) - 1)))),
            specs)
        fn = make_train_step(cfg, accum_steps=accum_steps)
        return (fn, (params_s, opt_s, specs),
                (p_sh, o_sh, batch_sh),
                (p_sh, o_sh, NamedSharding(mesh, P())),
                (0, 1))

    params_s = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    p_sh = _sharding_tree(mesh, param_pspecs(cfg, mesh))
    prefill_fn, decode_fn = make_serve_steps(cfg)

    if shape.kind == "prefill":
        batch_sh = jax.tree.map(
            lambda s: NamedSharding(
                mesh, P(*([dp_axes] + [None] * (len(s.shape) - 1)))),
            specs)
        cache_sh = _sharding_tree(
            mesh, cache_pspecs(cfg, mesh, shape.global_batch,
                               shape.seq_len))
        return (prefill_fn, (params_s, specs), (p_sh, batch_sh),
                (NamedSharding(mesh, P(dp_axes)), cache_sh), ())

    # decode: one token against a seq_len cache
    cache_sh = _sharding_tree(
        mesh, cache_pspecs(cfg, mesh, shape.global_batch, shape.seq_len))
    tok_sh = NamedSharding(mesh, P(dp_axes, None))
    pos_sh = NamedSharding(mesh, P(dp_axes))
    logit_sh = NamedSharding(mesh, P(dp_axes))
    return (decode_fn,
            (params_s, specs["token"], specs["pos"], specs["caches"]),
            (p_sh, tok_sh, pos_sh, cache_sh),
            (tok_sh, logit_sh, cache_sh), (3,))


def run_cell(arch: str, shape_name: str, *, multi_pod=False,
             act_mode="dp", accum_steps=1, overrides=None,
             tag="baseline", save=True) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    t0 = time.time()
    with mesh, use_act_mode(act_mode):
        fn, args, in_sh, out_sh, donate = build_cell(
            cfg, shape, mesh, accum_steps=accum_steps)
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        cost_info = {"flops": cost.get("flops"),
                     "bytes_accessed": cost.get("bytes accessed")}
    except Exception as e:  # pragma: no cover
        cost_info = {"error": str(e)}

    coll = collective_bytes(compiled.as_text())

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.shape.values()),
        "mesh_axes": list(mesh.shape.keys()),
        "n_devices": int(n_dev),
        "multi_pod": multi_pod,
        "act_mode": act_mode,
        "accum_steps": accum_steps,
        "overrides": overrides or {},
        "tag": tag,
        "kind": shape.kind,
        "param_count": param_count(cfg),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_info,
        "cost": cost_info,
        "collectives": coll,
    }
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        pod = "mp" if multi_pod else "sp1"
        out = RESULTS_DIR / f"{arch}__{shape_name}__{pod}__{tag}.json"
        out.write_text(json.dumps(result, indent=2))
    return result


def cells(archs=None):
    for arch in archs or ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in applicable_shapes(cfg):
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every applicable (arch, shape) cell")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--act-mode", default=None, choices=["dp", "sp"])
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    todo = []
    if args.all:
        todo = list(cells([args.arch] if args.arch else None))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for arch, shape_name in todo:
        for mp in meshes:
            # sequence-parallel activations for the long train/prefill
            # cells by default; decode stays DP (one-token steps)
            mode = args.act_mode or (
                "sp" if SHAPES[shape_name].kind in ("train", "prefill")
                else "dp")
            # grad accumulation default: microbatch the big train cells
            # (every production framework's memory lever of first resort);
            # recurrent (ssm) archs also accumulate — their time-scan
            # backward stores per-step residuals proportional to batch
            accum = args.accum
            if accum == 1 and SHAPES[shape_name].kind == "train":
                cfg_ = get_config(arch)
                pc = param_count(cfg_)
                if pc > 2e10:
                    # giant-vocab 27B+ (gemma3) needs deeper microbatching
                    accum = 16 if cfg_.vocab > 200_000 else 8
                elif pc > 5e9 or cfg_.family == "ssm":
                    accum = 4
                else:
                    accum = 1
            pod = "mp" if mp else "sp1"
            out = RESULTS_DIR / f"{arch}__{shape_name}__{pod}__{args.tag}.json"
            if args.skip_done and out.exists():
                print(f"[skip] {arch} {shape_name} {pod}")
                continue
            try:
                r = run_cell(arch, shape_name, multi_pod=mp,
                             act_mode=mode, accum_steps=accum,
                             tag=args.tag)
                print(f"[ok] {arch} {shape_name} {pod} "
                      f"compile={r['compile_s']}s "
                      f"flops={r['cost'].get('flops')} "
                      f"coll={r['collectives']['total']}")
            except Exception as e:
                failures.append((arch, shape_name, mp, str(e)))
                print(f"[FAIL] {arch} {shape_name} {pod}: {e}")
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: "
                         + "; ".join(f"{a}/{s}" for a, s, *_ in failures))
    print("dry-run complete")


if __name__ == "__main__":
    main()
