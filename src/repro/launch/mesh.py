"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices.

Mesh layout (TPU v5e pods, 256 chips each):
  single pod:  (16, 16)      axes ("data", "model")
  two pods:    (2, 16, 16)   axes ("pod", "data", "model")
The "model" axis carries TP/EP/SP; "data" and "pod" carry DP (the
gradient all-reduce crosses the pod axis — the slow inter-pod links —
which is what the int8 gradient-compression path targets).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many (possibly fake) devices exist."""
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link (~per-axis budget)
    "hbm_bytes": 16 * 1024**3,   # 16 GiB per chip
}
