"""Seeded fault injection for the zygote serving path (chaos tier).

The warm-pool stack — :class:`~repro.pool.forkserver.ForkServer`,
:class:`~repro.pool.forkserver.BaseZygote`,
:class:`~repro.pool.fleet.ZygoteFleet`,
:class:`~repro.serving.engine.EnginePool` and the
:class:`~repro.pool.daemon.FleetDaemon` — each accept an optional
``fault_hook`` callable.  When unset (the default) the hook is a single
``is not None`` check and the serving path is unchanged.  When set, the
components call it at well-known **sites** with keyword context::

    fault_hook("protocol",   app=..., op=..., pid=..., server=...)
    fault_hook("spawn_app",  app=..., base=...)
    fault_hook("dispatch",   app=..., base=...)
    fault_hook("cold_start", app=...)
    fault_hook("rewarm",     app=...)
    fault_hook("route",      app=..., node=...)   # cluster router
    fault_hook("profiler",   app=...)             # adaptive re-optimize
    fault_hook("election",   router=..., epoch=...)  # HA leader path
    fault_hook("handoff",    app=..., node=..., target=...)  # warm handoff

:class:`FaultInjector` is the hook implementation this module ships: it
consumes a :class:`FaultPlan` — a deterministic, seed-generatable list
of :class:`FaultEvent` — and *applies* each event when its (site, app,
op) filter has matched ``at`` times:

==================  ==========  =========================================
kind                site        effect
==================  ==========  =========================================
kill_app_zygote     protocol    SIGKILL the app zygote before the write
kill_base_zygote    dispatch    SIGKILL the shared base zygote
wedge_handler       protocol    SIGSTOP the zygote: the reply never
                                arrives, the client times out after
                                ``timeout_s`` and kills it
fail_spawn          spawn_app   raise ForkServerError (boot failure)
fail_preload        protocol    raise ForkServerError on a preload
socket_eof          protocol    raise ForkServerError (injected EOF)
socket_oserror      protocol    raise ForkServerError from an OSError
delay_import        protocol    sleep ``delay_s`` before the command
fail_cold           cold_start  raise (fresh-process cold start fails)
fail_rewarm         rewarm      raise inside the daemon rewarm tick
node_loss           route       raise NodeLossFault: the cluster router
                                declares the routed node lost and
                                re-places its apps on survivors
profiler_stall      profiler    optional ``delay_s`` sleep, then raise
                                inside the adaptive re-optimization
                                step; the AdaptiveLoop must swallow the
                                error into its ring and keep serving
router_loss         election    raise RouterLossFault: the HA harness
                                halts the leader router abruptly; a
                                standby must win the lease election and
                                resume from its replicated ledger
handoff_stall       handoff     optional ``delay_s`` sleep, then raise
                                HandoffStallFault mid warm handoff; the
                                router falls back to cold re-place with
                                accounting intact
==================  ==========  =========================================

Everything is deterministic given the plan: matching is by per-event
occurrence counters, never wall-clock.  ``simulate=True`` swaps the
process signals for equivalent exceptions so pure in-process tests
(and the hypothesis conservation property) can run a plan without
booting zygotes.
"""

from __future__ import annotations

import json
import os
import random
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.pool.forkserver import ForkServerError, ForkServerTimeout

__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultInjector",
    "FaultPlan",
    "HandoffStallFault",
    "NodeLossFault",
    "RouterLossFault",
    "chaos_report_payload",
]

# kind -> (site, default op filter); op None matches any command
_KIND_SPEC: dict[str, tuple[str, Optional[str]]] = {
    "kill_app_zygote": ("protocol", "exec"),
    "kill_base_zygote": ("dispatch", None),
    "wedge_handler": ("protocol", "exec"),
    "fail_spawn": ("spawn_app", None),
    "fail_preload": ("protocol", "preload"),
    "socket_eof": ("protocol", "exec"),
    "socket_oserror": ("protocol", "exec"),
    "delay_import": ("protocol", "preload"),
    "fail_cold": ("cold_start", None),
    "fail_rewarm": ("rewarm", None),
    "node_loss": ("route", None),
    "profiler_stall": ("profiler", None),
    "router_loss": ("election", None),
    "handoff_stall": ("handoff", None),
}

FAULT_KINDS = tuple(sorted(_KIND_SPEC))

SITES = ("protocol", "spawn_app", "dispatch", "cold_start", "rewarm",
         "route", "profiler", "election", "handoff")


class NodeLossFault(RuntimeError):
    """Injected whole-node failure, raised at the cluster router's
    ``route`` site (:mod:`repro.cluster.router`).  The router reacts by
    declaring the routed node lost: its fleet is finalized (queued work
    flushed into its summary, preserving conservation) and its apps are
    re-placed onto the surviving nodes."""


class RouterLossFault(RuntimeError):
    """Injected *leader router* failure, raised at the HA coordinator's
    ``election`` site (:mod:`repro.cluster.ha`).  The coordinator halts
    the leader abruptly (sockets die, no drain, lease left to expire or
    be fenced) and promotes the standby, which must win a majority
    lease election and resume routing from its replicated ledger."""


class HandoffStallFault(RuntimeError):
    """Injected stall during a planned warm-state handoff, raised at
    the router's ``handoff`` site.  The router abandons the prewarm for
    that app and falls back to the unplanned cold re-place path —
    placement still flips and conservation must still hold."""


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    Fires on the ``at``-th call (0-based) of the event's site that
    matches the ``app``/``op`` filters, and keeps firing for ``count``
    consecutive matches (``count=-1``: every match from ``at`` on).
    ``app="*"`` matches any app; ``op=None`` takes the kind's default
    filter (see module table).
    """

    kind: str
    at: int = 0
    app: str = "*"
    op: Optional[str] = None
    delay_s: float = 0.0
    count: int = 1

    def __post_init__(self) -> None:
        if self.kind not in _KIND_SPEC:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {FAULT_KINDS})")
        if self.at < 0:
            raise ValueError(f"at must be >= 0, got {self.at}")
        if self.count == 0 or self.count < -1:
            raise ValueError(f"count must be positive or -1 (unlimited),"
                             f" got {self.count}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")

    @property
    def site(self) -> str:
        return _KIND_SPEC[self.kind][0]

    @property
    def op_filter(self) -> Optional[str]:
        return self.op if self.op is not None else _KIND_SPEC[self.kind][1]

    def to_dict(self) -> dict:
        out: dict = {"kind": self.kind, "at": self.at}
        if self.app != "*":
            out["app"] = self.app
        if self.op is not None:
            out["op"] = self.op
        if self.delay_s:
            out["delay_s"] = self.delay_s
        if self.count != 1:
            out["count"] = self.count
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        return cls(kind=d["kind"], at=int(d.get("at", 0)),
                   app=d.get("app", "*"), op=d.get("op"),
                   delay_s=float(d.get("delay_s", 0.0)),
                   count=int(d.get("count", 1)))


@dataclass
class FaultPlan:
    """An ordered set of :class:`FaultEvent` plus the seed that (may
    have) generated it.  JSON round-trips via ``save``/``load`` so
    plans are reviewable, diffable CI inputs."""

    events: list[FaultEvent] = field(default_factory=list)
    seed: int = 0
    name: str = "chaos"

    def to_payload(self) -> dict:
        return {"kind": "chaos_plan", "schema_version": 1,
                "name": self.name, "seed": self.seed,
                "events": [ev.to_dict() for ev in self.events]}

    @classmethod
    def from_payload(cls, payload: dict) -> "FaultPlan":
        k = payload.get("kind", "chaos_plan")
        if k != "chaos_plan":
            raise ValueError(f"not a chaos_plan payload (kind={k!r})")
        return cls(events=[FaultEvent.from_dict(d)
                           for d in payload.get("events", [])],
                   seed=int(payload.get("seed", 0)),
                   name=str(payload.get("name", "chaos")))

    def save(self, path: str) -> str:
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as fh:
            json.dump(self.to_payload(), fh, indent=2, sort_keys=True)
            fh.write("\n")
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as fh:
            payload = json.load(fh)
        if isinstance(payload, list):  # bare event list is accepted
            payload = {"kind": "chaos_plan", "events": payload}
        return cls.from_payload(payload)

    @classmethod
    def generate(cls, seed: int, apps: list[str],
                 n_events: int = 6) -> "FaultPlan":
        """Random-but-reproducible plan: same seed + apps, same plan.

        Leans toward recoverable faults (kills, EOFs, delays) so a
        generated plan exercises recovery paths rather than just
        drowning every request; wedges are rare because each one costs
        ``timeout_s`` wall-clock."""
        rng = random.Random(seed)
        weighted = (["kill_app_zygote"] * 4 + ["socket_eof"] * 3
                    + ["socket_oserror"] * 2 + ["delay_import"] * 2
                    + ["fail_spawn"] * 2 + ["fail_preload"]
                    + ["fail_cold"] + ["kill_base_zygote"]
                    + ["wedge_handler"])
        events = []
        for _ in range(n_events):
            kind = rng.choice(weighted)
            app = rng.choice(list(apps) + ["*"])
            ev = FaultEvent(
                kind=kind, at=rng.randint(0, 4), app=app,
                delay_s=(round(rng.uniform(0.01, 0.1), 3)
                         if kind == "delay_import" else 0.0))
            events.append(ev)
        return cls(events=events, seed=seed, name=f"generated-{seed}")

    @classmethod
    def storm(cls, apps: list[str], seed: int = 0) -> "FaultPlan":
        """The canonical crash storm (the acceptance scenario): kill
        the first app's zygote and make every respawn and cold start
        for it fail (driving the circuit breaker open and then
        ``crash_loop`` sheds), wedge one handler on the last app
        (a ``timeout`` shed), and kill the shared base mid-burst.
        ``seed`` shifts *when* the kills land, not what happens."""
        rng = random.Random(seed)
        victim, wedged = apps[0], apps[-1]
        return cls(events=[
            FaultEvent("kill_app_zygote", at=rng.randint(0, 1),
                       app=victim),
            FaultEvent("fail_spawn", at=0, app=victim, count=-1),
            FaultEvent("fail_cold", at=0, app=victim, count=-1),
            FaultEvent("wedge_handler", at=rng.randint(0, 1),
                       app=wedged),
            FaultEvent("kill_base_zygote", at=rng.randint(2, 4)),
        ], seed=seed, name=f"storm-{seed}")


class _EventState:
    __slots__ = ("event", "seen", "fired")

    def __init__(self, event: FaultEvent) -> None:
        self.event = event
        self.seen = 0      # filter matches observed
        self.fired = 0     # times applied


class FaultInjector:
    """The ``fault_hook`` callable: matches plan events against hook
    calls and applies them.  Thread-safe; every injection is recorded
    in ``injected`` (kind/site/app/op/sequence) for the
    ``chaos_report`` artifact.

    ``simulate=True`` replaces process signals with the exception the
    real fault would ultimately surface (kill -> ForkServerError,
    wedge -> ForkServerTimeout, base kill -> no-op) so in-process
    tests can run plans without zygotes.
    """

    def __init__(self, plan: FaultPlan, *,
                 simulate: bool = False) -> None:
        self.plan = plan
        self.simulate = simulate
        self._states = [_EventState(ev) for ev in plan.events]
        self._lock = threading.Lock()
        self.calls = 0
        self.injected: list[dict] = []

    # ------------------------------------------------------------ matching
    def __call__(self, site: str, **ctx) -> None:
        app = ctx.get("app", "*")
        op = ctx.get("op")
        due: list[FaultEvent] = []
        with self._lock:
            self.calls += 1
            for st in self._states:
                ev = st.event
                if ev.site != site:
                    continue
                if ev.app != "*" and ev.app != app:
                    continue
                if (site == "protocol" and ev.op_filter is not None
                        and ev.op_filter != op):
                    continue
                st.seen += 1
                n = st.seen - 1  # 0-based occurrence index
                if n < ev.at:
                    continue
                if ev.count != -1 and n >= ev.at + ev.count:
                    continue
                st.fired += 1
                due.append(ev)
                self.injected.append({
                    "seq": len(self.injected), "kind": ev.kind,
                    "site": site, "app": app, "op": op,
                    "occurrence": n,
                })
        # apply outside the lock: actions sleep, signal, raise
        raiser: Optional[FaultEvent] = None
        for ev in due:
            if ev.kind == "delay_import":
                time.sleep(ev.delay_s)
            elif ev.kind in ("kill_app_zygote", "wedge_handler"):
                if self.simulate:
                    raiser = raiser or ev
                else:
                    pid = ctx.get("pid")
                    if pid:
                        sig = (signal.SIGKILL
                               if ev.kind == "kill_app_zygote"
                               else signal.SIGSTOP)
                        try:
                            os.kill(pid, sig)
                        except ProcessLookupError:
                            pass
            elif ev.kind == "kill_base_zygote":
                base = ctx.get("base")
                if not self.simulate and base is not None \
                        and getattr(base, "pid", None):
                    try:
                        os.kill(base.pid, signal.SIGKILL)
                    except ProcessLookupError:
                        pass
            elif ev.kind in ("profiler_stall", "handoff_stall"):
                if ev.delay_s:
                    time.sleep(ev.delay_s)  # the "stall" half
                raiser = raiser or ev
            else:  # pure-exception kinds
                raiser = raiser or ev
        if raiser is not None:
            self._raise(raiser, app)

    @staticmethod
    def _raise(ev: FaultEvent, app: str) -> None:
        tag = f"chaos[{ev.kind}]"
        if ev.kind == "wedge_handler":
            # simulate-only: the real wedge surfaces as a client-side
            # read timeout, so mirror that exception type exactly
            raise ForkServerTimeout(
                f"{tag} injected handler wedge for {app!r}")
        if ev.kind == "socket_oserror":
            try:
                raise OSError(107, "injected: transport endpoint is "
                                   "not connected")
            except OSError as exc:
                raise ForkServerError(
                    f"{tag} injected OSError on protocol socket "
                    f"for {app!r}: {exc}") from exc
        if ev.kind == "fail_rewarm":
            raise RuntimeError(f"{tag} injected rewarm-tick failure "
                               f"for {app!r}")
        if ev.kind == "fail_cold":
            raise RuntimeError(f"{tag} injected cold-start failure "
                               f"for {app!r}")
        if ev.kind == "node_loss":
            raise NodeLossFault(f"{tag} injected node loss while "
                                f"routing {app!r}")
        if ev.kind == "router_loss":
            raise RouterLossFault(f"{tag} injected leader router loss")
        if ev.kind == "handoff_stall":
            raise HandoffStallFault(f"{tag} injected warm-handoff "
                                    f"stall for {app!r}")
        if ev.kind == "profiler_stall":
            raise RuntimeError(f"{tag} injected live-profiler stall "
                               f"for {app!r}")
        # socket_eof / fail_spawn / fail_preload / simulated kill
        raise ForkServerError(f"{tag} injected protocol failure "
                              f"for {app!r}")

    # ----------------------------------------------------------- reporting
    def counts(self) -> dict[str, int]:
        with self._lock:
            out: dict[str, int] = {}
            for rec in self.injected:
                out[rec["kind"]] = out.get(rec["kind"], 0) + 1
            return out

    def pending(self) -> list[dict]:
        """Events that never (fully) fired — a plan-vs-run mismatch
        worth surfacing in the report."""
        with self._lock:
            out = []
            for st in self._states:
                want = st.event.count
                if want == -1:
                    if st.fired == 0:
                        out.append(st.event.to_dict())
                elif st.fired < want:
                    out.append({**st.event.to_dict(),
                                "fired": st.fired})
            return out

    def report(self) -> dict:
        with self._lock:
            injected = [dict(r) for r in self.injected]
            calls = self.calls
        return {"plan": self.plan.to_payload(),
                "seed": self.plan.seed,
                "hook_calls": calls,
                "injected": injected,
                "injected_by_kind": self.counts(),
                "pending": self.pending()}


def chaos_report_payload(injector: FaultInjector,
                         summary: Optional[dict] = None,
                         recoveries: Optional[dict] = None) -> dict:
    """Payload for the versioned ``chaos_report`` artifact: what was
    injected, what recovered, and whether the conservation invariant
    (``requests == served + sheds + flushed + errors + abandoned``)
    survived the run."""
    rep = injector.report()
    invariant: dict = {"checked": summary is not None, "holds": None}
    if summary is not None:
        lhs = summary.get("requests", 0)
        rhs = (summary.get("served", 0) + summary.get("sheds", 0)
               + summary.get("flushed", 0) + summary.get("errors", 0)
               + summary.get("abandoned", 0))
        invariant = {
            "checked": True, "holds": lhs == rhs,
            "requests": lhs, "accounted": rhs,
            "expression": "requests == served + sheds + flushed "
                          "+ errors + abandoned",
        }
    return {
        "seed": rep["seed"],
        "plan": rep["plan"],
        "injected": rep["injected"],
        "injected_by_kind": rep["injected_by_kind"],
        "pending": rep["pending"],
        "hook_calls": rep["hook_calls"],
        "recoveries": dict(recoveries or {}),
        "invariant": invariant,
        "summary": summary,
    }
