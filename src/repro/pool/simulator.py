"""Trace-driven fleet simulator for warm-pool policies.

Replays a :class:`~repro.pool.trace.Trace` against a
:class:`~repro.pool.policies.KeepAlivePolicy` using *measured* per-app
latency/memory profiles (from the benchsuite harness or the fork
server), and reports the fleet-level numbers a keep-alive paper cares
about: cold-start ratio, p50/p99 end-to-end latency, and memory-seconds.

Semantics follow FaaS platforms (one request per instance at a time):

* a request is served by an idle warm instance if one exists — latency
  is ``warm_init_ms + invoke_ms`` (fork-pool forks still pay a small
  per-fork init; fresh-process pools pay ~0 warm init);
* otherwise a new instance cold-starts — ``cold_init_ms + invoke_ms`` —
  and joins the pool; there is no request queueing: concurrency spawns
  instances, exactly like Lambda;
* an instance idle longer than ``policy.keep_alive_s(app)`` is
  reclaimed at ``idle_since + keep_alive`` (that moment, not the next
  arrival, bounds its memory-seconds);
* ``policy.prewarm(app)`` instances are provisioned at t=0 and never
  reclaimed below the floor — they pay memory for the whole trace.

Memory accounting integrates ``rss_mb`` over each instance's lifetime
(birth to reclaim, or to trace end), i.e. MB-seconds, reported as
GB-seconds — the unit serverless providers bill.
"""

from __future__ import annotations

import math
import statistics
from dataclasses import dataclass, field
from typing import Optional

from repro.pool.policies import KeepAlivePolicy
from repro.pool.trace import Trace


def percentile_ms(latencies_ms: list[float], q: float) -> float:
    """Nearest-rank percentile shared by per-app and fleet-level
    reports (keeping the two from silently diverging)."""
    if not latencies_ms:
        return math.nan
    ys = sorted(latencies_ms)
    return ys[min(len(ys) - 1, max(0, round(q * (len(ys) - 1))))]


class PercentilePool:
    """A latency pool that sorts once and answers many quantiles.

    :class:`~repro.pool.fleet.FleetSummary` merges every app's latency
    list on *each* percentile-property access; on a large replay that
    re-builds and re-sorts a 100k-element list four times per
    ``summary()`` call.  This caches the merged sorted pool (invalidated
    when the source lists grow) and serves percentiles from
    :func:`statistics.quantiles` over it, so repeated
    ``summary()``/``app_rows()`` calls are O(1) after the first sort.
    """

    def __init__(self, source) -> None:
        # source: zero-arg callable yielding the (mutable) lists to merge
        self._source = source
        self._token = None
        self._grid: list[float] = []
        self._n = 0
        self._mean = math.nan

    def _refresh(self) -> None:
        lists = list(self._source())
        # invalidation token: total length plus each list's tail.  The
        # fleet's sources are append-only (tail changes on growth), and
        # the tail also catches a wholesale same-length replacement;
        # in-place mutation of interior elements is the one edit this
        # cannot see — don't do that to a pooled list
        token = (sum(len(xs) for xs in lists),
                 tuple(xs[-1] if xs else None for xs in lists))
        if token == self._token:
            return
        merged = sorted(x for xs in lists for x in xs)
        self._token = token
        self._n = len(merged)
        self._mean = statistics.fmean(merged) if merged else math.nan
        if len(merged) >= 2:
            # one 100-way cut answers every later percentile request
            self._grid = statistics.quantiles(merged, n=100,
                                              method="inclusive")
        else:
            self._grid = merged * 99  # 0 or 1 samples: flat grid

    def percentile(self, q: float) -> float:
        self._refresh()
        if not self._grid:
            return math.nan
        return self._grid[min(98, max(0, round(q * 100) - 1))]

    @classmethod
    def merge(cls, pools: "list[PercentilePool]") -> "PercentilePool":
        """A pool over the union of several pools' samples.

        Percentiles do not compose — averaging per-node p99s is wrong
        whenever the nodes' latency distributions differ (the usual
        case: each node hosts different apps).  The cluster router
        therefore merges the *pools* and reads true global quantiles
        from the combined sample set.  The merged pool chains the
        source callables rather than copying lists, so it sees later
        growth of any member and stays cache-invalidation-correct.
        """
        members = list(pools)

        def source():
            for pool in members:
                yield from pool._source()

        return cls(source)

    @classmethod
    def of_lists(cls, lists: "list[list[float]]") -> "PercentilePool":
        """A pool over fixed sample lists (e.g. latency samples shipped
        back over the wire by cluster node agents)."""
        held = list(lists)
        return cls(lambda: held)

    @property
    def mean(self) -> float:
        self._refresh()
        return self._mean

    def __len__(self) -> int:
        self._refresh()
        return self._n


@dataclass(frozen=True)
class AppProfile:
    """Measured single-instance numbers driving the simulation."""

    app: str
    cold_init_ms: float
    invoke_ms: float
    warm_init_ms: float = 0.0
    rss_mb: float = 128.0
    # resident cost of keeping a profile-guided zygote for this app (its
    # pre-imported hot set stays paged in); 0 = no zygote modeled
    zygote_rss_mb: float = 0.0
    # with a shared base zygote (two-tier fleet): the app zygote's
    # *private* pages above the base — its measured CoW delta.  0 =
    # unknown; the fleet then derives max(zygote_rss_mb -
    # shared_base_mb, 0)
    zygote_private_mb: float = 0.0

    @classmethod
    def from_stats(cls, cold_stats, pool_stats=None,
                   invoke_ms: Optional[float] = None) -> "AppProfile":
        """Build from harness :class:`ColdStartStats` (and optionally the
        fork-pool stats for the warm-path init)."""
        inv = invoke_ms if invoke_ms is not None else max(
            cold_stats.e2e_mean - cold_stats.init_mean, 0.0)
        return cls(
            app=cold_stats.app,
            cold_init_ms=cold_stats.init_mean,
            invoke_ms=inv,
            warm_init_ms=(pool_stats.init_mean if pool_stats is not None
                          else 0.0),
            rss_mb=cold_stats.rss_mean_mb,
            zygote_rss_mb=(pool_stats.rss_mean_mb
                           if pool_stats is not None else 0.0),
        )


@dataclass
class _Instance:
    born_t: float
    busy_until: float = 0.0
    idle_since: float = 0.0
    prewarmed: bool = False
    served: int = 0


@dataclass
class FleetReport:
    policy: str
    trace: str
    n_requests: int
    cold_starts: int
    latencies_ms: list[float] = field(default_factory=list, repr=False)
    memory_mb_s: float = 0.0
    max_instances: int = 0
    reclaims: int = 0
    # bounded-queue accounting (zero/empty when replay ran unbounded)
    sheds: int = 0
    flushed: int = 0
    queue_waits_ms: list[float] = field(default_factory=list, repr=False)
    # sheds broken out by cause ("queue-full" reject-new vs
    # "drop-oldest"); values sum to ``sheds``
    shed_reasons: dict = field(default_factory=dict, repr=False)

    def count_shed(self, reason: str) -> None:
        self.sheds += 1
        self.shed_reasons[reason] = self.shed_reasons.get(reason, 0) + 1

    @property
    def cold_start_ratio(self) -> float:
        return self.cold_starts / max(self.n_requests, 1)

    @property
    def served(self) -> int:
        """Requests that actually ran (arrivals minus shed/flushed)."""
        return self.n_requests - self.sheds - self.flushed

    @property
    def queue_wait_p50_ms(self) -> float:
        return percentile_ms(self.queue_waits_ms, 0.50)

    @property
    def queue_wait_p99_ms(self) -> float:
        return percentile_ms(self.queue_waits_ms, 0.99)

    @property
    def p50_ms(self) -> float:
        return self._pct(0.50)

    @property
    def p99_ms(self) -> float:
        return self._pct(0.99)

    @property
    def mean_ms(self) -> float:
        return (statistics.fmean(self.latencies_ms)
                if self.latencies_ms else math.nan)

    @property
    def memory_gb_s(self) -> float:
        return self.memory_mb_s / 1024.0

    def _pct(self, q: float) -> float:
        return percentile_ms(self.latencies_ms, q)

    def summary(self) -> dict:
        return {
            "policy": self.policy,
            "trace": self.trace,
            "requests": self.n_requests,
            "cold_starts": self.cold_starts,
            "cold_ratio": round(self.cold_start_ratio, 4),
            "p50_ms": round(self.p50_ms, 2),
            "p99_ms": round(self.p99_ms, 2),
            "mean_ms": round(self.mean_ms, 2),
            "memory_gb_s": round(self.memory_gb_s, 3),
            "max_instances": self.max_instances,
            "reclaims": self.reclaims,
            "sheds": self.sheds,
            "queue_wait_p99_ms": round(self.queue_wait_p99_ms, 2)
            if self.queue_waits_ms else 0.0,
        }


class FleetSimulator:
    """One app fleet under one policy.  ``run(trace)`` is pure: a fresh
    pool every call, so the same simulator sweeps many traces."""

    def __init__(self, profile: AppProfile, policy: KeepAlivePolicy) -> None:
        self.profile = profile
        self.policy = policy

    # ------------------------------------------------------------------ run
    def run(self, trace: Trace) -> FleetReport:
        prof, policy = self.profile, self.policy
        report = FleetReport(policy=policy.name, trace=trace.name,
                             n_requests=len(trace), cold_starts=0)
        pool: list[_Instance] = [
            _Instance(born_t=0.0, prewarmed=True)
            for _ in range(policy.prewarm(prof.app))
        ]
        report.max_instances = len(pool)

        def reclaim_idle(now: float) -> None:
            ka = policy.keep_alive_s(prof.app)
            survivors: list[_Instance] = []
            for inst in pool:
                idle_from = max(inst.busy_until, inst.idle_since)
                if (not inst.prewarmed and inst.busy_until <= now
                        and now - idle_from > ka):
                    died_at = idle_from + ka
                    report.memory_mb_s += prof.rss_mb * (died_at
                                                         - inst.born_t)
                    report.reclaims += 1
                else:
                    survivors.append(inst)
            pool[:] = survivors

        for req in trace:
            policy.observe_arrival(prof.app, req.t)
            reclaim_idle(req.t)
            warm = [i for i in pool if i.busy_until <= req.t]
            if warm:
                # prefer the most-recently-used instance (LIFO reuse
                # keeps the rest of the pool aging toward reclaim)
                inst = max(warm, key=lambda i: i.busy_until)
                latency_ms = prof.warm_init_ms + prof.invoke_ms
            else:
                inst = _Instance(born_t=req.t)
                pool.append(inst)
                report.cold_starts += 1
                latency_ms = prof.cold_init_ms + prof.invoke_ms
            inst.busy_until = req.t + latency_ms / 1e3
            inst.idle_since = inst.busy_until
            inst.served += 1
            report.latencies_ms.append(latency_ms)
            report.max_instances = max(report.max_instances, len(pool))

        # expire whatever the idle tail of the trace should have
        # reclaimed, then account memory for everything still alive
        end = trace.duration_s
        reclaim_idle(end)
        for inst in pool:
            report.memory_mb_s += prof.rss_mb * (max(end, inst.busy_until)
                                                 - inst.born_t)
        return report


def sweep(profile: AppProfile, policies: list[KeepAlivePolicy],
          traces: dict[str, Trace],
          policy_factory=None) -> list[FleetReport]:
    """Every policy x every trace.  Stateful policies (histogram) must
    not leak learned state across runs; pass ``policy_factory`` mapping
    a policy to a fresh clone, or rely on the default which re-uses the
    given instances (fine for stateless policies)."""
    out: list[FleetReport] = []
    for pol in policies:
        for trace in traces.values():
            p = policy_factory(pol) if policy_factory is not None else pol
            out.append(FleetSimulator(profile, p).run(trace))
    return out
