"""Profile-guided fork-server (zygote) for warm instance pools.

The paper removes library-loading cost from the cold path by *deferring*
imports; this module removes it by *amortizing* them: one long-lived
zygote process pre-imports the measured hot set (the packages an
:class:`~repro.core.profiler.report.OptimizationReport` shows are
actually exercised at runtime), then forks a fresh handler instance per
request.  Forked children share the preloaded libraries copy-on-write,
so their "cold" start only pays ``fork() + import handler`` — the
handler module itself plus whatever the hot set did not already load —
instead of the full library initialization.

Run as a module, this file *is* the zygote::

    python -m repro.pool.forkserver --app-dir .benchsuite/apps/graph_bfs \
        --preload fakelib_igraph

Protocol: newline-delimited JSON on stdin/stdout.  The zygote announces
``{"ok": true, "event": "ready", ...}`` once the preload set is
imported, then serves commands:

    {"cmd": "exec", "invocations": N, "handler": H, "seed": S,
     "preload": [...],   # optional batched preload: fast path
     "trace": {"trace_id": T, "parent_id": P}}  # optional span context
        -> {"ok": true, "metrics": {... runner-format metrics ...}}
           # with "trace": metrics carries a "spans" list (fork /
           # per-module import / invoke) measured on the shared
           # monotonic clock, and batched preloads add their own
           # preload:<mod> spans to the reply
    {"cmd": "preload", "modules": [...]}     # adaptive re-warm
        -> {"ok": true, "preloaded": [...], "errors": [...]}
    {"cmd": "ping"}      -> {"ok": true, "preloaded": [...]}
    {"cmd": "shutdown"}  -> {"ok": true}  (zygote exits)

Each ``exec`` forks; the child redirects its stdout to ``/dev/null`` (so
handler prints cannot corrupt the control channel), imports ``handler``,
runs the shared :func:`repro.benchsuite.runner.run_invocations` loop and
ships :func:`repro.benchsuite.runner.metrics_dict` JSON back over a
dedicated pipe.  Fork-to-ready time is measured against the zygote's
clock (``time.perf_counter`` is CLOCK_MONOTONIC — system-wide, valid
across ``fork``), so reported ``init_ms`` includes the fork itself.
The optional ``preload`` list on ``exec`` is the **protocol fast
path**: a rewarm's new modules and the fork+exec land in one
roundtrip instead of two.

Two-tier mode (``--base``, PR 5): a single **base zygote** pre-imports
the fleet's cross-app *shared* hot set
(:mod:`repro.pool.sharing`) and serves one extra command::

    {"cmd": "spawn_app", "app_dir": D, "preload": [delta...],
     "socket": S, "accept_timeout_s": T}
        -> {"ok": true, "pid": P}

``spawn_app`` forks a **per-app zygote from the base** — the shared
hot set's pages are inherited copy-on-write fleet-wide — which layers
only its app-specific delta on top, then serves the classic zygote
protocol over the unix socket ``S`` (the client connects directly, so
per-request dispatch stays a single roundtrip that never routes
through the base).  The batched delta in ``spawn_app`` makes app-zygote
boot itself one roundtrip: no boot-then-N-preloads chatter.  App
zygotes that crash are respawned from the still-warm base
(:meth:`ForkServer.restart`) instead of paying a full interpreter +
shared-set boot.

The in-process :class:`ForkServer` wraps either kind of zygote for the
harness: ``start() -> exec()* -> stop()``, plus ``rewarm(report)``
which the adaptive
:class:`~repro.core.adaptive.controller.SlimStartController` calls
after a re-profile to preload the *new* workload's hot set.
:class:`BaseZygote` manages the shared parent and hands
:class:`ForkServer` instances their spawn channel.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import select
import signal
import socket
import subprocess
import sys
import tempfile
import threading
import time
import traceback
from typing import Optional, Sequence

from repro.benchsuite import runner as _runner

_REPRO_SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


# ---------------------------------------------------------------------------
# Zygote side
# ---------------------------------------------------------------------------

def _import_modules(modules: Sequence[str]
                    ) -> tuple[list[str], list[str], dict]:
    done: list[str] = []
    errors: list[str] = []
    timings: dict[str, float] = {}  # module -> wall ms (import order)
    for mod in modules:
        mod = mod.strip()
        if not mod:
            continue
        t0 = time.perf_counter()
        try:
            importlib.import_module(mod)
            done.append(mod)
            timings[mod] = round((time.perf_counter() - t0) * 1e3, 3)
        except Exception as exc:  # zygote must survive bad preloads
            errors.append(f"{mod}: {exc!r}")
    return done, errors, timings


def _preload_span_dicts(trace: dict, t_start_s: float,
                        timings: dict) -> list[dict]:
    """Span dicts for a batched preload: one ``preload`` wrapper with a
    ``preload:<mod>`` child per module, laid out sequentially from the
    measured per-module wall times (imports run in order)."""
    from repro.obs.tracing import new_id, span_dict

    wrapper_id = new_id()
    t_ms = t_start_s * 1e3
    out = [span_dict("preload", trace_id=trace["trace_id"],
                     parent_id=trace.get("parent_id"), span_id=wrapper_id,
                     t_start_ms=t_ms,
                     duration_ms=sum(timings.values()),
                     modules=len(timings))]
    for mod, ms in timings.items():
        out.append(span_dict(f"preload:{mod}",
                             trace_id=trace["trace_id"],
                             parent_id=wrapper_id, t_start_ms=t_ms,
                             duration_ms=ms, module=mod))
        t_ms += ms
    return out


def _fork_exec(cmd: dict) -> dict:
    """Fork one instance; relay its metrics.  Runs inside the zygote.

    When the command carries a ``trace`` context
    (``{"trace_id", "parent_id"}``), the child also records
    ``fork`` / ``import`` (with per-module ``import:<mod>`` children
    via :class:`~repro.core.profiler.import_timer.ImportTimer`) /
    ``invoke`` spans against the system-wide monotonic clock and ships
    them back inside ``metrics["spans"]`` — the parent's tracer merges
    them under its own ``dispatch`` span.  Without a trace context the
    fork path is byte-for-byte the untraced one.

    When the command carries a ``live_profile`` config (the adaptive
    loop's sampled in-production profiling,
    :class:`repro.core.adaptive.LiveProfiler`), the child additionally
    times its imports (restricted to ``only_under`` roots — preloaded
    hot-set modules are already in ``sys.modules`` pre-fork, so what
    shows up here is exactly the defer-set misses and new modules) and
    runs a :class:`~repro.core.profiler.sampler.CallPathSampler` around
    the invocations, shipping a profile-shard-shaped payload back as
    ``metrics["live_profile"]`` with its own measured ``overhead_s``.
    With neither ``trace`` nor ``live_profile`` the fork path is
    byte-for-byte unchanged.
    """
    r, w = os.pipe()
    t0 = time.perf_counter()
    pid = os.fork()
    if pid == 0:  # ---------------------------------------------- child
        code = 1
        try:
            os.close(r)
            devnull = os.open(os.devnull, os.O_WRONLY)
            os.dup2(devnull, 1)
            rss_sampler = _runner.PeakRssSampler().start()
            trace = cmd.get("trace") or None
            lp = cmd.get("live_profile") or None
            spans: list[dict] = []
            lp_overhead = 0.0
            if trace or lp:
                from repro.core.profiler.import_timer import ImportTimer
                # live profiling restricts timing to the app's vendored
                # libs (what the analyzer maps); tracing wants everything
                only_under = (tuple(lp.get("only_under") or ())
                              if lp and not trace else ())
                timer = ImportTimer(only_under=only_under)
                if trace:
                    from repro.obs.tracing import (
                        new_id,
                        span_dict,
                        spans_from_import_timer,
                    )
                    t_child = time.perf_counter()
                    spans.append(span_dict(
                        "fork", trace_id=trace["trace_id"],
                        parent_id=trace.get("parent_id"),
                        t_start_ms=t0 * 1e3,
                        duration_ms=(t_child - t0) * 1e3,
                        pid=os.getpid()))
                with timer:
                    handler_mod = importlib.import_module("handler")
                if trace:
                    t_imp = time.perf_counter()
                    import_id = new_id()
                    spans.append(span_dict(
                        "import", trace_id=trace["trace_id"],
                        parent_id=trace.get("parent_id"),
                        span_id=import_id,
                        t_start_ms=t_child * 1e3,
                        duration_ms=(t_imp - t_child) * 1e3,
                        module="handler"))
                    spans.extend(spans_from_import_timer(
                        timer.records, trace_id=trace["trace_id"],
                        parent_id=import_id, t_start_ms=t_child * 1e3))
            else:
                handler_mod = importlib.import_module("handler")
            init_s = time.perf_counter() - t0
            sampler = None
            if lp:
                t_lp = time.perf_counter()
                from repro.core.profiler.sampler import (
                    CallPathSampler,
                    SamplerConfig,
                )
                sampler = CallPathSampler(SamplerConfig(
                    interval_s=float(lp.get("interval_s", 0.010)),
                    timer=str(lp.get("timer", "prof")),
                    max_depth=int(lp.get("max_depth", 128))))
                sampler.start()
                lp_overhead += time.perf_counter() - t_lp
            t_inv = time.perf_counter()
            invocation_s, counts = _runner.run_invocations(
                handler_mod,
                invocations=int(cmd.get("invocations", 1)),
                handler=cmd.get("handler"),
                seed=int(cmd.get("seed", 0)))
            if trace:
                spans.append(span_dict(
                    "invoke", trace_id=trace["trace_id"],
                    parent_id=trace.get("parent_id"),
                    t_start_ms=t_inv * 1e3,
                    duration_ms=(time.perf_counter() - t_inv) * 1e3,
                    invocations=int(cmd.get("invocations", 1))))
            live = None
            if sampler is not None:
                t_lp = time.perf_counter()
                sampler.stop()
                n_signals = sampler.n_signals
                live = {
                    "init_s": init_s,
                    "e2e_cold_s": init_s + (invocation_s[0][1]
                                            if invocation_s else 0.0),
                    "init_records": timer.to_dict(),
                    "cct": sampler.build_cct().to_dict(),
                    "n_signals": n_signals,
                    "counts": counts,
                }
                lp_overhead += time.perf_counter() - t_lp
            peak_kb = max(_runner.instance_rss_kb(), rss_sampler.stop())
            metrics = _runner.metrics_dict(init_s, invocation_s, counts,
                                           peak_kb)
            if spans:
                metrics["spans"] = spans
            if live is not None:
                live["overhead_s"] = lp_overhead
                live["exec_s"] = time.perf_counter() - t0
                metrics["live_profile"] = live
            with os.fdopen(w, "w") as fh:
                fh.write(json.dumps(metrics))
            code = 0
        except BaseException:
            traceback.print_exc(file=sys.stderr)
        finally:
            os._exit(code)
    # -------------------------------------------------------------- zygote
    os.close(w)
    with os.fdopen(r) as fh:
        payload = fh.read()
    _, status = os.waitpid(pid, 0)
    if status != 0 or not payload:
        return {"ok": False,
                "error": f"forked instance pid={pid} wait-status={status}"}
    return {"ok": True, "pid": pid, "metrics": json.loads(payload)}


def _serve_commands(lines, reply, preloaded: list[str], *,
                    spawn_fn=None) -> None:
    """The zygote command loop, shared by the classic stdio zygote,
    the base zygote (which adds ``spawn_app`` via ``spawn_fn``) and
    app zygotes serving a unix socket.  Returns on EOF or shutdown."""
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            cmd = json.loads(line)
        except ValueError:
            reply({"ok": False, "error": "bad json"})
            continue
        op = cmd.get("cmd")
        if op == "exec" and spawn_fn is None:
            # fast path: an optional batched preload rides the same
            # roundtrip as the fork+exec (rewarm + dispatch in one)
            extra = {}
            if cmd.get("preload"):
                t0 = time.perf_counter()
                done, errs, timings = _import_modules(cmd["preload"])
                preloaded.extend(done)
                extra = {"preloaded": done, "preload_errors": errs}
                if cmd.get("trace") and timings:
                    extra["spans"] = _preload_span_dicts(
                        cmd["trace"], t0, timings)
            reply({**_fork_exec(cmd), **extra})
        elif op == "preload":
            done, errs, timings = _import_modules(cmd.get("modules", []))
            preloaded.extend(done)
            reply({"ok": not errs, "preloaded": done, "errors": errs,
                   "preload_ms": timings})
        elif op == "spawn_app" and spawn_fn is not None:
            reply(spawn_fn(cmd))
        elif op == "ping":
            reply({"ok": True, "preloaded": list(preloaded)})
        elif op == "shutdown":
            reply({"ok": True})
            return
        else:
            reply({"ok": False, "error": f"unknown cmd {op!r}"})


def _app_zygote_child(cmd: dict, preloaded: Sequence[str]) -> None:
    """Runs in the child the base forked: become a per-app zygote.

    The shared hot set is already in ``sys.modules`` (inherited CoW
    from the base); layer the app's delta on top, then serve the
    classic zygote protocol over the spawn's unix socket.  Never
    returns — exits the process."""
    code = 1
    try:
        # SIGCHLD was set to a reaper in the base; _fork_exec must be
        # able to waitpid its own forks
        signal.signal(signal.SIGCHLD, signal.SIG_DFL)
        devnull = os.open(os.devnull, os.O_RDWR)
        os.dup2(devnull, 0)  # must not steal the base's stdin commands
        os.dup2(devnull, 1)  # must not corrupt the base's stdout channel
        _runner.setup_app_path(os.path.abspath(cmd["app_dir"]))
        done, errors, timings = _import_modules(cmd.get("preload") or [])
        preloaded = [*preloaded, *done]
        path = cmd["socket"]
        try:
            os.unlink(path)
        except OSError:
            pass
        srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        srv.bind(path)
        srv.listen(1)
        # bound + listening: tell the base it may ack the client
        os.write(int(cmd["_ack_fd"]), b"ok\n")
        os.close(int(cmd["_ack_fd"]))
        srv.settimeout(float(cmd.get("accept_timeout_s", 120.0)))
        conn, _ = srv.accept()
        srv.close()
        conn.settimeout(None)
        rfile = conn.makefile("r")
        wfile = conn.makefile("w")

        def reply(obj: dict) -> None:
            wfile.write(json.dumps(obj) + "\n")
            wfile.flush()

        reply({"ok": True, "event": "ready", "preloaded": list(preloaded),
               "errors": errors, "pid": os.getpid(), "from_base": True,
               "preload_ms": timings})
        _serve_commands(rfile, reply, list(preloaded))
        code = 0
    except BaseException:
        traceback.print_exc(file=sys.stderr)
    finally:
        os._exit(code)


def _make_spawn_fn(preloaded: list[str], children: set[int]):
    """``spawn_app`` handler for the base zygote's command loop."""

    def spawn(cmd: dict) -> dict:
        if not cmd.get("app_dir") or not cmd.get("socket"):
            return {"ok": False, "error": "spawn_app needs app_dir+socket"}
        r, w = os.pipe()
        pid = os.fork()
        if pid == 0:
            os.close(r)
            _app_zygote_child({**cmd, "_ack_fd": w}, preloaded)
        os.close(w)
        # wait for the child to be bound+listening (or dead): the
        # client connects the moment it sees this reply
        ack = b""
        try:
            ack = os.read(r, 16)
        finally:
            os.close(r)
        if not ack.startswith(b"ok"):
            return {"ok": False, "pid": pid,
                    "error": f"app zygote for {cmd['app_dir']} died "
                             f"before listening (delta import crash?)"}
        children.add(pid)
        return {"ok": True, "pid": pid, "socket": cmd["socket"]}

    return spawn


def zygote_main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app-dir", default=None)
    ap.add_argument("--preload", default="",
                    help="comma-separated modules imported at zygote boot")
    ap.add_argument("--base", action="store_true",
                    help="run as the shared base zygote: no app, serves "
                         "spawn_app forks of per-app zygotes")
    ap.add_argument("--path", action="append", default=[],
                    help="extra sys.path entry so the base can resolve "
                         "the shared hot set (repeatable)")
    args = ap.parse_args(argv)

    if not hasattr(os, "fork"):
        print(json.dumps({"ok": False, "error": "platform lacks fork()"}),
              flush=True)
        return 2
    if not args.base and not args.app_dir:
        print(json.dumps({"ok": False,
                          "error": "need --app-dir (or --base)"}),
              flush=True)
        return 2

    if args.app_dir:
        _runner.setup_app_path(os.path.abspath(args.app_dir))
    for p in reversed(args.path):
        sys.path.insert(0, os.path.abspath(p))
    preloaded, errors, preload_ms = _import_modules(args.preload.split(","))

    def reply(obj: dict) -> None:
        sys.stdout.write(json.dumps(obj) + "\n")
        sys.stdout.flush()

    spawn_fn = None
    children: set[int] = set()
    if args.base:
        # reap spawned app zygotes as they exit (their ForkServer
        # clients own their lifecycle; the base just must not leak
        # zombies)
        def _reap(*_sig) -> None:
            while True:
                try:
                    pid, _ = os.waitpid(-1, os.WNOHANG)
                except ChildProcessError:
                    return
                if pid == 0:
                    return
                children.discard(pid)

        signal.signal(signal.SIGCHLD, _reap)
        spawn_fn = _make_spawn_fn(preloaded, children)

    reply({"ok": True, "event": "ready", "preloaded": preloaded,
           "errors": errors, "pid": os.getpid(),
           "mode": "base" if args.base else "app",
           "preload_ms": preload_ms})
    _serve_commands(sys.stdin, reply, preloaded, spawn_fn=spawn_fn)
    for pid in list(children):  # base down: take the tier down with it
        try:
            os.kill(pid, signal.SIGTERM)
        except OSError:
            pass
    return 0


# ---------------------------------------------------------------------------
# Parent side
# ---------------------------------------------------------------------------

class ForkServerError(RuntimeError):
    pass


class ForkServerTimeout(ForkServerError):
    """The zygote did not reply within ``timeout_s`` (a wedged handler
    fork); the client killed it.  Distinct from a plain
    :class:`ForkServerError` because retrying the same request cold
    would likely wedge again — callers shed it instead (the daemon's
    ``timeout`` shed reason)."""


class ForkServerBackoff(ForkServerError):
    """A zygote boot was suppressed by the exponential-backoff gate
    after consecutive boot failures.  Not evidence of a new failure —
    callers should serve the request cold and retry the boot later."""


def _pid_alive(pid: Optional[int]) -> bool:
    if not pid:
        return False
    try:
        os.kill(pid, 0)
        return True
    except OSError:
        return False


class ForkServer:
    """Client for one zygote serving one deployed app.

    Two transports behind one protocol:

    * **subprocess** (default) — the zygote is a fresh
      ``python -m repro.pool.forkserver`` child speaking JSON lines on
      its stdin/stdout (PR 1 behavior: the app zygote pays a full
      interpreter + hot-set boot).
    * **shared base** (``base=BaseZygote``) — the zygote is *forked
      from the base* via ``spawn_app`` and speaks the same protocol
      over a unix socket.  Boot cost collapses to ``fork() + delta
      import`` and the shared hot set's pages are CoW-shared with
      every sibling zygote; crash recovery re-forks from the still-warm
      base instead of re-booting an interpreter.
    """

    def __init__(self, app_dir: str, *, preload: Sequence[str] = (),
                 timeout_s: float = 120.0,
                 base: Optional["BaseZygote"] = None,
                 fault_hook=None,
                 boot_backoff_s: float = 0.5,
                 boot_backoff_max_s: float = 30.0,
                 clock=time.monotonic) -> None:
        self.app_dir = os.path.abspath(app_dir)
        self.preload_modules = list(preload)
        self.timeout_s = timeout_s
        self.base = base
        # chaos hook (repro.pool.chaos): called before every protocol
        # write; None (the default) keeps the serving path unchanged
        self.fault_hook = fault_hook
        # boot backoff gate: consecutive boot failures push the next
        # allowed attempt out exponentially, so a persistently-crashing
        # zygote cannot hot-loop interpreter boots.  clock is injectable
        # so tests can drive the gate without sleeping.
        self.boot_backoff_s = boot_backoff_s
        self.boot_backoff_max_s = boot_backoff_max_s
        self.boot_failures = 0
        self._next_boot_t = 0.0
        self._clock = clock
        self.proc: Optional[subprocess.Popen] = None
        self._stderr_file = None
        # shared-base transport state
        self._sock: Optional[socket.socket] = None
        self._rfile = None
        self._wfile = None
        self._spawn_pid: Optional[int] = None
        self.ready: dict = {}
        self.execs = 0
        # modules whose fast-path preload failed in the zygote: kept so
        # callers can see the failure and so exec() stops re-sending
        # (and re-failing) them every dispatch
        self.preload_errors: list[str] = []
        # the zygote protocol is strictly request/reply on one pipe
        # pair: concurrent callers (a serve worker + the daemon's
        # rewarm tick) must not interleave writes or steal replies
        self._lock = threading.RLock()

    # ------------------------------------------------------------ lifecycle
    @property
    def alive(self) -> bool:
        if self.base is not None:
            return self._sock is not None and _pid_alive(self._spawn_pid)
        return self.proc is not None and self.proc.poll() is None

    @property
    def pid(self) -> Optional[int]:
        """The zygote's pid (spawned-from-base or subprocess)."""
        if self.base is not None:
            return self._spawn_pid
        return self.proc.pid if self.proc is not None else None

    def _argv(self) -> list[str]:
        cmd = [sys.executable, "-m", "repro.pool.forkserver",
               "--app-dir", self.app_dir]
        if self.preload_modules:
            cmd += ["--preload", ",".join(self.preload_modules)]
        return cmd

    def start(self) -> dict:
        with self._lock:
            return self._start_locked()

    def _start_locked(self) -> dict:
        if self.alive:
            return self.ready
        now = self._clock()
        if now < self._next_boot_t:
            raise ForkServerBackoff(
                f"zygote boot for "
                f"{os.path.basename(self.app_dir) or 'base'!r} gated "
                f"for {self._next_boot_t - now:.2f}s more after "
                f"{self.boot_failures} consecutive boot failures")
        try:
            ready = self._boot_locked()
        except Exception:
            self.boot_failures += 1
            delay = min(
                self.boot_backoff_s * (2 ** (self.boot_failures - 1)),
                self.boot_backoff_max_s)
            self._next_boot_t = self._clock() + delay
            raise
        self.boot_failures = 0
        self._next_boot_t = 0.0
        return ready

    def _boot_locked(self) -> dict:
        if self.proc is not None or self._sock is not None:
            self._stop_locked()  # zygote died behind our back: clean up
        t0 = time.perf_counter()
        if self.base is not None:
            ready = self._start_from_base_locked()
        else:
            env = dict(os.environ)
            env["PYTHONPATH"] = (_REPRO_SRC + os.pathsep
                                 + env.get("PYTHONPATH", ""))
            # stderr goes to an unbuffered temp file, NOT a pipe:
            # children print tracebacks there, and an undrained pipe
            # would fill and deadlock the zygote mid-waitpid
            self._stderr_file = tempfile.TemporaryFile()
            self.proc = subprocess.Popen(
                self._argv(), stdin=subprocess.PIPE,
                stdout=subprocess.PIPE, stderr=self._stderr_file,
                text=True, env=env)
            ready = self._check_ready_locked()
        self._record_boot_span(t0, ready)
        return ready

    def _record_boot_span(self, t0: float, ready: dict) -> None:
        """When tracing is on, boot becomes its own trace: a
        ``spawn_app`` (forked from the base) or ``zygote_boot``
        (subprocess) root with a ``preload:<mod>`` child per module
        the zygote reported importing at boot."""
        from repro.obs.tracing import get_tracer, new_id

        tracer = get_tracer()
        if not tracer.enabled:
            return
        name = "spawn_app" if self.base is not None else "zygote_boot"
        trace_id = new_id()
        root_id = tracer.add(
            name, trace_id=trace_id, t_start_ms=t0 * 1e3,
            duration_ms=(time.perf_counter() - t0) * 1e3,
            attrs={"app": os.path.basename(self.app_dir) or "base",
                   "pid": ready.get("pid")})
        t_ms = t0 * 1e3
        for mod, ms in (ready.get("preload_ms") or {}).items():
            tracer.add(f"preload:{mod}", trace_id=trace_id,
                       parent_id=root_id, t_start_ms=t_ms,
                       duration_ms=float(ms), attrs={"module": mod})
            t_ms += float(ms)

    def _check_ready_locked(self) -> dict:
        self.ready = self._read_reply()
        if not self.ready.get("ok") or self.ready.get("errors"):
            # a zygote that failed to preload its hot set would silently
            # serve *bare* forks — fail loudly instead
            detail = self.ready
            self._stop_locked()
            raise ForkServerError(f"zygote failed to boot: {detail}")
        self.preload_errors = []  # fresh zygote, fresh slate
        return self.ready

    def _start_from_base_locked(self) -> dict:
        """Fork this app's zygote from the shared base: one
        ``spawn_app`` roundtrip carries the app dir *and* the batched
        delta preload, then we connect straight to the child."""
        spawn = self.base.spawn_app(self.app_dir, self.preload_modules,
                                    accept_timeout_s=self.timeout_s)
        self._spawn_pid = spawn["pid"]
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(self.timeout_s)
        try:
            sock.connect(spawn["socket"])
        except OSError as exc:
            sock.close()
            self._spawn_pid = None
            raise ForkServerError(
                f"cannot reach spawned app zygote: {exc}") from exc
        sock.settimeout(None)  # _read_reply's select() bounds reads
        self._sock = sock
        self._rfile = sock.makefile("r")
        self._wfile = sock.makefile("w")
        self._socket_path = spawn["socket"]
        return self._check_ready_locked()

    def stop(self) -> None:
        with self._lock:
            self._stop_locked()

    def _stop_locked(self) -> None:
        self._stop_spawned_locked()
        if self.proc is None:
            return
        try:
            if self.proc.poll() is None:
                self._request({"cmd": "shutdown"})
        except (ForkServerError, OSError, ValueError):
            pass
        finally:
            if self.proc.poll() is None:
                self.proc.terminate()
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()
            self.proc = None
            if self._stderr_file is not None:
                self._stderr_file.close()
                self._stderr_file = None

    def _stop_spawned_locked(self) -> None:
        if self._sock is not None:
            try:
                if _pid_alive(self._spawn_pid):
                    self._request({"cmd": "shutdown"})
            except (ForkServerError, OSError, ValueError):
                pass
            for fh in (self._rfile, self._wfile, self._sock):
                try:
                    fh.close()
                except OSError:
                    pass
            self._rfile = self._wfile = self._sock = None
        if _pid_alive(self._spawn_pid):
            # unresponsive spawned zygote: kill it; the base reaps
            try:
                os.kill(self._spawn_pid, signal.SIGKILL)
            except OSError:
                pass
        self._spawn_pid = None

    def restart(self, preload: Optional[Sequence[str]] = None) -> dict:
        """Tear down (whatever is left of) the zygote and boot a fresh
        one; ``preload`` replaces the pre-import set if given.  With a
        shared base this is the crash-recovery fast path: a re-fork
        from the resident base, not an interpreter boot."""
        with self._lock:
            self._stop_locked()
            if preload is not None:
                self.preload_modules = list(dict.fromkeys(preload))
            return self._start_locked()

    def rebase(self, base: Optional["BaseZygote"],
               preload: Optional[Sequence[str]] = None) -> dict:
        """Swap this app's zygote onto a (new) base: used by the rewarm
        tick's base hot-swap.  Holds the protocol lock, so in-flight
        execs finish before the old zygote is torn down and callers
        blocked on the lock land on the freshly spawned one."""
        with self._lock:
            self._stop_locked()
            self.base = base
            if preload is not None:
                self.preload_modules = list(dict.fromkeys(preload))
            return self._start_locked()

    def __enter__(self) -> "ForkServer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------- commands
    def exec(self, *, invocations: int = 1, handler: Optional[str] = None,
             seed: int = 0, preload: Optional[Sequence[str]] = None,
             trace: Optional[dict] = None,
             live_profile: Optional[dict] = None) -> dict:
        """One forked warm instance; returns runner-format metrics.

        ``preload`` rides the fast path: the modules are imported in
        the zygote *in the same roundtrip*, ahead of the fork — a
        rewarm plus a dispatch for the price of one protocol exchange.
        A module that fails to import does not fail the exec (serving
        beats rewarming), but the failure is recorded in
        ``preload_errors`` and the module is not re-sent on later
        execs; use :meth:`preload` for the fail-loudly semantics.

        ``trace`` is an optional ``{"trace_id", "parent_id"}`` span
        context: the zygote child then records fork / per-module import
        / invoke spans and ships them back; they land (merged with any
        fast-path preload spans, protocol order preserved) under
        ``"spans"`` in the returned metrics dict for the caller's
        tracer.

        ``live_profile`` is an optional sampler config (see
        :meth:`repro.core.adaptive.LiveProfileConfig.exec_config`); the
        child then ships a profile-shard-shaped payload back under
        ``"live_profile"`` in the metrics dict for the adaptive loop.
        """
        msg = {"cmd": "exec", "invocations": invocations,
               "handler": handler, "seed": seed}
        if live_profile:
            # in-production sampled profiling (repro.core.adaptive):
            # the child times imports under the app's libs root and
            # runs the call-path sampler around the invocations
            msg["live_profile"] = {
                **live_profile,
                "only_under": [os.path.join(self.app_dir, "libs")],
            }
        if trace:
            msg["trace"] = {"trace_id": trace["trace_id"],
                            "parent_id": trace.get("parent_id")}
        if preload:
            failed = {e.split(":", 1)[0] for e in self.preload_errors}
            msg["preload"] = [m for m in preload
                              if m not in self.preload_modules
                              and m not in failed]
        rep = self._request(msg)
        self.preload_modules.extend(rep.get("preloaded", []))
        self.preload_errors.extend(rep.get("preload_errors", []))
        self.execs += 1
        metrics = rep["metrics"]
        if rep.get("spans"):  # batched-preload spans precede the fork's
            metrics["spans"] = [*rep["spans"], *metrics.get("spans", [])]
        return metrics

    def preload(self, modules: Sequence[str]) -> dict:
        rep = self._request({"cmd": "preload", "modules": list(modules)})
        self.preload_modules.extend(rep.get("preloaded", []))
        return rep

    def rewarm(self, report) -> dict:
        """Re-warm from a fresh report (adaptive loop callback):
        preload the newly-hot packages.  ``report`` is anything
        :func:`repro.api.as_report` accepts — the
        :class:`~repro.core.profiler.report.OptimizationReport` itself
        or the path of a saved versioned artifact.  A zygote that died
        since the last exec (OOM-killed, crashed handler fork taking it
        down) is booted fresh with the merged hot set — the adaptive
        loop doubles as the fleet's crash recovery."""
        from repro.api.artifacts import as_report
        from repro.pool.policies import hot_set_from_report
        hot = hot_set_from_report(as_report(report))
        with self._lock:
            return self._rewarm_locked(hot)

    def _rewarm_locked(self, hot: list) -> dict:
        if not self.alive:
            merged = list(dict.fromkeys([*self.preload_modules, *hot]))
            # restart raises ForkServerError if the merged hot set fails
            # to preload, so a bad re-warm surfaces instead of silently
            # serving bare forks
            ready = self.restart(preload=merged)
            return {"ok": True, "preloaded": ready.get("preloaded", merged),
                    "errors": list(ready.get("errors", [])),
                    "restarted": True}
        mods = [m for m in hot if m not in self.preload_modules]
        if not mods:
            return {"ok": True, "preloaded": [], "errors": []}
        return self.preload(mods)

    def ping(self) -> dict:
        return self._request({"cmd": "ping"})

    def rss_kb(self) -> int:
        """The zygote's current resident set in kB (0 if not running) —
        what a fleet budget arbiter charges for keeping this zygote
        resident.  Reads ``/proc/<pid>/statm`` (one line) instead of
        scanning ``status``; arbiters poll this per admission tick."""
        if not self.alive:
            return 0
        return _runner.proc_memory_kb(self.pid)["rss_kb"]

    def memory_kb(self) -> dict:
        """Shared/private-aware memory of the zygote:
        ``{"rss_kb", "pss_kb", "shared_kb", "private_kb"}`` (all zero
        when not running).  With a shared base, ``private_kb`` (or the
        RSS increment over the base, whichever the kernel can report —
        see :func:`repro.benchsuite.runner.proc_memory_kb`) is the
        *incremental* cost of this zygote; the base's pages are charged
        once fleet-wide."""
        if not self.alive:
            return {"rss_kb": 0, "pss_kb": 0, "shared_kb": 0,
                    "private_kb": 0}
        return _runner.proc_memory_kb(self.pid)

    # ------------------------------------------------------------- plumbing
    def _reader(self):
        return self._rfile if self._sock is not None else (
            self.proc.stdout if self.proc is not None else None)

    def _writer(self):
        return self._wfile if self._sock is not None else (
            self.proc.stdin if self.proc is not None else None)

    def _kill_unresponsive(self) -> None:
        if self._sock is not None:
            if _pid_alive(self._spawn_pid):
                try:
                    os.kill(self._spawn_pid, signal.SIGKILL)
                except OSError:
                    pass
        elif self.proc is not None:
            self.proc.kill()

    def _dead_detail(self) -> str:
        if self._sock is not None or self.base is not None:
            tail = self.base._stderr_tail() if self.base is not None \
                else ""
            return f"spawned zygote pid={self._spawn_pid} died: {tail}"
        return f"zygote died (exit={self.proc.poll()}): " \
               f"{self._stderr_tail()}"

    def _request(self, obj: dict) -> dict:
        with self._lock:
            if self.fault_hook is not None:
                # chaos site "protocol": may kill/stop the zygote pid
                # or raise before the write, so the request/reply
                # stream itself is never left half-written
                self.fault_hook(
                    "protocol",
                    app=os.path.basename(self.app_dir) or "_base",
                    op=obj.get("cmd"), pid=self.pid, server=self)
            if not self.alive:
                raise ForkServerError("zygote is not running")
            w = self._writer()
            try:
                w.write(json.dumps(obj) + "\n")
                w.flush()
            except (OSError, ValueError, BrokenPipeError) as exc:
                raise ForkServerError(
                    f"zygote control channel broken: {exc}") from exc
            rep = self._read_reply()
        if not rep.get("ok"):
            raise ForkServerError(str(rep))
        return rep

    def _read_reply(self) -> dict:
        # bound every protocol read by timeout_s: a wedged handler in a
        # forked child would otherwise hang the zygote (and us) forever
        reader = self._reader()
        ready, _, _ = select.select([reader], [], [], self.timeout_s)
        if not ready:
            self._kill_unresponsive()
            raise ForkServerTimeout(
                f"zygote unresponsive after {self.timeout_s}s "
                f"(hung forked instance?); killed")
        line = reader.readline()
        if not line:
            raise ForkServerError(self._dead_detail())
        return json.loads(line)

    def _stderr_tail(self, nbytes: int = 2000) -> str:
        if self._stderr_file is None:
            return ""
        try:
            self._stderr_file.seek(0, os.SEEK_END)
            size = self._stderr_file.tell()
            self._stderr_file.seek(max(0, size - nbytes))
            return self._stderr_file.read().decode("utf-8", "replace")
        except (OSError, ValueError):
            return ""


class BaseZygote(ForkServer):
    """The shared parent of a two-tier zygote fleet.

    Boots ``python -m repro.pool.forkserver --base`` pre-importing the
    cross-app shared hot set (:mod:`repro.pool.sharing`), then serves
    ``spawn_app``: per-app zygotes are forked *from this process*, so
    the shared set's pages exist once fleet-wide (CoW) and an app
    zygote's boot is ``fork() + its private delta import``.

    ``search_paths`` are extra ``sys.path`` entries (typically every
    member app's vendored ``libs/``, see
    :func:`repro.pool.sharing.shared_search_paths`) letting the base
    resolve modules that only exist inside app deployments.
    """

    def __init__(self, *, preload: Sequence[str] = (),
                 search_paths: Sequence[str] = (),
                 timeout_s: float = 120.0,
                 fault_hook=None,
                 boot_backoff_s: float = 0.5,
                 boot_backoff_max_s: float = 30.0,
                 clock=time.monotonic) -> None:
        super().__init__(os.getcwd(), preload=preload,
                         timeout_s=timeout_s, fault_hook=fault_hook,
                         boot_backoff_s=boot_backoff_s,
                         boot_backoff_max_s=boot_backoff_max_s,
                         clock=clock)
        self.app_dir = ""  # the base serves the fleet, not one app
        self.search_paths = [os.path.abspath(p) for p in search_paths]
        self._rundir: Optional[str] = None
        self._spawn_seq = 0

    def _argv(self) -> list[str]:
        cmd = [sys.executable, "-m", "repro.pool.forkserver", "--base"]
        for p in self.search_paths:
            cmd += ["--path", p]
        if self.preload_modules:
            cmd += ["--preload", ",".join(self.preload_modules)]
        return cmd

    def _start_locked(self) -> dict:
        if not self.alive and self._rundir is None:
            self._rundir = tempfile.mkdtemp(prefix="zygote-base-")
        return super()._start_locked()

    def _stop_locked(self) -> None:
        super()._stop_locked()
        if self._rundir is not None:
            import shutil
            shutil.rmtree(self._rundir, ignore_errors=True)
            self._rundir = None

    def spawn_app(self, app_dir: str, preload: Sequence[str] = (), *,
                  accept_timeout_s: float = 120.0) -> dict:
        """Fork a per-app zygote from the base (single roundtrip,
        batched delta preload); returns ``{"pid", "socket"}`` for the
        caller to connect to.  Raises :class:`ForkServerError` when the
        base is down or the delta import crashed the child."""
        with self._lock:
            if self.fault_hook is not None:
                # chaos site "spawn_app": injected boot failures land
                # here, *named for the app being spawned* (the
                # protocol-site hook below sees the base)
                self.fault_hook(
                    "spawn_app",
                    app=os.path.basename(app_dir.rstrip(os.sep)),
                    base=self)
            if not self.alive:
                raise ForkServerError("base zygote is not running")
            self._spawn_seq += 1
            path = os.path.join(self._rundir,
                                f"app-{self._spawn_seq}.sock")
            rep = self._request({
                "cmd": "spawn_app",
                "app_dir": os.path.abspath(app_dir),
                "preload": list(preload),
                "socket": path,
                "accept_timeout_s": accept_timeout_s,
            })
            return {"pid": rep["pid"], "socket": rep["socket"]}

    def exec(self, **_kw) -> dict:  # pragma: no cover - misuse guard
        raise ForkServerError(
            "the base zygote serves spawn_app, not exec; dispatch "
            "through a per-app ForkServer spawned from it")


if __name__ == "__main__":
    raise SystemExit(zygote_main())
