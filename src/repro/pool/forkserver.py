"""Profile-guided fork-server (zygote) for warm instance pools.

The paper removes library-loading cost from the cold path by *deferring*
imports; this module removes it by *amortizing* them: one long-lived
zygote process pre-imports the measured hot set (the packages an
:class:`~repro.core.profiler.report.OptimizationReport` shows are
actually exercised at runtime), then forks a fresh handler instance per
request.  Forked children share the preloaded libraries copy-on-write,
so their "cold" start only pays ``fork() + import handler`` — the
handler module itself plus whatever the hot set did not already load —
instead of the full library initialization.

Run as a module, this file *is* the zygote::

    python -m repro.pool.forkserver --app-dir .benchsuite/apps/graph_bfs \
        --preload fakelib_igraph

Protocol: newline-delimited JSON on stdin/stdout.  The zygote announces
``{"ok": true, "event": "ready", ...}`` once the preload set is
imported, then serves commands:

    {"cmd": "exec", "invocations": N, "handler": H, "seed": S}
        -> {"ok": true, "metrics": {... runner-format metrics ...}}
    {"cmd": "preload", "modules": [...]}     # adaptive re-warm
        -> {"ok": true, "preloaded": [...], "errors": [...]}
    {"cmd": "ping"}      -> {"ok": true, "preloaded": [...]}
    {"cmd": "shutdown"}  -> {"ok": true}  (zygote exits)

Each ``exec`` forks; the child redirects its stdout to ``/dev/null`` (so
handler prints cannot corrupt the control channel), imports ``handler``,
runs the shared :func:`repro.benchsuite.runner.run_invocations` loop and
ships :func:`repro.benchsuite.runner.metrics_dict` JSON back over a
dedicated pipe.  Fork-to-ready time is measured against the zygote's
clock (``time.perf_counter`` is CLOCK_MONOTONIC — system-wide, valid
across ``fork``), so reported ``init_ms`` includes the fork itself.

The in-process :class:`ForkServer` wraps the zygote for the harness:
``start() -> exec()* -> stop()``, plus ``rewarm(report)`` which the
adaptive :class:`~repro.core.adaptive.controller.SlimStartController`
calls after a re-profile to preload the *new* workload's hot set.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import select
import subprocess
import sys
import tempfile
import threading
import time
import traceback
from typing import Optional, Sequence

from repro.benchsuite import runner as _runner

_REPRO_SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


# ---------------------------------------------------------------------------
# Zygote side
# ---------------------------------------------------------------------------

def _import_modules(modules: Sequence[str]) -> tuple[list[str], list[str]]:
    done: list[str] = []
    errors: list[str] = []
    for mod in modules:
        mod = mod.strip()
        if not mod:
            continue
        try:
            importlib.import_module(mod)
            done.append(mod)
        except Exception as exc:  # zygote must survive bad preloads
            errors.append(f"{mod}: {exc!r}")
    return done, errors


def _fork_exec(cmd: dict) -> dict:
    """Fork one instance; relay its metrics.  Runs inside the zygote."""
    r, w = os.pipe()
    t0 = time.perf_counter()
    pid = os.fork()
    if pid == 0:  # ---------------------------------------------- child
        code = 1
        try:
            os.close(r)
            devnull = os.open(os.devnull, os.O_WRONLY)
            os.dup2(devnull, 1)
            rss_sampler = _runner.PeakRssSampler().start()
            handler_mod = importlib.import_module("handler")
            init_s = time.perf_counter() - t0
            invocation_s, counts = _runner.run_invocations(
                handler_mod,
                invocations=int(cmd.get("invocations", 1)),
                handler=cmd.get("handler"),
                seed=int(cmd.get("seed", 0)))
            peak_kb = max(_runner.instance_rss_kb(), rss_sampler.stop())
            metrics = _runner.metrics_dict(init_s, invocation_s, counts,
                                           peak_kb)
            with os.fdopen(w, "w") as fh:
                fh.write(json.dumps(metrics))
            code = 0
        except BaseException:
            traceback.print_exc(file=sys.stderr)
        finally:
            os._exit(code)
    # -------------------------------------------------------------- zygote
    os.close(w)
    with os.fdopen(r) as fh:
        payload = fh.read()
    _, status = os.waitpid(pid, 0)
    if status != 0 or not payload:
        return {"ok": False,
                "error": f"forked instance pid={pid} wait-status={status}"}
    return {"ok": True, "pid": pid, "metrics": json.loads(payload)}


def zygote_main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app-dir", required=True)
    ap.add_argument("--preload", default="",
                    help="comma-separated modules imported at zygote boot")
    args = ap.parse_args(argv)

    if not hasattr(os, "fork"):
        print(json.dumps({"ok": False, "error": "platform lacks fork()"}),
              flush=True)
        return 2

    _runner.setup_app_path(os.path.abspath(args.app_dir))
    preloaded, errors = _import_modules(args.preload.split(","))

    def reply(obj: dict) -> None:
        sys.stdout.write(json.dumps(obj) + "\n")
        sys.stdout.flush()

    reply({"ok": True, "event": "ready", "preloaded": preloaded,
           "errors": errors, "pid": os.getpid()})
    for line in sys.stdin:
        line = line.strip()
        if not line:
            continue
        try:
            cmd = json.loads(line)
        except ValueError:
            reply({"ok": False, "error": "bad json"})
            continue
        op = cmd.get("cmd")
        if op == "exec":
            reply(_fork_exec(cmd))
        elif op == "preload":
            done, errs = _import_modules(cmd.get("modules", []))
            preloaded.extend(done)
            reply({"ok": not errs, "preloaded": done, "errors": errs})
        elif op == "ping":
            reply({"ok": True, "preloaded": list(preloaded)})
        elif op == "shutdown":
            reply({"ok": True})
            return 0
        else:
            reply({"ok": False, "error": f"unknown cmd {op!r}"})
    return 0


# ---------------------------------------------------------------------------
# Parent side
# ---------------------------------------------------------------------------

class ForkServerError(RuntimeError):
    pass


class ForkServer:
    """Client for one zygote serving one deployed app."""

    def __init__(self, app_dir: str, *, preload: Sequence[str] = (),
                 timeout_s: float = 120.0) -> None:
        self.app_dir = os.path.abspath(app_dir)
        self.preload_modules = list(preload)
        self.timeout_s = timeout_s
        self.proc: Optional[subprocess.Popen] = None
        self._stderr_file = None
        self.ready: dict = {}
        self.execs = 0
        # the zygote protocol is strictly request/reply on one pipe
        # pair: concurrent callers (a serve worker + the daemon's
        # rewarm tick) must not interleave writes or steal replies
        self._lock = threading.RLock()

    # ------------------------------------------------------------ lifecycle
    @property
    def alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None

    def start(self) -> dict:
        with self._lock:
            return self._start_locked()

    def _start_locked(self) -> dict:
        if self.alive:
            return self.ready
        if self.proc is not None:  # zygote died behind our back: clean up
            self.stop()
        cmd = [sys.executable, "-m", "repro.pool.forkserver",
               "--app-dir", self.app_dir]
        if self.preload_modules:
            cmd += ["--preload", ",".join(self.preload_modules)]
        env = dict(os.environ)
        env["PYTHONPATH"] = (_REPRO_SRC + os.pathsep
                             + env.get("PYTHONPATH", ""))
        # stderr goes to an unbuffered temp file, NOT a pipe: children
        # print tracebacks there, and an undrained pipe would fill and
        # deadlock the zygote mid-waitpid
        self._stderr_file = tempfile.TemporaryFile()
        self.proc = subprocess.Popen(
            cmd, stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=self._stderr_file, text=True, env=env)
        self.ready = self._read_reply()
        if not self.ready.get("ok") or self.ready.get("errors"):
            # a zygote that failed to preload its hot set would silently
            # serve *bare* forks — fail loudly instead
            detail = self.ready
            self.stop()
            raise ForkServerError(f"zygote failed to boot: {detail}")
        return self.ready

    def stop(self) -> None:
        with self._lock:
            self._stop_locked()

    def _stop_locked(self) -> None:
        if self.proc is None:
            return
        try:
            if self.proc.poll() is None:
                self._request({"cmd": "shutdown"})
        except (ForkServerError, OSError, ValueError):
            pass
        finally:
            if self.proc.poll() is None:
                self.proc.terminate()
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()
            self.proc = None
            if self._stderr_file is not None:
                self._stderr_file.close()
                self._stderr_file = None

    def restart(self, preload: Optional[Sequence[str]] = None) -> dict:
        """Tear down (whatever is left of) the zygote and boot a fresh
        one; ``preload`` replaces the pre-import set if given."""
        with self._lock:
            self._stop_locked()
            if preload is not None:
                self.preload_modules = list(dict.fromkeys(preload))
            return self._start_locked()

    def __enter__(self) -> "ForkServer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------- commands
    def exec(self, *, invocations: int = 1, handler: Optional[str] = None,
             seed: int = 0) -> dict:
        """One forked warm instance; returns runner-format metrics."""
        rep = self._request({"cmd": "exec", "invocations": invocations,
                             "handler": handler, "seed": seed})
        self.execs += 1
        return rep["metrics"]

    def preload(self, modules: Sequence[str]) -> dict:
        rep = self._request({"cmd": "preload", "modules": list(modules)})
        self.preload_modules.extend(rep.get("preloaded", []))
        return rep

    def rewarm(self, report) -> dict:
        """Re-warm from a fresh report (adaptive loop callback):
        preload the newly-hot packages.  ``report`` is anything
        :func:`repro.api.as_report` accepts — the
        :class:`~repro.core.profiler.report.OptimizationReport` itself
        or the path of a saved versioned artifact.  A zygote that died
        since the last exec (OOM-killed, crashed handler fork taking it
        down) is booted fresh with the merged hot set — the adaptive
        loop doubles as the fleet's crash recovery."""
        from repro.api.artifacts import as_report
        from repro.pool.policies import hot_set_from_report
        hot = hot_set_from_report(as_report(report))
        with self._lock:
            return self._rewarm_locked(hot)

    def _rewarm_locked(self, hot: list) -> dict:
        if not self.alive:
            merged = list(dict.fromkeys([*self.preload_modules, *hot]))
            # restart raises ForkServerError if the merged hot set fails
            # to preload, so a bad re-warm surfaces instead of silently
            # serving bare forks
            ready = self.restart(preload=merged)
            return {"ok": True, "preloaded": ready.get("preloaded", merged),
                    "errors": list(ready.get("errors", [])),
                    "restarted": True}
        mods = [m for m in hot if m not in self.preload_modules]
        if not mods:
            return {"ok": True, "preloaded": [], "errors": []}
        return self.preload(mods)

    def ping(self) -> dict:
        return self._request({"cmd": "ping"})

    def rss_kb(self) -> int:
        """The zygote's current VmRSS in kB (0 if not running) — what a
        fleet budget arbiter charges for keeping this zygote resident."""
        if not self.alive:
            return 0
        try:
            with open(f"/proc/{self.proc.pid}/status") as fh:
                for line in fh:
                    if line.startswith("VmRSS:"):
                        return int(line.split()[1])
        except (OSError, ValueError, IndexError):
            pass
        return 0

    # ------------------------------------------------------------- plumbing
    def _request(self, obj: dict) -> dict:
        with self._lock:
            if self.proc is None or self.proc.poll() is not None:
                raise ForkServerError("zygote is not running")
            self.proc.stdin.write(json.dumps(obj) + "\n")
            self.proc.stdin.flush()
            rep = self._read_reply()
        if not rep.get("ok"):
            raise ForkServerError(str(rep))
        return rep

    def _read_reply(self) -> dict:
        # bound every protocol read by timeout_s: a wedged handler in a
        # forked child would otherwise hang the zygote (and us) forever
        ready, _, _ = select.select([self.proc.stdout], [], [],
                                    self.timeout_s)
        if not ready:
            self.proc.kill()
            raise ForkServerError(
                f"zygote unresponsive after {self.timeout_s}s "
                f"(hung forked instance?); killed")
        line = self.proc.stdout.readline()
        if not line:
            raise ForkServerError(
                f"zygote died (exit={self.proc.poll()}): "
                f"{self._stderr_tail()}")
        return json.loads(line)

    def _stderr_tail(self, nbytes: int = 2000) -> str:
        if self._stderr_file is None:
            return ""
        try:
            self._stderr_file.seek(0, os.SEEK_END)
            size = self._stderr_file.tell()
            self._stderr_file.seek(max(0, size - nbytes))
            return self._stderr_file.read().decode("utf-8", "replace")
        except (OSError, ValueError):
            return ""


if __name__ == "__main__":
    raise SystemExit(zygote_main())
