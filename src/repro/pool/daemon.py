"""Long-running fleet daemon: the continuous controller + fleet loop.

Everything before this module runs the fleet *one-shot*: replay a
trace, print a summary, exit.  SLIMSTART's pitch is continuous,
CI/CD-integrated optimization — profiles evolve with the workload and
the warm pool adapts online — so :class:`FleetDaemon` keeps the fleet
resident and serves invocations for as long as the process lives:

* **bounded admission** — every app gets a FIFO queue capped by
  :class:`~repro.pool.fleet.QueueConfig` (``depth`` + shed policy);
  overload is *shed* and accounted, never allowed to spawn unbounded
  demand instances;
* **rewarm timer** — every ``rewarm_interval_s`` the daemon re-loads
  the deployed per-app report artifacts and re-preloads the matching
  zygotes (``ZygoteFleet.rewarm_from_dir``), so defer-set drift picked
  up by an external ``python -m repro profile`` / ``ci-check`` run
  reaches the running fleet without a restart; with a two-tier fleet
  (``--shared-base``) the same tick recomputes the cross-app shared
  hot set and hot-swaps the base zygote when it changed — app zygotes
  are re-forked onto the new base one at a time under their protocol
  locks, so in-flight execs finish and nothing is shed;
* **graceful drain** — on SIGTERM (or an explicit ``drain``), the
  daemon stops admitting, lets in-flight invocations finish, flushes
  still-queued requests into the summary, and emits a schema-versioned
  ``fleet_summary`` artifact (:mod:`repro.api.artifacts`).

Two backends share the daemon shell:

:class:`SimFleetBackend`
    Drives a :class:`~repro.pool.fleet.FleetManager` incrementally
    (``begin -> offer -> finish``).  Queueing/shedding happens in
    simulated time, so a whole replayed trace runs in milliseconds —
    this is ``python -m repro fleet serve --sim`` and the fast test
    tier.

:class:`RealFleetBackend`
    Owns a :class:`~repro.pool.fleet.ZygoteFleet` plus one worker
    thread per app pulling from that app's bounded queue (the zygote
    control channel is single-flight, so per-app dispatch is
    serialized; ``QueueConfig.max_concurrency`` only shapes the
    simulation).  Queue waits here are real wall-clock milliseconds.
"""

from __future__ import annotations

import dataclasses
import json
import queue
import sys
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional, TextIO

from repro.obs.log import get_logger
from repro.obs.tracing import get_tracer, new_id, now_ms
from repro.pool.fleet import FleetManager, QueueConfig, ZygoteFleet
from repro.pool.simulator import percentile_ms
from repro.pool.trace import Request, Trace

_LOG = get_logger("fleet.daemon")


# -- metric shorthands.  Families are looked up per call (cheap dict
# hit in the default registry) instead of cached at import, so a
# test-time registry reset cannot strand stale handles.

def _reg():
    from repro.obs.metrics import default_registry
    return default_registry()


def _m_requests(app: str, outcome: str) -> None:
    _reg().counter("repro_requests_total",
                   "admissions by outcome (served/queued/shed)",
                   labels=("app", "outcome")).labels(
        app=app, outcome=outcome).inc()


def _m_served(app: str) -> None:
    _reg().counter("repro_served_total", "requests fully served",
                   labels=("app",)).labels(app=app).inc()


def _m_errors(app: str) -> None:
    _reg().counter("repro_errors_total", "dispatch failures",
                   labels=("app",)).labels(app=app).inc()


def _m_sheds(app: str, reason: str) -> None:
    _reg().counter("repro_sheds_total",
                   "requests shed by the bounded queue, by reason",
                   labels=("app", "reason")).labels(
        app=app, reason=reason).inc()


def _m_flushed(n: int) -> None:
    if n:
        _reg().counter("repro_flushed_total",
                       "queued requests flushed unserved at drain"
                       ).inc(n)


def _m_abandoned(app: str, n: int) -> None:
    if n:
        _reg().counter("repro_abandoned_total",
                       "in-flight dispatches abandoned at drain (the "
                       "worker never returned by the deadline)",
                       labels=("app",)).labels(app=app).inc(n)


def _m_rewarm_failure(app: str) -> None:
    _reg().counter("repro_rewarm_failures_total",
                   "rewarm-tick failures by app (app=\"_tick\" when "
                   "the whole tick raised)",
                   labels=("app",)).labels(app=app).inc()


def _m_hist(name: str, help: str, app: str, value_ms: float) -> None:
    _reg().histogram(name, help, labels=("app",)).labels(
        app=app).observe(value_ms)


def _m_gauge(name: str, help: str, app: str, value: float) -> None:
    _reg().gauge(name, help, labels=("app",)).labels(
        app=app).set(value)


def _merge_reasons(into: dict, more: dict) -> dict:
    for reason, n in (more or {}).items():
        into[reason] = into.get(reason, 0) + n
    return into


# ---------------------------------------------------------------------------
# Simulation backend
# ---------------------------------------------------------------------------

class SimFleetBackend:
    """Incremental :class:`FleetManager` behind the daemon interface.

    ``submit`` must see non-decreasing request times (trace replay or a
    wall clock both qualify).  ``reports_dir`` names the directory of
    deployed ``<app>.json`` report artifacts the rewarm tick re-loads
    into the keep-alive policy (only policies with ``add_report``, i.e.
    the profile-guided one, consume them).

    ``adaptive`` is an optional
    :class:`repro.core.adaptive.AdaptiveLoop` (see
    :func:`make_sim_adaptive_loop`): every admission feeds the drift
    detector in *simulated* time, and a confirmed drift regenerates
    synthetic reports into the policy between requests — which is what
    admits zygotes/prewarm floors for apps that became hot after the
    deployed report set was cut.
    """

    def __init__(self, manager: FleetManager, *,
                 reports_dir: Optional[str] = None,
                 adaptive=None) -> None:
        self.manager = manager
        self.reports_dir = reports_dir
        self.adaptive = adaptive
        self._lock = threading.Lock()
        self._started = False

    @property
    def apps(self) -> list[str]:
        return sorted(self.manager.profiles)

    def start(self, trace_name: str = "live") -> dict:
        with self._lock:
            self.manager.begin(trace_name)
            self._started = True
        return {"mode": "sim", "apps": self.apps}

    def submit(self, req: Request) -> str:
        tracer = get_tracer()
        t0 = now_ms() if tracer.enabled else 0.0
        if self.adaptive is not None:
            # drift detection runs in sim time; a fired window
            # re-optimizes here, before the offer, so the policy the
            # request sees is already the regenerated one
            self.adaptive.observe_request(req.app, req.handler,
                                          t=req.t)
        with self._lock:
            outcome = self.manager.offer(req)
        _m_requests(req.app, outcome)
        if tracer.enabled:
            # sim time compresses inside offer(); the span records the
            # *wall* cost of admitting one request, which is what the
            # tracer-overhead perf gate compares against
            tracer.add("request", trace_id=new_id(),
                       t_start_ms=t0, duration_ms=now_ms() - t0,
                       attrs={"app": req.app, "outcome": outcome,
                              "sim": True})
        return outcome

    def drain(self, timeout_s: Optional[float] = None, *,
              flush: bool = True) -> None:
        pass  # simulated queues drain inside finish()

    def finish(self, end_t: Optional[float] = None) -> dict:
        if self.adaptive is not None:
            self.adaptive.flush(t=end_t)
        with self._lock:
            summary = self.manager.finish(end_t)
            self._started = False
        payload = summary.artifact_payload(source="serve-sim")
        if self.adaptive is not None:
            payload["adaptive"] = self.adaptive.summary()
        return payload

    def snapshot(self) -> dict:
        with self._lock:
            reps = self.manager._apps
            reasons: dict = {}
            for s in reps.values():
                _merge_reasons(reasons, s.report.shed_reasons)
            return {
                "requests": sum(s.report.n_requests for s in reps.values()),
                "cold_starts": sum(s.report.cold_starts
                                   for s in reps.values()),
                "sheds": sum(s.report.sheds for s in reps.values()),
                "shed_reasons": reasons,
                "queued": sum(len(s.queue) for s in reps.values()),
                # mid-run "served" must exclude the still-queued (they
                # are neither shed nor flushed yet), or a failover
                # reconciliation would double-count them
                "served": sum(s.report.served - len(s.queue)
                              for s in reps.values()),
            }

    def latency_samples(self, cap: int = 50_000) -> list[float]:
        """Raw end-to-end latency samples (capped), for cluster-level
        percentile merging: the router pools every node's samples via
        :meth:`repro.pool.simulator.PercentilePool.merge` instead of
        averaging per-node percentiles."""
        out: list[float] = []
        with self._lock:
            summary = getattr(self.manager, "_summary", None)
            if summary is None:  # never started
                return out
            for rep in summary.per_app.values():
                take = cap - len(out)
                if take <= 0:
                    break
                out.extend(rep.latencies_ms[:take])
        return out

    def rewarm(self) -> dict:
        """Re-load deployed report artifacts into the policy's hot
        sets — the simulated analogue of re-preloading zygotes."""
        if not self.reports_dir:
            return {}
        from repro.api.artifacts import load_report
        import os
        policy = self.manager.policy
        if not hasattr(policy, "add_report"):
            return {}
        out = {}
        for app in self.apps:
            path = os.path.join(self.reports_dir, f"{app}.json")
            if not os.path.exists(path):
                continue
            try:
                policy.add_report(load_report(path))
                out[app] = {"ok": True}
            except Exception as exc:  # a bad artifact must not kill serving
                out[app] = {"ok": False, "error": repr(exc)}
        return out

    # ------------------------------------------------ warm-state handoff
    def export_app(self, app: str) -> dict:
        """Departing-owner side of a planned migration: package the
        app's warm state — its deployed report artifact (if any) plus
        the sim ground-truth profile — for the new owner to pre-warm
        from *before* placement flips."""
        import dataclasses
        import os
        with self._lock:
            prof = self.manager.profiles.get(app)
        if prof is None:
            raise KeyError(f"export for unknown app {app!r}")
        out: dict = {"app": app,
                     "profile": dataclasses.asdict(prof)}
        if self.reports_dir:
            path = os.path.join(self.reports_dir, f"{app}.json")
            if os.path.exists(path):
                from repro.api.artifacts import (ReportArtifact,
                                                 load_report)
                try:
                    out["report"] = ReportArtifact(
                        load_report(path)).to_payload()
                except Exception:
                    pass  # a bad artifact ships nothing, not a crash
        return out

    def prewarm_app(self, app: str, report=None,
                    profile=None) -> dict:
        """New-owner side: adopt the shipped profile/report and force
        the app's zygote resident before the first migrated request
        lands, so it pays ``warm_init_ms`` instead of cold."""
        from repro.pool.simulator import AppProfile
        with self._lock:
            if app not in self.manager.profiles and profile:
                import dataclasses
                fields = {f.name for f in
                          dataclasses.fields(AppProfile)}
                kw = {k: v for k, v in dict(profile).items()
                      if k in fields}
                kw.setdefault("app", app)
                self.manager.add_app(AppProfile(**kw))
            if report is not None:
                policy = self.manager.policy
                if hasattr(policy, "add_report"):
                    from repro.api.artifacts import ReportArtifact
                    try:
                        policy.add_report(
                            ReportArtifact.from_payload(
                                dict(report)).report)
                    except Exception:
                        pass  # bad shipped report: warm without it
            out = self.manager.prewarm_zygote(app)
        return {"app": app, **out}

    def collect_queued(self) -> list[dict]:
        """Planned-drain flush: requests still queued here are counted
        flushed locally and *returned* (as wire dicts) for the router
        to re-admit at the new owners."""
        with self._lock:
            reqs = self.manager.flush_queued()
        return [{"app": r.app, "handler": r.handler} for r in reqs]

    def stop(self) -> None:
        pass


def make_sim_adaptive_loop(manager: FleetManager, *, config=None,
                           fault_hook=None, clock=None):
    """Wire an :class:`repro.core.adaptive.AdaptiveLoop` to a simulated
    fleet.  There is no forked child to carry the sampler, so the
    "regenerated profile" is synthesized from the app's
    :class:`~repro.pool.simulator.AppProfile` ground truth — the drift
    *detection* and the deploy path (``policy.add_report`` → zygote
    admission + Little's-law prewarm floors) are the real code under
    test; only the profile measurement is simulated."""
    import time as _time

    from repro.core.adaptive import AdaptiveLoop
    from repro.core.profiler.report import OptimizationReport
    from repro.core.profiler.utilization import LibraryStats

    def regenerate(app, _profiler):
        prof = manager.profiles.get(app)
        if prof is None:
            return None
        e2e_s = (prof.cold_init_ms + prof.invoke_ms) / 1e3
        init_s = 0.8 * prof.cold_init_ms / 1e3
        return OptimizationReport(
            application=app, e2e_s=e2e_s, total_init_s=init_s,
            qualifies=init_s / max(e2e_s, 1e-9) > 0.10,
            stats=[LibraryStats(
                name=f"simlib_{app}", utilization=0.9, init_s=init_s,
                init_share=init_s / max(e2e_s, 1e-9),
                runtime_samples=50, file="<sim>")],
            defer_targets=[])

    def apply(report):
        policy = manager.policy
        if hasattr(policy, "add_report"):
            policy.add_report(report)

    return AdaptiveLoop(regenerate_fn=regenerate, apply_fn=apply,
                        config=config, clock=clock or _time.monotonic,
                        fault_hook=fault_hook)


# ---------------------------------------------------------------------------
# Real-process backend
# ---------------------------------------------------------------------------

@dataclass
class _AppServeStats:
    arrivals: int = 0
    served: int = 0
    sheds: int = 0
    flushed: int = 0
    pool: int = 0
    cold: int = 0
    errors: int = 0
    # in-flight dispatches whose worker never came back by the drain
    # deadline: not served, not shed, not flushed — accounted here so
    # conservation never loses a request
    abandoned: int = 0
    # requests served in a degraded mode (e.g. cold-only because the
    # app's zygote is circuit-broken); these ARE counted in ``served``
    degraded: int = 0
    init_ms: list = field(default_factory=list)
    e2e_ms: list = field(default_factory=list)
    queue_waits_ms: list = field(default_factory=list)
    # sheds by cause ("queue-full" | "drop-oldest" | "timeout" |
    # "crash_loop"); sums to ``sheds``
    shed_reasons: dict = field(default_factory=dict)
    # degrades by cause ("crash_loop"); sums to ``degraded``
    degrade_reasons: dict = field(default_factory=dict)

    def count_shed(self, reason: str) -> None:
        self.sheds += 1
        self.shed_reasons[reason] = self.shed_reasons.get(reason, 0) + 1

    def count_degrade(self, reason: str) -> None:
        self.degraded += 1
        self.degrade_reasons[reason] = \
            self.degrade_reasons.get(reason, 0) + 1

    def copy(self) -> "_AppServeStats":
        """Deep-enough copy for reading outside the queue lock: the
        worker threads append to the latency lists and bump counters
        concurrently, so readers must snapshot under ``_cond`` and
        aggregate from the copy."""
        return dataclasses.replace(
            self, init_ms=list(self.init_ms), e2e_ms=list(self.e2e_ms),
            queue_waits_ms=list(self.queue_waits_ms),
            shed_reasons=dict(self.shed_reasons),
            degrade_reasons=dict(self.degrade_reasons))


class RealFleetBackend:
    """Bounded per-app queues + worker threads over a ZygoteFleet."""

    def __init__(self, fleet: ZygoteFleet, *, queue: QueueConfig,
                 reports_dir: Optional[str] = None,
                 seed0: int = 500, adaptive=None) -> None:
        self.fleet = fleet
        self.queue_cfg = queue
        self.reports_dir = reports_dir
        self.seed0 = seed0
        # optional closed-loop re-optimization (repro.core.adaptive
        # .AdaptiveLoop): workers sample live profiles through it and
        # its drift windows close on the wall clock as requests flow
        self.adaptive = adaptive
        self._cond = threading.Condition()
        self._queues: dict[str, deque] = {}
        self._in_flight: dict[str, int] = {}
        self._stats: dict[str, _AppServeStats] = {}
        self._workers: list[threading.Thread] = []
        self._draining = False
        self._seed = seed0
        # bumped when drain() abandons still-running workers: a worker
        # that dequeued under an older generation must not count its
        # (already-abandoned) request when it finally returns
        self._gen = 0
        self.boot: dict = {}

    @property
    def apps(self) -> list[str]:
        return sorted(self.fleet.app_dirs)

    # ----------------------------------------------------------- lifecycle
    def start(self, trace_name: str = "live") -> dict:
        self.boot = self.fleet.start()
        self._trace_name = trace_name
        self._t0 = time.monotonic()
        for app in self.apps:
            self._queues[app] = deque()
            self._in_flight[app] = 0
            self._stats[app] = _AppServeStats()
            w = threading.Thread(target=self._worker, args=(app,),
                                 name=f"fleet-serve-{app}", daemon=True)
            w.start()
            self._workers.append(w)
        return {"mode": "real", "apps": self.apps, **self.boot}

    def submit(self, req: Request) -> str:
        qc = self.queue_cfg
        tracer = get_tracer()
        # (trace_id, root span_id) minted at admission so the queue_wait
        # span can hang off the request root the worker records later
        ids = (new_id(), new_id()) if tracer.enabled else None
        shed_reason = None
        with self._cond:
            if self._draining:
                return "shed"
            if req.app not in self._queues:
                raise KeyError(f"unknown app {req.app!r}; fleet serves "
                               f"{self.apps}")
            st = self._stats[req.app]
            st.arrivals += 1
            q = self._queues[req.app]
            if len(q) >= qc.depth:
                if qc.shed_policy == "drop-oldest" and q:
                    q.popleft()
                    st.count_shed("drop-oldest")
                    shed_reason = "drop-oldest"
                    q.append((time.monotonic(), req, ids))
                    self._cond.notify_all()
                    outcome = "queued"
                else:
                    st.count_shed("queue-full")
                    shed_reason = "queue-full"
                    outcome = "shed"
            else:
                q.append((time.monotonic(), req, ids))
                self._cond.notify_all()
                outcome = "queued"
            depth = len(q)
        # counters keep their own locks; update them outside _cond
        _m_requests(req.app, outcome)
        if shed_reason is not None:
            _m_sheds(req.app, shed_reason)
        _m_gauge("repro_queue_depth", "queued requests per app",
                 req.app, depth)
        return outcome

    def _worker(self, app: str) -> None:
        tracer = get_tracer()
        while True:
            with self._cond:
                while not self._queues[app] and not self._draining:
                    self._cond.wait(timeout=0.2)
                if not self._queues[app]:
                    if self._draining:
                        return
                    continue
                enq_t, req, ids = self._queues[app].popleft()
                self._in_flight[app] += 1
                gen = self._gen
                seed = self._seed
                self._seed += 1
            wait_ms = (time.monotonic() - enq_t) * 1e3
            # root span start = dequeue instant minus the measured wait,
            # so queue_wait and the dispatch subtree share one clock
            # even where monotonic() and perf_counter() differ
            t_deq_ms = now_ms()
            trace = None
            if ids is not None and tracer.enabled:
                tid, rid = ids
                tracer.add("queue_wait", trace_id=tid, parent_id=rid,
                           t_start_ms=t_deq_ms - wait_ms,
                           duration_ms=wait_ms, attrs={"app": app})
                trace = {"trace_id": tid, "parent_id": rid}
            st = self._stats[app]
            lp_cfg = (self.adaptive.observe_request(app, req.handler)
                      if self.adaptive is not None else None)
            try:
                m = self.fleet.dispatch(app, handler=req.handler,
                                        seed=seed, trace=trace,
                                        live_profile=lp_cfg)
            except Exception as exc:
                # classify the failure: a wedged handler or a
                # circuit-broken crash loop is *shed* (with a named
                # reason), anything else is a dispatch error
                from repro.pool.fleet import CrashLoopShed
                from repro.pool.forkserver import ForkServerTimeout
                shed_reason = None
                if isinstance(exc, ForkServerTimeout):
                    shed_reason = "timeout"
                elif isinstance(exc, CrashLoopShed):
                    shed_reason = "crash_loop"
                with self._cond:
                    if gen != self._gen:
                        continue  # drain already accounted this one
                    if shed_reason is not None:
                        st.count_shed(shed_reason)
                    else:
                        st.errors += 1
                    self._in_flight[app] -= 1
                    self._cond.notify_all()
                if shed_reason is not None:
                    _m_sheds(app, shed_reason)
                    _LOG.warning("dispatch-shed", app=app,
                                 reason=shed_reason, error=repr(exc))
                else:
                    _m_errors(app)
                    _LOG.warning("dispatch-failed", app=app,
                                 error=repr(exc))
                if trace is not None:
                    tracer.add("request", trace_id=tid, span_id=rid,
                               t_start_ms=t_deq_ms - wait_ms,
                               duration_ms=now_ms() - t_deq_ms + wait_ms,
                               attrs={"app": app, "error": repr(exc)})
                continue
            if self.adaptive is not None:
                # pops m["live_profile"] (when the child carried a
                # sampler) and folds it into the rolling live CCT
                self.adaptive.observe_exec(app, m)
            if trace is not None:
                tracer.add("request", trace_id=tid, span_id=rid,
                           t_start_ms=t_deq_ms - wait_ms,
                           duration_ms=now_ms() - t_deq_ms + wait_ms,
                           attrs={"app": app, "path": m["path"]})
            with self._cond:
                if gen != self._gen:
                    continue  # drain already accounted this one
                st.served += 1
                st.queue_waits_ms.append(wait_ms)
                st.init_ms.append(m["init_ms"])
                st.e2e_ms.append(wait_ms + m["e2e_cold_ms"])
                if m["path"] == "pool":
                    st.pool += 1
                else:
                    st.cold += 1
                if m.get("degraded"):
                    st.count_degrade(m["degraded"])
                self._in_flight[app] -= 1
                self._cond.notify_all()
            _m_served(app)
            _m_hist("repro_queue_wait_ms",
                    "wall time from enqueue to dispatch", app, wait_ms)
            _m_hist("repro_init_ms", "handler init latency",
                    app, m["init_ms"])
            _m_hist("repro_e2e_ms", "queue wait + end-to-end latency",
                    app, wait_ms + m["e2e_cold_ms"])

    def drain(self, timeout_s: Optional[float] = 30.0, *,
              flush: bool = True) -> None:
        """Stop admitting and wind the queues down.

        ``flush=True`` (SIGTERM semantics): queued requests are *not*
        run — they are counted as flushed in the summary; only in-flight
        dispatches finish.  ``flush=False`` (end-of-feed semantics): the
        workers keep serving until the queues are empty (or
        ``timeout_s`` expires, flushing whatever is left).
        """
        deadline = (time.monotonic() + timeout_s
                    if timeout_s is not None else None)

        def _remaining() -> Optional[float]:
            if deadline is None:
                return None
            return max(deadline - time.monotonic(), 0.0)

        if not flush:
            with self._cond:
                while any(self._queues.values()) \
                        or any(self._in_flight.values()):
                    rem = _remaining()
                    if rem == 0.0:
                        break
                    self._cond.wait(timeout=min(rem or 0.2, 0.2))
        flushed = 0
        with self._cond:
            self._draining = True
            for app, q in self._queues.items():
                self._stats[app].flushed += len(q)
                flushed += len(q)
                q.clear()
            self._cond.notify_all()
            while any(self._in_flight.values()):
                rem = _remaining()
                if rem == 0.0:
                    break
                self._cond.wait(timeout=min(rem or 0.2, 0.2))
        for w in self._workers:
            w.join(timeout=5.0)
        # join(timeout) can return with the worker still alive (a hung
        # dispatch): its in-flight request would be counted neither as
        # served nor flushed.  Account it as abandoned NOW, and bump
        # the generation so the worker — if it ever returns — skips its
        # own counting instead of double-accounting the same request.
        abandoned: dict[str, int] = {}
        if any(w.is_alive() for w in self._workers):
            with self._cond:
                self._gen += 1
                for app, n in self._in_flight.items():
                    if n > 0:
                        self._stats[app].abandoned += n
                        abandoned[app] = n
                        self._in_flight[app] = 0
        _m_flushed(flushed)
        for app, n in abandoned.items():
            _m_abandoned(app, n)
            _LOG.warning("drain-abandoned", app=app, abandoned=n)
        if flushed:
            _LOG.info("drain-flushed", flushed=flushed)

    def finish(self, end_t: Optional[float] = None) -> dict:
        per_app = []
        e2e_all: list[float] = []
        waits_all: list[float] = []
        tot = _AppServeStats()
        extra: dict = {}
        if self.adaptive is not None:
            self.adaptive.flush()
            extra["adaptive"] = self.adaptive.summary()
        with self._cond:
            # a dispatch still blocked at finish() time (finish without
            # drain, or one that slipped in since) is lost traffic:
            # account it as abandoned — and advance the generation so
            # the late worker cannot also count it as served/errored,
            # which would break conservation by double-counting
            if any(n > 0 for n in self._in_flight.values()):
                self._gen += 1
                for app, n in self._in_flight.items():
                    if n > 0:
                        self._stats[app].abandoned += n
                        self._in_flight[app] = 0
            # snapshot everything under the lock: an abandoned drain
            # leaves workers alive, still appending to these lists
            stats = {app: st.copy() for app, st in self._stats.items()}
        for app in self.apps:
            st = stats.get(app) or _AppServeStats()
            e2e_all.extend(st.e2e_ms)
            waits_all.extend(st.queue_waits_ms)
            tot.arrivals += st.arrivals
            tot.served += st.served
            tot.sheds += st.sheds
            tot.flushed += st.flushed
            tot.pool += st.pool
            tot.cold += st.cold
            tot.errors += st.errors
            tot.abandoned += st.abandoned
            tot.degraded += st.degraded
            _merge_reasons(tot.shed_reasons, st.shed_reasons)
            _merge_reasons(tot.degrade_reasons, st.degrade_reasons)
            per_app.append({
                "app": app,
                "requests": st.arrivals,
                "pool_starts": st.pool,
                "cold_starts": st.cold,
                "errors": st.errors,
                "abandoned": st.abandoned,
                "degraded": st.degraded,
                "degrade_reasons": dict(st.degrade_reasons),
                # arrivals denominator, like every other producer
                "cold_ratio": round(st.cold / max(st.arrivals, 1), 4),
                "p50_ms": round(percentile_ms(st.e2e_ms, 0.50), 2)
                if st.e2e_ms else 0.0,
                "p99_ms": round(percentile_ms(st.e2e_ms, 0.99), 2)
                if st.e2e_ms else 0.0,
                "sheds": st.sheds,
                "shed_reasons": dict(st.shed_reasons),
                "flushed": st.flushed,
                "queue_wait_p99_ms":
                    round(percentile_ms(st.queue_waits_ms, 0.99), 2)
                    if st.queue_waits_ms else 0.0,
            })
        from repro.pool.fleet import make_fleet_summary_payload
        return make_fleet_summary_payload(
            source="serve-real",
            requests=tot.arrivals,
            served=tot.served,
            cold_starts=tot.cold,
            p50_ms=round(percentile_ms(e2e_all, 0.50), 2)
            if e2e_all else 0.0,
            p99_ms=round(percentile_ms(e2e_all, 0.99), 2)
            if e2e_all else 0.0,
            sheds=tot.sheds,
            shed_reasons=dict(tot.shed_reasons),
            flushed=tot.flushed,
            queue_wait_p50_ms=round(percentile_ms(waits_all, 0.50), 2)
            if waits_all else 0.0,
            queue_wait_p99_ms=round(percentile_ms(waits_all, 0.99), 2)
            if waits_all else 0.0,
            per_app=per_app,
            policy="zygote-fleet",
            trace=getattr(self, "_trace_name", "live"),
            budget_mb=self.fleet.budget_mb,
            duration_s=round(time.monotonic()
                             - getattr(self, "_t0", time.monotonic()),
                             3),
            pool_starts=tot.pool,
            # dispatch failures (crashed handler, dead zygote + failed
            # cold fallback): neither served nor shed — without this
            # field the conservation invariant would silently miscount
            # lost traffic (requests == served + sheds + flushed
            # + errors + abandoned)
            errors=tot.errors,
            abandoned=tot.abandoned,
            degraded=tot.degraded,
            degrade_reasons=dict(tot.degrade_reasons),
            memory_gb_s=None,
            rewarm_ticks=0,
            queue=self.queue_cfg.to_dict(),
            zygotes=sorted(self.fleet.servers),
            skipped=list(self.fleet.skipped),
            used_mb=round(self.fleet.used_mb(), 1),
            # two-tier fleet: shared base modules, RSS and hot-swap
            # count ({} when the fleet runs one zygote per app)
            **self.fleet._base_info(),
            **extra,
        )

    def snapshot(self) -> dict:
        # copy every mutable read under the queue lock — the worker
        # threads mutate _stats/_queues/_in_flight concurrently
        with self._cond:
            stats = {app: st.copy() for app, st in self._stats.items()}
            queued = {app: len(q) for app, q in self._queues.items()}
            in_flight = dict(self._in_flight)
        reasons: dict = {}
        for st in stats.values():
            _merge_reasons(reasons, st.shed_reasons)
        snap = {
            "requests": sum(s.arrivals for s in stats.values()),
            "served": sum(s.served for s in stats.values()),
            "cold_starts": sum(s.cold for s in stats.values()),
            "sheds": sum(s.sheds for s in stats.values()),
            "shed_reasons": reasons,
            "errors": sum(s.errors for s in stats.values()),
            "abandoned": sum(s.abandoned for s in stats.values()),
            "degraded": sum(s.degraded for s in stats.values()),
            "queued": sum(queued.values()),
            "in_flight": sum(in_flight.values()),
            "per_app": {
                app: {"arrivals": st.arrivals, "served": st.served,
                      "sheds": st.sheds, "errors": st.errors,
                      "abandoned": st.abandoned,
                      "degraded": st.degraded,
                      "pool": st.pool, "cold": st.cold,
                      "queued": queued.get(app, 0),
                      "in_flight": in_flight.get(app, 0)}
                for app, st in sorted(stats.items())
            },
        }
        breakers = getattr(self.fleet, "breakers", None)
        if breakers:
            open_apps = sorted(a for a, br in breakers.items()
                               if br.open)
            if open_apps:
                snap["breakers_open"] = open_apps
        if self.fleet.shared_base:
            snap["base_alive"] = (self.fleet.base is not None
                                  and self.fleet.base.alive)
            snap["base_swaps"] = self.fleet.base_swaps
        return snap

    def latency_samples(self, cap: int = 50_000) -> list[float]:
        """Raw end-to-end samples (capped) for cluster-level percentile
        merging; see :meth:`SimFleetBackend.latency_samples`."""
        out: list[float] = []
        with self._cond:
            for st in self._stats.values():
                take = cap - len(out)
                if take <= 0:
                    break
                out.extend(st.e2e_ms[:take])
        return out

    def rewarm(self) -> dict:
        if not self.reports_dir:
            return {}
        return self.fleet.rewarm_from_dir(self.reports_dir)

    # ------------------------------------------------ warm-state handoff
    def export_app(self, app: str) -> dict:
        """Departing-owner side of a planned migration: ship the app's
        in-memory report artifact so the new owner's prewarm boots a
        zygote with the *proven* hot set, not a bare one."""
        if app not in self.fleet.app_dirs:
            raise KeyError(f"export for unknown app {app!r}")
        out: dict = {"app": app}
        rep = self.fleet.reports.get(app)
        if rep is not None:
            from repro.api.artifacts import ReportArtifact
            try:
                out["report"] = ReportArtifact(rep).to_payload()
            except Exception:
                pass  # a bad artifact ships nothing, not a crash
        return out

    def prewarm_app(self, app: str, report=None,
                    profile=None) -> dict:
        """New-owner side: boot the app's zygote (adopting the shipped
        report's hot set) before placement flips here.  ``profile`` is
        sim-only state and ignored on the real tier."""
        return self.fleet.prewarm_app(app, report=report)

    def collect_queued(self) -> list[dict]:
        """Planned-drain flush: pop every still-queued request, count
        it flushed locally (this node admitted it and must account for
        it), and return it for re-admission at the new owners."""
        popped: list = []
        with self._cond:
            for app, q in self._queues.items():
                while q:
                    popped.append(q.popleft())
                    self._stats[app].flushed += 1
            self._cond.notify_all()
        if popped:
            _m_flushed(len(popped))
        return [{"app": req.app, "handler": req.handler}
                for _enq_t, req, _ids in popped]

    def stop(self) -> None:
        self.fleet.stop()


# ---------------------------------------------------------------------------
# The daemon shell
# ---------------------------------------------------------------------------

class FleetDaemon:
    """Lifecycle shell around a serve backend.

    ``start() -> submit()*/run_trace()/run_stdin() -> shutdown()``.
    ``request_shutdown`` is async-signal-safe (it only sets an event):
    install it as the SIGTERM/SIGINT handler and the serve loop drains
    gracefully — in-flight invocations finish, queued ones are flushed
    into the emitted ``fleet_summary`` artifact.
    """

    MAX_REWARM_ERRORS = 100  # rewarm_errors ring size

    def __init__(self, backend, *, rewarm_interval_s: float = 0.0,
                 rewarm_fn: Optional[Callable[[], dict]] = None,
                 summary_path: Optional[str] = None,
                 drain_timeout_s: Optional[float] = 30.0,
                 fault_hook=None) -> None:
        self.backend = backend
        self.rewarm_interval_s = rewarm_interval_s
        # default rewarm action: whatever the backend's tick does
        self.rewarm_fn = rewarm_fn or backend.rewarm
        self.summary_path = summary_path
        self.drain_timeout_s = drain_timeout_s
        # chaos hook (repro.pool.chaos): exercises the rewarm-tick
        # failure path; None leaves the daemon untouched
        self.fault_hook = fault_hook
        self.rewarm_ticks = 0
        self.rewarm_errors: list[str] = []
        self._stop_evt = threading.Event()
        self._interrupted = False
        self._rewarm_thread: Optional[threading.Thread] = None
        self._finished: Optional[dict] = None
        self._extra_meta: dict = {}
        self._lock = threading.Lock()

    # ----------------------------------------------------------- lifecycle
    def start(self, trace_name: str = "live") -> dict:
        boot = self.backend.start(trace_name)
        _LOG.info("started", mode=boot.get("mode", "?"),
                  apps=",".join(boot.get("apps", [])),
                  rewarm_interval_s=self.rewarm_interval_s)
        if self.rewarm_interval_s > 0:
            self._rewarm_thread = threading.Thread(
                target=self._rewarm_loop, name="fleet-rewarm",
                daemon=True)
            self._rewarm_thread.start()
        return boot

    def __enter__(self) -> "FleetDaemon":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def request_shutdown(self, *_args) -> None:
        """Signal-handler entry point: flag the drain, return at once.
        A shutdown requested this way *flushes* queued requests (they
        land in the summary as ``flushed``, unserved) — only in-flight
        invocations finish."""
        self._interrupted = True
        self._stop_evt.set()

    @property
    def draining(self) -> bool:
        return self._stop_evt.is_set()

    def shutdown(self, *, end_t: Optional[float] = None,
                 flush: Optional[bool] = None) -> dict:
        """Graceful drain: stop admitting, finish in-flight work, then
        emit the summary artifact.  ``flush`` defaults to True when the
        shutdown came from a signal (queued work is flushed) and False
        when the feed simply ended (queued work is served first).
        Idempotent."""
        if flush is None:
            flush = self._interrupted
        self._stop_evt.set()
        with self._lock:
            if self._finished is not None:
                return self._finished
            if self._rewarm_thread is not None:
                self._rewarm_thread.join(timeout=5.0)
            self.backend.drain(timeout_s=self.drain_timeout_s,
                               flush=flush)
            payload = self.backend.finish(end_t)
            payload["rewarm_ticks"] = self.rewarm_ticks
            # surface rewarm failures swallowed into the ring buffer:
            # without this the summary (and the serve exit status built
            # on it) reported a clean run even when every tick errored
            payload["rewarm_errors"] = len(self.rewarm_errors)
            if self._extra_meta:  # must land before the artifact save
                payload.setdefault("meta", {}).update(self._extra_meta)
            self.backend.stop()
            if self.summary_path:
                from repro.api.artifacts import save_fleet_summary
                save_fleet_summary(payload, self.summary_path)
            self._finished = payload
            _LOG.info("drained", requests=payload.get("requests", 0),
                      served=payload.get("served", 0),
                      sheds=payload.get("sheds", 0),
                      flushed=payload.get("flushed", 0))
        return payload

    # ------------------------------------------------------------- serving
    def submit(self, req: Request) -> str:
        if self._stop_evt.is_set():
            return "draining"
        return self.backend.submit(req)

    def run_trace(self, trace: Trace, *, pace: float = 0.0,
                  end_t: Optional[float] = None) -> dict:
        """Feed a whole trace through the daemon, then drain.

        ``pace`` scales arrival gaps into real sleeps (0 = as fast as
        possible; 1 = real time).  With the sim backend, request times
        are the trace's own, so the replay is deterministic regardless
        of pace.
        """
        outcomes = {"served": 0, "queued": 0, "shed": 0, "draining": 0}
        prev_t = 0.0
        for req in trace:
            if self._stop_evt.is_set():
                break
            if pace > 0 and req.t > prev_t:
                self._stop_evt.wait((req.t - prev_t) * pace)
            prev_t = req.t
            outcomes[self.submit(req)] += 1
        self._extra_meta["admission"] = outcomes
        return self.shutdown(
            end_t=trace.duration_s if end_t is None else end_t)

    def run_stdin(self, in_stream: Optional[TextIO] = None,
                  out_stream: Optional[TextIO] = None,
                  clock: Callable[[], float] = time.monotonic) -> dict:
        """Serve a JSONL feed until EOF / ``shutdown`` / SIGTERM.

        Events: ``{"app": ..., "handler": ...}`` submits an invocation
        (its arrival time is the wall clock); ``{"cmd": "stats"}``
        prints a live snapshot; ``{"cmd": "rewarm"}`` forces a rewarm
        tick; ``{"cmd": "drain"}`` / ``{"cmd": "shutdown"}`` ends the
        loop.  Every event is answered with one JSON line.
        """
        fin = in_stream if in_stream is not None else sys.stdin
        fout = out_stream if out_stream is not None else sys.stdout
        t0 = clock()

        def reply(obj: dict) -> None:
            fout.write(json.dumps(obj) + "\n")
            fout.flush()

        # A blocking readline would swallow a SIGTERM for as long as the
        # feed stays silent (and select() on a *buffered* text stream
        # misses lines already pulled into the Python-side buffer), so a
        # reader thread feeds a queue the loop polls every 200 ms.
        lines: queue.Queue = queue.Queue()

        def _reader() -> None:
            try:
                for raw in fin:
                    lines.put(raw)
            except (OSError, ValueError):
                pass
            lines.put(None)  # EOF sentinel

        threading.Thread(target=_reader, name="fleet-stdin",
                         daemon=True).start()

        while not self._stop_evt.is_set():
            try:
                line = lines.get(timeout=0.2)
            except queue.Empty:
                continue
            if line is None:
                break  # EOF
            line = line.strip()
            if not line:
                continue
            try:
                evt = json.loads(line)
            except ValueError:
                reply({"ok": False, "error": "bad json"})
                continue
            cmd = evt.get("cmd")
            if cmd == "stats":
                reply({"ok": True, "stats": self.backend.snapshot(),
                       "rewarm_ticks": self.rewarm_ticks,
                       "metrics": _reg().snapshot()})
            elif cmd == "rewarm":
                reply({"ok": True, "rewarm": self.rewarm_now()})
            elif cmd in ("drain", "shutdown"):
                reply({"ok": True, "event": "draining"})
                break
            elif cmd is not None:
                reply({"ok": False, "error": f"unknown cmd {cmd!r}"})
            elif "app" not in evt:
                reply({"ok": False, "error": "need 'app' or 'cmd'"})
            else:
                req = Request(t=clock() - t0, app=evt["app"],
                              handler=evt.get("handler"))
                try:
                    outcome = self.submit(req)
                except KeyError as exc:
                    reply({"ok": False, "error": str(exc)})
                    continue
                # "draining": a shutdown raced the read — the request
                # was never admitted, so the ack must not claim success
                reply({"ok": outcome not in ("shed", "draining"),
                       "outcome": outcome})
        payload = self.shutdown(end_t=clock() - t0)
        reply({"ok": True, "event": "summary", "summary": payload})
        return payload

    # -------------------------------------------------------------- rewarm
    def rewarm_now(self) -> dict:
        """One rewarm tick (also what the timer thread calls): re-load
        deployed report artifacts and re-preload warm state.  Failures
        — a whole-tick exception (e.g. a corrupt/partially-written
        report artifact) or a per-app ``{"ok": False}`` result — are
        counted in ``repro_rewarm_failures_total{app}`` and logged
        structured, never raised: in-flight work is untouched and the
        timer keeps ticking."""
        try:
            if self.fault_hook is not None:
                # chaos site "rewarm": injected tick failures land
                # inside the try, exercising exactly this recovery path
                self.fault_hook("rewarm", app="_tick")
            out = self.rewarm_fn()
            self.rewarm_ticks += 1
            _reg().counter("repro_rewarm_ticks_total",
                           "successful rewarm timer ticks").inc()
            _LOG.debug("rewarm-tick", ticks=self.rewarm_ticks)
            out = out if isinstance(out, dict) else {"ok": True}
        except Exception as exc:
            self._record_rewarm_error("_tick", repr(exc))
            return {"ok": False, "error": repr(exc)}
        # per-app failures ride inside a successful tick's result
        # (rewarm_from_dir never raises); surface them the same way
        for app, res in out.items():
            if isinstance(res, dict) and res.get("ok") is False:
                self._record_rewarm_error(
                    app, str(res.get("error", "rewarm failed")))
        return out

    def _record_rewarm_error(self, app: str, error: str) -> None:
        # bounded: a flapping app on a fast timer must not grow this
        # list (and the daemon's memory) without limit
        if len(self.rewarm_errors) >= self.MAX_REWARM_ERRORS:
            del self.rewarm_errors[
                :len(self.rewarm_errors) - self.MAX_REWARM_ERRORS + 1]
        self.rewarm_errors.append(f"{app}: {error}")
        _m_rewarm_failure(app)
        _LOG.warning("rewarm-failed", app=app, error=error[:500])

    def _rewarm_loop(self) -> None:
        # rewarm_now never raises, so one bad tick (corrupt artifact,
        # crashed zygote, chaos injection) cannot kill the timer
        # thread and silently stop all future rewarms
        while not self._stop_evt.wait(self.rewarm_interval_s):
            self.rewarm_now()
