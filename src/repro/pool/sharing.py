"""Cross-app page sharing: the fleet-wide shared base hot set.

PR 2's fleet boots one zygote *per app*, so every zygote re-imports the
packages the whole deployment has in common (numpy-heavy fakelibs,
stdlib-adjacent deps, the runner itself) and the memory budget pays for
those pages once per app.  SLIMSTART's 1.51X memory-reduction axis says
those pages should exist once: this module computes the **shared hot
set** — the modules hot (per their ``optimization_report`` artifacts)
for enough of the deployed apps to earn a slot in a single
:class:`~repro.pool.forkserver.BaseZygote` that every per-app zygote is
forked from.  Forked children then share the base's pages
copy-on-write, and each app only layers its private *delta* (hot
modules the base does not carry) on top.

The result is itself a schema-versioned artifact (kind
``shared_hot_set``, see :class:`repro.api.artifacts.SharedHotSetArtifact`)
so the serve daemon's rewarm tick can recompute it from freshly
deployed reports and hot-swap the base without a restart.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence

from repro.pool.policies import hot_set_from_report


def _covers(module: str, hot_set: Sequence[str]) -> bool:
    """True when importing ``hot_set`` already loads ``module`` (the
    module itself or a package prefix of it is in the set)."""
    parts = module.split(".")
    prefixes = {".".join(parts[:i]) for i in range(1, len(parts) + 1)}
    return any(m in prefixes for m in hot_set)


def intersect_hot_sets(hot_sets: Mapping[str, Sequence[str]], *,
                       min_members: int = 2,
                       prefixes: bool = True) -> list[str]:
    """Names hot for at least ``min_members`` of the given members.

    With ``prefixes=True`` (module semantics): ``pkg`` in one app's set
    covers ``pkg.sub`` in another's, and the *widest* common prefix
    wins (pre-importing ``pkg`` gives both apps their pages).  Pass
    ``prefixes=False`` for flat namespaces where a dot is not a
    containment relation — e.g. the Level-B
    :class:`~repro.serving.engine.EnginePool` component names, where
    ``expert.1`` and ``expert.2`` share no loadable parent.
    """
    if not hot_sets:
        return []
    min_members = max(1, min_members)
    counts: dict[str, int] = {}
    exact: set[str] = set()
    for hot in hot_sets.values():
        seen = set()
        for mod in hot:
            mod = mod.strip()
            if not mod:
                continue
            exact.add(mod)
            if prefixes:
                # credit the name and every package prefix, once per
                # member
                parts = mod.split(".")
                for i in range(1, len(parts) + 1):
                    seen.add(".".join(parts[:i]))
            else:
                seen.add(mod)
        for name in seen:
            counts[name] = counts.get(name, 0) + 1
    if not prefixes:
        return sorted(m for m, n in counts.items() if n >= min_members)

    def qualifies(name: str) -> bool:
        if counts[name] < min_members:
            return False
        if name in exact:
            return True
        # a synthetic prefix (no member names it as-is) earns a slot
        # only when it *aggregates* demand — more members than any one
        # of its submodules alone — otherwise pre-importing the whole
        # package over-serves a single submodule's hot entry
        best_child = max((counts[m] for m in exact
                          if m != name and _covers(m, [name])),
                         default=0)
        return counts[name] > best_child

    shared = [m for m in counts if qualifies(m)]
    # keep maximal prefixes only (importing pkg imports pkg.sub)
    shared.sort(key=lambda p: (p.count("."), p))
    keep: list[str] = []
    for mod in shared:
        if not _covers(mod, keep):
            keep.append(mod)
    return keep


@dataclass
class SharedHotSet:
    """One fleet's two-tier pre-import plan.

    ``modules`` boot the shared :class:`BaseZygote`; each app's
    ``per_app_delta`` is what its zygote layers on top after forking
    from the base.  ``counts`` records how many member apps wanted each
    shared module — provenance for the rewarm tick's swap decision.
    """

    modules: list[str]
    apps: list[str]
    per_app_delta: dict[str, list[str]]
    min_apps: int = 2
    counts: dict[str, int] = field(default_factory=dict)

    def delta(self, app: str, hot: Optional[Sequence[str]] = None
              ) -> list[str]:
        """The app's private preload: its hot set minus what the base
        already imports."""
        if app in self.per_app_delta:
            return list(self.per_app_delta[app])
        return [m for m in (hot or []) if not _covers(m, self.modules)]

    def to_payload(self) -> dict:
        return {"modules": list(self.modules), "apps": list(self.apps),
                "per_app_delta": {a: list(d)
                                  for a, d in self.per_app_delta.items()},
                "min_apps": self.min_apps, "counts": dict(self.counts)}

    @classmethod
    def from_payload(cls, payload: dict) -> "SharedHotSet":
        return cls(modules=list(payload["modules"]),
                   apps=list(payload["apps"]),
                   per_app_delta={a: list(d) for a, d in
                                  payload["per_app_delta"].items()},
                   min_apps=int(payload.get("min_apps", 2)),
                   counts=dict(payload.get("counts", {})))


def compute_shared_hot_set(reports: Mapping[str, object], *,
                           min_apps: int = 2,
                           min_fraction: Optional[float] = None,
                           ) -> SharedHotSet:
    """Intersect deployed report artifacts into the two-tier plan.

    ``reports`` maps app name -> anything :func:`repro.api.as_report`
    accepts (the report object or a saved artifact path).  A module
    joins the shared base when it is hot for at least ``min_apps`` apps
    (or ``ceil(min_fraction * n_apps)`` when ``min_fraction`` is given
    — the knob for large fleets where "2 of 400 apps" is not sharing).
    Strict intersection across heterogeneous deployments is usually
    empty; the threshold is what makes the base earn its pages.
    """
    from repro.api.artifacts import as_report
    hot_sets = {app: hot_set_from_report(as_report(rep))
                for app, rep in reports.items()}
    n = len(hot_sets)
    threshold = min_apps
    if min_fraction is not None:
        threshold = max(1, math.ceil(min_fraction * n))
    shared = intersect_hot_sets(hot_sets, min_members=threshold)
    def wants(hot: Sequence[str], mod: str) -> bool:
        # the app's hot set names the shared module, something under
        # it, or a package above it — any of which the base satisfies
        return _covers(mod, hot) or any(_covers(m, [mod]) for m in hot)

    counts: dict[str, int] = {}
    for mod in shared:
        counts[mod] = sum(1 for hot in hot_sets.values()
                          if wants(hot, mod))
    deltas = {app: [m for m in hot if not _covers(m, shared)]
              for app, hot in hot_sets.items()}
    return SharedHotSet(modules=shared, apps=sorted(hot_sets),
                        per_app_delta=deltas, min_apps=threshold,
                        counts=counts)


def shared_search_paths(app_dirs: Mapping[str, str]) -> list[str]:
    """``sys.path`` entries letting the base zygote resolve the shared
    modules: every app's vendored ``libs/`` directory, deduplicated in
    app order.  Apps vendor identical copies (generated from one
    ``libs_src``), so first-on-path wins and forked children find the
    already-imported module in ``sys.modules`` — the CoW share."""
    out: list[str] = []
    for app_dir in app_dirs.values():
        libs = os.path.join(os.path.abspath(app_dir), "libs")
        if os.path.isdir(libs) and libs not in out:
            out.append(libs)
    return out
