"""Keep-alive and pool-sizing policies for the warm-instance pool.

A policy answers three questions the fleet (simulated or real) asks:

* ``prewarm(app)``       — how many instances to keep provisioned as a
  floor, even before any traffic arrives (they pay memory from t=0 but
  turn the first requests warm);
* ``keep_alive_s(app)``  — how long an *idle* warm instance survives
  before the fleet reclaims it;
* ``preload_modules(app)`` — which library modules the fork-server
  zygote should pre-import so forked instances share them copy-on-write
  (only the profile-guided policy has a real answer; the others return
  an empty hot set and fall back to whole-process warm reuse).

``observe_arrival`` lets adaptive policies (histogram) learn online from
the request stream; stateless policies ignore it.

Policies implemented:

* :class:`FixedSizePolicy`     — classic provisioned concurrency: N
  instances, never reclaimed.
* :class:`IdleTimeoutPolicy`   — the industry default (e.g. a 10-minute
  fixed keep-alive after the last request).
* :class:`HistogramPolicy`     — "Serverless in the Wild"-style: learn
  the inter-arrival-time distribution per app and keep instances alive
  to a percentile of it, clamped to a budget.
* :class:`ProfileGuidedPolicy` — SLIMSTART's contribution: sized from
  the :class:`~repro.core.profiler.report.OptimizationReport` — the
  zygote pre-imports exactly the measured hot set (packages with
  runtime samples, minus defer targets), and keep-alive scales with the
  measured init cost so expensive-to-build instances are retained
  longer than cheap ones.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.profiler.report import OptimizationReport


def hot_set_from_report(report: OptimizationReport) -> list[str]:
    """The zygote pre-import list: top-level packages that the profile
    shows are actually exercised at runtime (i.e. not defer targets and
    not below the init-share floor).

    Only maximal prefixes are returned — pre-importing ``fakelib_igraph``
    already executes ``fakelib_igraph.core`` when ``__init__`` pulls it
    in, and the import system resolves submodules from ``sys.modules``.
    """
    deferred = set(report.defer_targets)

    def under_deferred(pkg: str) -> bool:
        parts = pkg.split(".")
        return any(".".join(parts[:i]) in deferred
                   for i in range(1, len(parts) + 1))

    hot = [s.name for s in report.stats
           if s.runtime_samples > 0 and not under_deferred(s.name)]
    # keep maximal prefixes only
    hot_sorted = sorted(set(hot), key=lambda p: p.count("."))
    keep: list[str] = []
    for pkg in hot_sorted:
        parts = pkg.split(".")
        if not any(".".join(parts[:i]) in keep
                   for i in range(1, len(parts))):
            keep.append(pkg)
    return keep


class KeepAlivePolicy:
    """Interface; subclasses override the decisions they care about."""

    name = "base"

    def prewarm(self, app: str) -> int:
        return 0

    def keep_alive_s(self, app: str) -> float:
        return 0.0

    def preload_modules(self, app: str) -> list[str]:
        return []

    def observe_arrival(self, app: str, t: float) -> None:
        pass

    def observe_rate(self, app: str, rate_per_s: float) -> None:
        """Fleet feedback: the measured recent arrival rate.  Policies
        that size pools from a rate (profile-guided Little's law) learn
        from it; the rest ignore it."""


@dataclass
class FixedSizePolicy(KeepAlivePolicy):
    """Provisioned concurrency: ``size`` instances, never reclaimed."""

    size: int = 2
    name: str = "fixed"

    def prewarm(self, app: str) -> int:
        return self.size

    def keep_alive_s(self, app: str) -> float:
        return math.inf


@dataclass
class IdleTimeoutPolicy(KeepAlivePolicy):
    """Fixed idle keep-alive after the last request (industry default)."""

    timeout_s: float = 600.0
    name: str = "idle-timeout"

    def keep_alive_s(self, app: str) -> float:
        return self.timeout_s


@dataclass
class HistogramPolicy(KeepAlivePolicy):
    """Learn per-app inter-arrival times; keep alive to a percentile.

    Until ``min_samples`` arrivals are seen the policy falls back to
    ``default_s`` (cold-start-averse default).  The learned value is
    clamped to ``[floor_s, cap_s]`` so one huge gap cannot pin memory
    forever.
    """

    percentile: float = 0.95
    default_s: float = 600.0
    floor_s: float = 10.0
    cap_s: float = 3600.0
    min_samples: int = 8
    name: str = "histogram"
    _last_t: dict[str, float] = field(default_factory=dict, repr=False)
    _iats: dict[str, list[float]] = field(default_factory=dict, repr=False)

    def observe_arrival(self, app: str, t: float) -> None:
        last = self._last_t.get(app)
        if last is not None and t >= last:
            self._iats.setdefault(app, []).append(t - last)
        self._last_t[app] = t

    def keep_alive_s(self, app: str) -> float:
        iats = self._iats.get(app, [])
        if len(iats) < self.min_samples:
            return self.default_s
        ys = sorted(iats)
        idx = min(len(ys) - 1, int(self.percentile * (len(ys) - 1)))
        return min(self.cap_s, max(self.floor_s, ys[idx]))


@dataclass
class ProfileGuidedPolicy(KeepAlivePolicy):
    """Pool sizing and pre-import set derived from SLIMSTART profiles.

    * ``preload_modules`` — the measured hot set from the report, so
      zygote forks share exactly the libraries the workload uses.
    * ``prewarm`` — Little's-law floor ``ceil(rate * service_s)`` from
      the expected request rate and measured end-to-end time: enough
      instances that the steady-state workload never queues cold.  The
      rate starts at ``rate_hint_per_s`` and tracks the fleet's measured
      arrival rate via ``observe_rate`` (EWMA), so a traffic ramp raises
      the floor before requests start missing.
    * ``keep_alive_s`` — init cost amortization: an instance is kept
      ``amortize`` times its measured init cost (clamped), so apps with
      2 s inits are retained far longer than 20 ms ones instead of a
      one-size-fits-all timeout.
    """

    reports: dict[str, OptimizationReport] = field(default_factory=dict)
    rate_hint_per_s: float = 1.0
    amortize: float = 400.0
    floor_s: float = 30.0
    cap_s: float = 3600.0
    max_prewarm: int = 8
    rate_ewma: float = 0.3
    name: str = "profile-guided"
    _rates: dict[str, float] = field(default_factory=dict, repr=False)

    def add_report(self, report: OptimizationReport) -> None:
        self.reports[report.application] = report

    def observe_rate(self, app: str, rate_per_s: float) -> None:
        prev = self._rates.get(app)
        if prev is None or not math.isfinite(prev):
            self._rates[app] = max(rate_per_s, 0.0)
        else:
            self._rates[app] = ((1.0 - self.rate_ewma) * prev
                                + self.rate_ewma * max(rate_per_s, 0.0))

    def expected_rate_per_s(self, app: str) -> float:
        return self._rates.get(app, self.rate_hint_per_s)

    def prewarm(self, app: str) -> int:
        rep = self.reports.get(app)
        if rep is None:
            return 0
        n = max(1, math.ceil(self.expected_rate_per_s(app) * rep.e2e_s))
        return max(0, min(self.max_prewarm, n))  # never exceed the budget

    def keep_alive_s(self, app: str) -> float:
        rep = self.reports.get(app)
        if rep is None:
            return self.floor_s
        # after deferral only the hot set is rebuilt on a cold start
        hot_init_s = max(rep.total_init_s
                         - sum(s.init_s for s in rep.stats
                               if s.name in set(rep.defer_targets)),
                         0.0)
        return min(self.cap_s, max(self.floor_s, self.amortize * hot_init_s))

    def preload_modules(self, app: str) -> list[str]:
        rep = self.reports.get(app)
        return hot_set_from_report(rep) if rep is not None else []


def default_policies(reports: Optional[dict[str, OptimizationReport]] = None,
                     rate_hint_per_s: float = 1.0) -> list[KeepAlivePolicy]:
    """The benchmark's standard policy panel."""
    panel: list[KeepAlivePolicy] = [
        FixedSizePolicy(size=2),
        IdleTimeoutPolicy(timeout_s=600.0),
        HistogramPolicy(),
    ]
    pg = ProfileGuidedPolicy(rate_hint_per_s=rate_hint_per_s)
    for rep in (reports or {}).values():
        pg.add_report(rep)
    panel.append(pg)
    return panel
