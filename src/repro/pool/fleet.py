"""Multi-app zygote fleet manager under a shared memory budget.

PR 1's pieces are single-app: one :class:`~repro.pool.forkserver.ForkServer`
per process, one :class:`~repro.pool.simulator.FleetSimulator` per
profile.  SLIMSTART's profile-guided optimization only pays off
fleet-wide when *many* apps contend for one memory budget — the regime
FaaSLight and HotSwap measure — so this module adds the arbiter:

:class:`FleetManager` (simulation)
    Replays a multi-app :class:`~repro.pool.trace.Trace` (e.g. an
    Azure-style trace from :func:`~repro.pool.trace.azure_synthetic_rows`)
    against one :class:`~repro.pool.policies.KeepAlivePolicy` shared by
    every app, charging warm instances, prewarmed floors and resident
    zygotes against ``budget_mb``.  Decisions:

    * **prewarm** — the policy's per-app floor (profile-guided: Little's
      law ``ceil(rate * service_s)``, with the rate learned online from
      the arrival stream via ``policy.observe_rate``) is maintained in
      priority order whenever budget allows, so the app about to miss
      gets instances before traffic lands on it cold;
    * **evict** — when retention exceeds the budget, the idle instance
      (then zygote) of the app whose warm state *amortizes worst* —
      lowest ``rate * init_saved_ms / rss_mb`` — is reclaimed first;
    * **zygote residency** — apps whose policy pre-imports a hot set
      (``policy.preload_modules(app)``) keep one zygote resident while
      it fits; instance creation for those apps is a cheap fork
      (``warm_init_ms``) counted as a *pool start*, not a cold start.

    Demand-driven instances always spawn (serving beats retention,
    exactly like Lambda) *unless* a :class:`QueueConfig` bounds them:
    with queueing enabled, demand spawns stop at
    ``max_concurrency`` instances per app, excess requests wait in a
    bounded FIFO (their queue wait lands in the reported latency), and
    arrivals past ``depth`` are **shed** per the configured policy —
    the backpressure regime a long-running daemon needs instead of
    unbounded spawns.

    ``replay(trace)`` is one-shot; the long-running daemon
    (:mod:`repro.pool.daemon`) drives the same machinery incrementally
    through ``begin() -> offer(request)* -> finish(end_t)``.

:class:`ZygoteFleet` (real processes)
    The same arbitration over real fork-servers: one zygote per app,
    booted best-amortizing-first while measured zygote RSS fits the
    budget; ``dispatch`` routes a request to the app's zygote (fork) and
    falls back to a fresh-process cold start when the app has no
    resident zygote or its zygote died; ``rewarm(report)`` is the
    :class:`~repro.core.adaptive.controller.SlimStartController`
    ``rewarm_fn`` hook — it re-preloads (and, after a crash, reboots)
    the zygote of the re-profiled app.
"""

from __future__ import annotations

import math
import os
import statistics
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core.profiler.report import OptimizationReport
from repro.pool.forkserver import (
    BaseZygote,
    ForkServer,
    ForkServerBackoff,
    ForkServerError,
    ForkServerTimeout,
)
from repro.pool.policies import KeepAlivePolicy, hot_set_from_report
from repro.pool.sharing import (
    SharedHotSet,
    compute_shared_hot_set,
    shared_search_paths,
)
from repro.pool.simulator import (
    AppProfile,
    FleetReport,
    PercentilePool,
    percentile_ms,
)
from repro.pool.trace import Request, Trace


# ---------------------------------------------------------------------------
# Queueing / backpressure configuration (shared by sim + real daemon)
# ---------------------------------------------------------------------------

SHED_POLICIES = ("reject-new", "drop-oldest")


def _m_dispatches(app: str, path: str) -> None:
    # looked up per call (not cached at import) so a test-time registry
    # reset cannot strand a stale family handle
    from repro.obs.metrics import default_registry
    default_registry().counter(
        "repro_dispatch_total",
        "real dispatches by path (pool fork / cold subprocess / "
        "fallback after a zygote died mid-exec)",
        labels=("app", "path")).labels(app=app, path=path).inc()


def _m_degraded(app: str, reason: str) -> None:
    from repro.obs.metrics import default_registry
    default_registry().counter(
        "repro_degraded_total",
        "requests served degraded (e.g. cold-only because the app's "
        "zygote is circuit-broken after a crash loop)",
        labels=("app", "reason")).labels(app=app, reason=reason).inc()


class CrashLoopShed(RuntimeError):
    """Raised by :meth:`ZygoteFleet.dispatch` when an app is
    circuit-broken (its zygote keeps failing to boot) *and* the
    fresh-process cold fallback failed too — the request has nowhere
    left to go.  The daemon counts it as a ``crash_loop`` shed."""


@dataclass(frozen=True)
class BreakerConfig:
    """Per-app circuit breaker for zygote crash loops: after
    ``max_failures`` consecutive zygote *boot* failures the app is
    demoted to cold-path-only for ``cooldown_s``; the first attempt
    after the cooldown is the half-open probe — success closes the
    breaker, failure re-opens it for another cooldown."""

    max_failures: int = 3
    cooldown_s: float = 30.0

    def __post_init__(self) -> None:
        if self.max_failures < 1:
            raise ValueError(
                f"max_failures must be >= 1, got {self.max_failures}")
        if self.cooldown_s < 0:
            raise ValueError(
                f"cooldown_s must be >= 0, got {self.cooldown_s}")


class CircuitBreaker:
    """State machine for one app (see :class:`BreakerConfig`).  The
    clock is injectable so tests can step through cooldowns without
    sleeping.  Not thread-safe on its own: callers hold the fleet's
    dispatch context (the daemon serializes per-app work)."""

    def __init__(self, cfg: BreakerConfig,
                 clock=time.monotonic) -> None:
        self.cfg = cfg
        self._clock = clock
        self.failures = 0      # consecutive boot failures
        self.trips = 0         # closed->open transitions
        self._opened_t: Optional[float] = None

    @property
    def open(self) -> bool:
        """True while demoted to cold-only.  After ``cooldown_s`` this
        turns False again (half-open): one probe boot is allowed."""
        return (self._opened_t is not None
                and self._clock() - self._opened_t < self.cfg.cooldown_s)

    def record_failure(self) -> bool:
        """Count one boot failure; returns True when this transition
        (re)opened the breaker."""
        was_open = self.open
        self.failures += 1
        if self.failures >= self.cfg.max_failures:
            self._opened_t = self._clock()
        newly_open = self.open and not was_open
        if newly_open:
            self.trips += 1
        return newly_open

    def record_success(self) -> None:
        self.failures = 0
        self._opened_t = None

    def state(self) -> dict:
        return {"open": self.open, "failures": self.failures,
                "trips": self.trips}


def make_fleet_summary_payload(*, source: str, requests: int,
                               served: int, cold_starts: int,
                               p50_ms: float, p99_ms: float, sheds: int,
                               flushed: int, queue_wait_p50_ms: float,
                               queue_wait_p99_ms: float, per_app: list,
                               **optional) -> dict:
    """The one constructor for ``fleet_summary`` artifact payloads.

    Every producer (sim replay, real replay, the serve daemon, the
    bench) goes through here so the required fields and their
    *semantics* cannot drift — in particular ``cold_start_ratio`` is
    always ``cold_starts / requests`` (arrivals, not served), matching
    docs/artifacts.md.  Extra schema-optional fields pass through
    ``optional`` verbatim.
    """
    return {
        "source": source,
        "requests": requests,
        "served": served,
        "cold_starts": cold_starts,
        "cold_start_ratio": round(cold_starts / max(requests, 1), 4),
        "p50_ms": p50_ms,
        "p99_ms": p99_ms,
        "sheds": sheds,
        "flushed": flushed,
        "queue_wait_p50_ms": queue_wait_p50_ms,
        "queue_wait_p99_ms": queue_wait_p99_ms,
        "per_app": per_app,
        **optional,
    }


@dataclass(frozen=True)
class QueueConfig:
    """Bounded per-app admission: how much demand may pile up.

    ``max_concurrency`` caps demand-driven instances per app (prewarm
    floors may exceed it — the cap applies to spawning under load, not
    to retained state).  ``depth`` bounds the per-app FIFO of requests
    waiting for an instance to free.  ``shed_policy`` decides who is
    dropped once the queue is full: ``reject-new`` sheds the arriving
    request (classic load shedding), ``drop-oldest`` sheds the
    longest-waiting queued request and admits the new one (freshness
    beats fairness, e.g. for timeout-bound clients).
    """

    depth: int = 16
    max_concurrency: int = 4
    shed_policy: str = "reject-new"

    def __post_init__(self) -> None:
        if self.depth < 0:
            raise ValueError("queue depth must be >= 0")
        if self.max_concurrency < 1:
            raise ValueError("max_concurrency must be >= 1")
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(
                f"unknown shed_policy {self.shed_policy!r} "
                f"(choose from {SHED_POLICIES})")

    def to_dict(self) -> dict:
        return {"depth": self.depth,
                "max_concurrency": self.max_concurrency,
                "shed_policy": self.shed_policy}


# ---------------------------------------------------------------------------
# Simulation side
# ---------------------------------------------------------------------------

@dataclass
class _FleetInstance:
    app: str
    born_t: float
    busy_until: float = 0.0
    prewarmed: bool = False
    served: int = 0


@dataclass
class _AppState:
    profile: AppProfile
    report: FleetReport
    instances: list[_FleetInstance] = field(default_factory=list)
    zygote_up: bool = False
    zygote_since: float = 0.0
    zygote_mb_s: float = 0.0
    zygote_evicted_t: float = -math.inf
    pool_starts: int = 0
    arrivals: deque = field(default_factory=deque)
    # bounded-queue state: (enqueue_t, Request) FIFO of waiting requests
    queue: deque = field(default_factory=deque)

    def zygote_rss_mb(self) -> float:
        return self.profile.zygote_rss_mb or self.profile.rss_mb

    def zygote_charge_mb(self, shared_base_mb: float) -> float:
        """What this app's resident zygote costs the budget.  With a
        shared base (two-tier fleet) only the *incremental* pages above
        the base are charged — the measured private delta when the
        profile has one, else the RSS increment over the base."""
        full = self.zygote_rss_mb()
        if shared_base_mb <= 0:
            return full
        if self.profile.zygote_private_mb > 0:
            return min(self.profile.zygote_private_mb, full)
        return max(full - shared_base_mb, 0.0)


@dataclass
class FleetSummary:
    """Fleet-level rollup of one multi-app replay."""

    policy: str
    trace: str
    budget_mb: float
    duration_s: float
    per_app: dict[str, FleetReport]
    pool_starts: int = 0
    prewarm_spawns: int = 0
    evictions: int = 0
    zygote_evictions: int = 0
    budget_violations: int = 0
    memory_mb_s: float = 0.0
    peak_mb: float = 0.0
    zygote_apps: list[str] = field(default_factory=list)
    queue: Optional[QueueConfig] = None
    rewarm_ticks: int = 0
    # two-tier fleet: the shared base zygote's resident MB (charged once
    # fleet-wide) and the memory-seconds it accrued over the replay
    shared_base_mb: float = 0.0
    base_mb_s: float = 0.0

    def __post_init__(self) -> None:
        # percentile pools sort the merged latency lists once and are
        # invalidated by growth, so summary()/app_rows() on a large
        # replay stop re-sorting the full pool on every property access
        self._lat_pool = PercentilePool(
            lambda: (r.latencies_ms for r in self.per_app.values()))
        self._wait_pool = PercentilePool(
            lambda: (r.queue_waits_ms for r in self.per_app.values()))

    @property
    def n_requests(self) -> int:
        return sum(r.n_requests for r in self.per_app.values())

    @property
    def cold_starts(self) -> int:
        return sum(r.cold_starts for r in self.per_app.values())

    @property
    def sheds(self) -> int:
        return sum(r.sheds for r in self.per_app.values())

    @property
    def flushed(self) -> int:
        return sum(r.flushed for r in self.per_app.values())

    @property
    def shed_reasons(self) -> dict[str, int]:
        merged: dict[str, int] = {}
        for r in self.per_app.values():
            for reason, n in r.shed_reasons.items():
                merged[reason] = merged.get(reason, 0) + n
        return merged

    @property
    def served(self) -> int:
        return sum(r.served for r in self.per_app.values())

    @property
    def queue_wait_p50_ms(self) -> float:
        return self._wait_pool.percentile(0.50)

    @property
    def queue_wait_p99_ms(self) -> float:
        return self._wait_pool.percentile(0.99)

    @property
    def cold_start_ratio(self) -> float:
        return self.cold_starts / max(self.n_requests, 1)

    @property
    def p50_ms(self) -> float:
        return self._lat_pool.percentile(0.50)

    @property
    def p99_ms(self) -> float:
        return self._lat_pool.percentile(0.99)

    @property
    def mean_ms(self) -> float:
        return self._lat_pool.mean

    @property
    def budget_utilization(self) -> float:
        """Time-averaged retained+running memory over the budget."""
        if self.budget_mb <= 0 or self.duration_s <= 0:
            return math.nan
        return (self.memory_mb_s / self.duration_s) / self.budget_mb

    def summary(self) -> dict:
        return {
            "policy": self.policy,
            "trace": self.trace,
            "budget_mb": round(self.budget_mb, 1),
            "requests": self.n_requests,
            "cold_starts": self.cold_starts,
            "cold_ratio": round(self.cold_start_ratio, 4),
            "pool_starts": self.pool_starts,
            "p99_ms": round(self.p99_ms, 2),
            "mean_ms": round(self.mean_ms, 2),
            "budget_util": round(self.budget_utilization, 3),
            "peak_mb": round(self.peak_mb, 1),
            "evictions": self.evictions,
            "prewarm_spawns": self.prewarm_spawns,
            "zygotes": ",".join(self.zygote_apps) or "-",
            "sheds": self.sheds,
            "queue_wait_p99_ms": round(self.queue_wait_p99_ms, 2)
            if not math.isnan(self.queue_wait_p99_ms) else 0.0,
            "memory_gb_s": round(self.memory_mb_s / 1024.0, 3),
            "shared_base_mb": round(self.shared_base_mb, 1),
        }

    def app_rows(self) -> list[dict]:
        def _num(x: float) -> float:
            # strict-JSON safe: a silent app has no latencies -> 0.0
            return 0.0 if math.isnan(x) else round(x, 2)

        rows = []
        for app, rep in sorted(self.per_app.items()):
            rows.append({
                "app": app,
                "requests": rep.n_requests,
                "cold_starts": rep.cold_starts,
                "cold_ratio": round(rep.cold_start_ratio, 4),
                "p50_ms": _num(rep.p50_ms),
                "p99_ms": _num(rep.p99_ms),
                "memory_gb_s": round(rep.memory_gb_s, 3),
                "max_instances": rep.max_instances,
                "sheds": rep.sheds,
                "shed_reasons": dict(rep.shed_reasons),
                "flushed": rep.flushed,
                "queue_wait_p99_ms": round(rep.queue_wait_p99_ms, 2)
                if rep.queue_waits_ms else 0.0,
            })
        return rows

    def artifact_payload(self, *, source: str = "replay-sim",
                         rewarm_ticks: Optional[int] = None) -> dict:
        """The schema-versioned ``fleet_summary`` artifact payload (see
        :class:`repro.api.artifacts.FleetSummaryArtifact`) for this
        replay — what ``fleet serve`` / ``fleet replay`` emit."""

        def _num(x: float) -> float:
            return 0.0 if math.isnan(x) else round(x, 3)

        return make_fleet_summary_payload(
            source=source,
            requests=self.n_requests,
            served=self.served,
            cold_starts=self.cold_starts,
            p50_ms=_num(self.p50_ms),
            p99_ms=_num(self.p99_ms),
            sheds=self.sheds,
            shed_reasons=self.shed_reasons,
            flushed=self.flushed,
            queue_wait_p50_ms=_num(self.queue_wait_p50_ms),
            queue_wait_p99_ms=_num(self.queue_wait_p99_ms),
            per_app=self.app_rows(),
            policy=self.policy,
            trace=self.trace,
            budget_mb=round(self.budget_mb, 1),
            duration_s=round(self.duration_s, 3),
            pool_starts=self.pool_starts,
            memory_gb_s=round(self.memory_mb_s / 1024.0, 3),
            rewarm_ticks=(self.rewarm_ticks if rewarm_ticks is None
                          else rewarm_ticks),
            queue=self.queue.to_dict() if self.queue else None,
            shared_base_mb=round(self.shared_base_mb, 1),
            base_gb_s=round(self.base_mb_s / 1024.0, 3),
        )


class FleetManager:
    """Arbitrates warm state for many apps under one memory budget.

    ``replay(trace)`` is the simulation entry point; the decision
    helpers (``amortization_score``, ``observed_rate_per_s``) are public
    so the real :class:`ZygoteFleet` and tests share the same math.
    """

    def __init__(self, profiles: dict[str, AppProfile],
                 policy: KeepAlivePolicy, *, budget_mb: float,
                 rate_window_s: float = 120.0,
                 zygote_retry_s: float = 60.0,
                 queue: Optional[QueueConfig] = None,
                 shared_base_mb: float = 0.0) -> None:
        if budget_mb <= 0:
            raise ValueError("budget_mb must be positive")
        if shared_base_mb < 0:
            raise ValueError("shared_base_mb must be >= 0")
        self.profiles = dict(profiles)
        self.policy = policy
        self.budget_mb = budget_mb
        self.rate_window_s = rate_window_s
        # hysteresis: a zygote evicted under budget pressure is not
        # re-booted before this many seconds (prevents boot/evict thrash
        # when zygotes and instances contend for a tight budget)
        self.zygote_retry_s = zygote_retry_s
        # None = unbounded demand spawns (Lambda-style); a QueueConfig
        # bounds concurrency per app and sheds past the queue depth
        self.queue = queue
        # two-tier fleet: one shared base zygote is resident for the
        # whole run (charged once); per-app zygotes then cost only
        # their incremental pages (AppProfile.zygote_private_mb, or
        # zygote_rss_mb minus the base) — so eviction ranks on what
        # evicting actually frees, and admission headroom on what
        # admitting actually costs
        self.shared_base_mb = shared_base_mb
        self._apps: dict[str, _AppState] = {}
        self._last_t = 0.0

    # ------------------------------------------------------------- signals
    def observed_rate_per_s(self, app: str, now: float) -> float:
        """Arrival rate over the trailing window (0 before any traffic).
        Prunes here, not just on arrival: a silent app's rate must decay
        to zero or its dead warm state would outrank live apps."""
        st = self._apps.get(app)
        if st is None:
            return 0.0
        horizon = now - self.rate_window_s
        while st.arrivals and st.arrivals[0] < horizon:
            st.arrivals.popleft()
        if not st.arrivals:
            return 0.0
        # early in the trace the window is the elapsed time, floored at
        # 1 s so a burst at t=0 doesn't read as an infinite rate
        window = min(self.rate_window_s, max(now, 1.0))
        return len(st.arrivals) / window

    def amortization_score(self, app: str, now: float) -> float:
        """How well this app's warm state pays for its memory: init
        milliseconds saved per second, per resident MB.  Ranks apps for
        zygote admission and prewarm priority (descending)."""
        prof = self.profiles[app]
        saved = max(prof.cold_init_ms - prof.warm_init_ms, 0.0)
        rate = self.observed_rate_per_s(app, now)
        return rate * saved / max(prof.rss_mb, 1e-9)

    def instance_evict_cost(self, app: str, now: float) -> float:
        """Marginal cost of evicting one idle instance of ``app``: extra
        init ms per second of traffic, per freed MB.  Crucially, an app
        with a resident zygote falls back to a cheap fork — its idle
        instances are nearly free to evict — while a zygote-less app's
        warm instance shields a full cold start."""
        st = self._apps[app]
        prof = st.profile
        fallback_ms = (prof.warm_init_ms if st.zygote_up
                       else prof.cold_init_ms)
        saved = max(fallback_ms - prof.warm_init_ms, 0.0)
        rate = self.observed_rate_per_s(app, now)
        return rate * saved / max(prof.rss_mb, 1e-9)

    def zygote_evict_cost(self, app: str, now: float) -> float:
        """Marginal cost of evicting ``app``'s zygote: every future
        start degrades from fork to full cold, per freed MB.  With a
        shared base the denominator is the *incremental* charge — the
        base's pages stay resident either way, so a big-RSS zygote
        whose pages are mostly the shared set is cheap to keep and
        expensive-per-freed-MB to evict."""
        st = self._apps[app]
        prof = st.profile
        saved = max(prof.cold_init_ms - prof.warm_init_ms, 0.0)
        rate = self.observed_rate_per_s(app, now)
        return rate * saved / max(st.zygote_charge_mb(self.shared_base_mb),
                                  1e-9)

    # -------------------------------------------------------------- replay
    def replay(self, trace: Trace) -> FleetSummary:
        self.begin(trace.name)
        for req in trace:
            self.offer(req)
        return self.finish(trace.duration_s)

    # ------------------------------------------------- incremental serving
    # The daemon (repro.pool.daemon) drives the same machinery one
    # arrival at a time: begin() -> offer(req)* -> finish(end_t).
    # Offers must be time-ordered (wall clock or trace time).

    def begin(self, trace_name: str = "live") -> None:
        """Reset state for a fresh (incremental or one-shot) run."""
        self._reset(trace_name)
        self._rebalance(0.0)

    def offer(self, req: Request) -> str:
        """Feed one arrival; returns the admission outcome:
        ``"served"`` (warm/cold/pool start or demand spawn),
        ``"queued"`` (waiting for an instance) or ``"shed"``."""
        if req.app not in self._apps:
            raise KeyError(
                f"trace requests unknown app {req.app!r}; "
                f"fleet serves {sorted(self._apps)}")
        self._last_t = max(self._last_t, req.t)
        self.policy.observe_arrival(req.app, req.t)
        self._record_arrival(req.app, req.t)
        for st in self._apps.values():
            self._drain_queue(st, req.t)
        self._reclaim_idle(req.t)
        self._rebalance(req.t)
        return self._serve(req)

    def add_app(self, profile: AppProfile) -> None:
        """Register a new app mid-run (cluster migration: an app moves
        onto this node while it serves).  Safe between offers; the
        app's report joins the live summary so later ``finish()`` rolls
        it up like any other."""
        app = profile.app
        if app in self._apps:
            return
        self.profiles[app] = profile
        st = _AppState(
            profile=profile,
            report=FleetReport(policy=self.policy.name,
                               trace=self._summary.trace,
                               n_requests=0, cold_starts=0))
        self._apps[app] = st
        self._summary.per_app[app] = st.report

    def retire_app(self, app: str, now: Optional[float] = None) -> dict:
        """Remove an app mid-run (cluster migration: the app moves off
        this node).  Conservation-preserving: queued requests that can
        still start on a free instance do; the rest are *flushed*
        (counted, never served).  Warm state is released and its
        memory-seconds accounted.  The report stays in the summary so
        nothing this node admitted ever disappears from the rollup.
        Returns ``{"flushed": n}``."""
        st = self._apps.get(app)
        if st is None:
            return {"flushed": 0}
        t = self._last_t if now is None else max(now, self._last_t)
        self._drain_queue(st, t)
        flushed = len(st.queue)
        st.report.flushed += flushed
        st.queue.clear()
        for inst in st.instances:
            st.report.memory_mb_s += st.profile.rss_mb * (
                max(t, inst.busy_until) - inst.born_t)
        st.instances = []
        if st.zygote_up:
            st.zygote_up = False
            st.zygote_mb_s += st.zygote_charge_mb(
                self.shared_base_mb) * (t - st.zygote_since)
        # fold the accrued zygote overhead in now — _finalize only
        # visits live _apps entries, and this one is leaving
        st.report.memory_mb_s += st.zygote_mb_s
        st.zygote_mb_s = 0.0
        del self._apps[app]
        self.profiles.pop(app, None)
        return {"flushed": flushed}

    def prewarm_zygote(self, app: str,
                       now: Optional[float] = None) -> dict:
        """Warm-handoff target side: force ``app``'s zygote resident
        *before* placement flips to this node, so the first migrated
        request pays ``warm_init_ms`` instead of ``cold_init_ms``.
        Budget still rules — a prewarm that does not fit degrades to a
        cold handoff rather than blowing the cap."""
        st = self._apps.get(app)
        if st is None:
            return {"warm": False, "reason": "unknown_app"}
        t = self._last_t if now is None else max(now, self._last_t)
        if st.zygote_up:
            return {"warm": True, "already": True}
        charge = st.zygote_charge_mb(self.shared_base_mb)
        if (self.budget_mb is not None
                and self._used_mb() + charge > self.budget_mb):
            return {"warm": False, "reason": "budget"}
        st.zygote_up = True
        st.zygote_since = t
        self._note_peak()
        return {"warm": True, "already": False}

    def flush_queued(self,
                     now: Optional[float] = None) -> list[Request]:
        """Planned-drain flush: give every queue one last chance to
        start on a free instance, then *return* whatever is still
        waiting instead of dropping it.  The returned requests are
        counted ``flushed`` here (conservation: this node admitted
        them and must account for them) — the caller re-admits them
        elsewhere as fresh arrivals."""
        t = self._last_t if now is None else max(now, self._last_t)
        out: list[Request] = []
        for st in self._apps.values():
            self._drain_queue(st, t)
            if st.queue:
                st.report.flushed += len(st.queue)
                out.extend(req for _, req in st.queue)
                st.queue.clear()
        return out

    def finish(self, end_t: Optional[float] = None) -> FleetSummary:
        """Drain queues, account trailing memory, return the summary.
        Requests still queued at ``end_t`` (nothing freed up in time)
        are *flushed*: counted, never served."""
        end = self._last_t if end_t is None else max(end_t, self._last_t)
        for st in self._apps.values():
            self._drain_queue(st, end)
            st.report.flushed += len(st.queue)
            st.queue.clear()
        self._reclaim_idle(end)
        self._finalize(end)
        self._summary.duration_s = max(self._summary.duration_s, end)
        return self._summary

    # ------------------------------------------------------------ internals
    def _reset(self, trace_name: str) -> None:
        self._apps = {
            app: _AppState(
                profile=prof,
                report=FleetReport(policy=self.policy.name,
                                   trace=trace_name, n_requests=0,
                                   cold_starts=0))
            for app, prof in self.profiles.items()
        }
        self._last_t = 0.0
        self._summary = FleetSummary(
            policy=self.policy.name, trace=trace_name,
            budget_mb=self.budget_mb, duration_s=0.0,
            per_app={app: st.report for app, st in self._apps.items()},
            queue=self.queue, shared_base_mb=self.shared_base_mb)

    def _record_arrival(self, app: str, t: float) -> None:
        self._apps[app].arrivals.append(t)
        self.policy.observe_rate(app, self.observed_rate_per_s(app, t))

    def _used_mb(self, *, retained_only: bool = False,
                 now: Optional[float] = None) -> float:
        # the shared base zygote (two-tier mode) is resident for the
        # whole run and charged exactly once, fleet-wide
        total = self.shared_base_mb
        for st in self._apps.values():
            if st.zygote_up:
                total += st.zygote_charge_mb(self.shared_base_mb)
            insts = st.instances
            if retained_only and now is not None:
                insts = [i for i in insts if i.busy_until <= now]
            total += st.profile.rss_mb * len(insts)
        return total

    def _note_peak(self) -> None:
        self._summary.peak_mb = max(self._summary.peak_mb, self._used_mb())

    def _reclaim_idle(self, now: float) -> None:
        for app, st in self._apps.items():
            ka = self.policy.keep_alive_s(app)
            survivors = []
            for inst in st.instances:
                if (not inst.prewarmed and inst.busy_until <= now
                        and now - inst.busy_until > ka):
                    died_at = inst.busy_until + ka
                    st.report.memory_mb_s += st.profile.rss_mb * (
                        died_at - inst.born_t)
                    st.report.reclaims += 1
                else:
                    survivors.append(inst)
            st.instances = survivors

    def _rebalance(self, now: float) -> None:
        ranked = sorted(self._apps,
                        key=lambda a: -self.amortization_score(a, now))
        # 1) zygote residency for apps whose policy pre-imports a hot set
        for app in ranked:
            st = self._apps[app]
            if st.zygote_up or not self.policy.preload_modules(app):
                continue
            if now - st.zygote_evicted_t < self.zygote_retry_s:
                continue  # recently squeezed out: don't thrash
            # admit only with headroom for at least one forked instance
            # — a zygote that starves serving of memory is pure
            # overhead.  Two-tier mode admits on the *delta*: the
            # shared pages are already paid for
            need = st.zygote_charge_mb(self.shared_base_mb) \
                + st.profile.rss_mb
            if self._used_mb() + need <= self.budget_mb:
                st.zygote_up = True
                st.zygote_since = now
        # 2) prewarm floors, best amortizer first
        for app in ranked:
            st = self._apps[app]
            floor = self.policy.prewarm(app)
            while (len(st.instances) < floor
                   and self._used_mb() + st.profile.rss_mb
                   <= self.budget_mb):
                self._spawn(st, now, prewarmed=True)
                self._summary.prewarm_spawns += 1
        # 3) evict retention back under the budget (worst amortizer first)
        self._evict_to_budget(now)
        self._note_peak()
        if self._used_mb(retained_only=True, now=now) > self.budget_mb \
                and self._any_retained(now):
            self._summary.budget_violations += 1

    def _any_retained(self, now: float) -> bool:
        return any(st.zygote_up
                   or any(i.busy_until <= now for i in st.instances)
                   for st in self._apps.values())

    def _evict_to_budget(self, now: float) -> None:
        while self._used_mb() > self.budget_mb:
            victim = self._eviction_victim(now)
            if victim is None:
                break  # only busy instances left: serving wins
            app, kind = victim
            st = self._apps[app]
            if kind == "instance":
                idle = [i for i in st.instances if i.busy_until <= now]
                inst = min(idle, key=lambda i: i.busy_until)  # oldest idle
                st.instances.remove(inst)
                st.report.memory_mb_s += st.profile.rss_mb * (
                    now - inst.born_t)
                self._summary.evictions += 1
            else:
                st.zygote_up = False
                st.zygote_evicted_t = now
                st.zygote_mb_s += st.zygote_charge_mb(
                    self.shared_base_mb) * (now - st.zygote_since)
                self._summary.zygote_evictions += 1

    def _eviction_victim(self, now: float) -> Optional[tuple[str, str]]:
        """The retained item (idle instance or zygote, any app) whose
        eviction costs the fleet least per freed MB — "the app whose
        warm instance amortizes worst goes first"."""
        best: Optional[tuple[float, str, str]] = None
        for app, st in self._apps.items():
            if any(i.busy_until <= now for i in st.instances):
                cost = self.instance_evict_cost(app, now)
                if best is None or cost < best[0]:
                    best = (cost, app, "instance")
            if st.zygote_up:
                cost = self.zygote_evict_cost(app, now)
                if best is None or cost < best[0]:
                    best = (cost, app, "zygote")
        return (best[1], best[2]) if best is not None else None

    def _start_latency_ms(self, st: _AppState) -> tuple[float, bool]:
        """(init latency for a brand-new instance, is_cold).  A resident
        zygote turns the start into a cheap fork — a *pool start*."""
        if st.zygote_up:
            return st.profile.warm_init_ms, False
        return st.profile.cold_init_ms, True

    def _spawn(self, st: _AppState, now: float, *,
               prewarmed: bool) -> _FleetInstance:
        init_ms, cold = self._start_latency_ms(st)
        inst = _FleetInstance(app=st.profile.app, born_t=now,
                              prewarmed=prewarmed)
        # a prewarmed instance becomes usable once its init completes;
        # its init cost stays off every request's latency
        inst.busy_until = now + init_ms / 1e3
        st.instances.append(inst)
        if not prewarmed:
            if cold:
                st.report.cold_starts += 1
            else:
                st.pool_starts += 1
                self._summary.pool_starts += 1
        st.report.max_instances = max(st.report.max_instances,
                                      len(st.instances))
        return inst

    def _drain_queue(self, st: _AppState, now: float) -> None:
        """Start queued requests on instances that freed up before
        ``now`` (in free-time order, so FIFO requests chain onto the
        earliest available instance with no idle gap)."""
        while st.queue:
            if not st.instances:
                break
            inst = min(st.instances, key=lambda i: i.busy_until)
            free_t = inst.busy_until
            if free_t > now:
                break
            enq_t, _qreq = st.queue.popleft()
            start = max(free_t, enq_t)
            wait_ms = (start - enq_t) * 1e3
            latency_ms = wait_ms + st.profile.warm_init_ms \
                + st.profile.invoke_ms
            inst.busy_until = start + (st.profile.warm_init_ms
                                       + st.profile.invoke_ms) / 1e3
            inst.served += 1
            st.report.queue_waits_ms.append(wait_ms)
            st.report.latencies_ms.append(latency_ms)

    def _serve(self, req: Request) -> str:
        st = self._apps[req.app]
        prof = st.profile
        qc = self.queue
        st.report.n_requests += 1
        if not st.queue:  # FIFO: nobody may overtake a queued request
            idle = [i for i in st.instances if i.busy_until <= req.t]
            if idle:
                inst = max(idle, key=lambda i: i.busy_until)  # LIFO reuse
                latency_ms = prof.warm_init_ms + prof.invoke_ms
                inst.busy_until = req.t + latency_ms / 1e3
                inst.served += 1
                st.report.latencies_ms.append(latency_ms)
                self._note_peak()
                return "served"
            if qc is None or len(st.instances) < qc.max_concurrency:
                init_ms, _cold = self._start_latency_ms(st)
                inst = self._spawn(st, req.t, prewarmed=False)
                latency_ms = init_ms + prof.invoke_ms
                inst.busy_until = req.t + latency_ms / 1e3
                inst.served += 1
                st.report.latencies_ms.append(latency_ms)
                self._note_peak()
                return "served"
        elif len(st.instances) < qc.max_concurrency:
            # queued work exists but the concurrency cap has room (an
            # instance was evicted/reclaimed while requests waited):
            # spawn a demand instance — the queue head chains onto it
            # once its init completes (init lands inside that request's
            # measured queue wait)
            self._spawn(st, req.t, prewarmed=False)
        # no instance available: queue (bounded) or shed
        assert qc is not None  # unbounded mode always spawned above
        if len(st.queue) < qc.depth:
            st.queue.append((req.t, req))
            return "queued"
        if qc.shed_policy == "drop-oldest" and st.queue:
            st.queue.popleft()
            st.report.count_shed("drop-oldest")
            st.queue.append((req.t, req))
            return "queued"
        st.report.count_shed("queue-full")  # reject-new
        return "shed"

    def _finalize(self, end: float) -> None:
        zygote_apps = []
        for app, st in self._apps.items():
            for inst in st.instances:
                st.report.memory_mb_s += st.profile.rss_mb * (
                    max(end, inst.busy_until) - inst.born_t)
            if st.zygote_up:
                st.zygote_mb_s += st.zygote_charge_mb(
                    self.shared_base_mb) * (end - st.zygote_since)
            if st.zygote_up or st.zygote_mb_s > 0:
                zygote_apps.append(app)
            # zygote memory is fleet overhead attributed to the app
            st.report.memory_mb_s += st.zygote_mb_s
        self._summary.zygote_apps = sorted(zygote_apps)
        # the shared base's pages are fleet overhead attributed to no
        # single app: account them once, against the whole run
        self._summary.base_mb_s = self.shared_base_mb * end
        self._summary.memory_mb_s = self._summary.base_mb_s + sum(
            st.report.memory_mb_s for st in self._apps.values())


def fleet_sweep(profiles: dict[str, AppProfile],
                policies: Sequence[KeepAlivePolicy], trace: Trace, *,
                budget_mb: float, policy_factory=None,
                shared_base_mb: float = 0.0) -> list[FleetSummary]:
    """Replay one multi-app trace under every policy at the same budget.
    Stateful policies must not leak learned state across runs: pass
    ``policy_factory`` mapping a policy to a fresh clone (deepcopy is a
    fine default for the standard panel).  ``shared_base_mb`` turns on
    two-tier accounting (see :class:`FleetManager`)."""
    out = []
    for pol in policies:
        p = policy_factory(pol) if policy_factory is not None else pol
        out.append(FleetManager(profiles, p, budget_mb=budget_mb,
                                shared_base_mb=shared_base_mb,
                                ).replay(trace))
    return out


# ---------------------------------------------------------------------------
# Real-process side
# ---------------------------------------------------------------------------

class ZygoteFleet:
    """One real fork-server zygote per app under a shared memory budget.

    ``apps`` maps app name -> deployed app directory.  ``reports``
    (per-app :class:`OptimizationReport` objects or saved versioned
    artifact paths, see :func:`repro.api.as_report`) give each zygote
    its profile-guided pre-import hot set; apps without a report boot
    bare zygotes.  ``start`` boots zygotes in the given priority order while
    *measured* zygote RSS fits ``budget_mb``; apps that don't fit are
    recorded in ``skipped`` and serve fresh-process cold starts.

    ``shared_base=True`` turns on the **two-tier hierarchy** (PR 5):
    one :class:`~repro.pool.forkserver.BaseZygote` pre-imports the
    cross-app shared hot set (modules hot for >= ``base_min_apps``
    member reports, :func:`repro.pool.sharing.compute_shared_hot_set`)
    and every per-app zygote is *forked from it* — boot collapses to
    ``fork + delta import``, the shared pages exist once fleet-wide
    (CoW), and the budget charges each app only its **incremental**
    memory over the base, so admission headroom and eviction rank on
    what a zygote actually adds, not its full RSS.
    """

    def __init__(self, apps: dict[str, str], *,
                 budget_mb: Optional[float] = None,
                 reports: Optional[dict[str, OptimizationReport]] = None,
                 timeout_s: float = 180.0,
                 shared_base: bool = False,
                 base_min_apps: int = 2,
                 fault_hook=None,
                 breaker: Optional[BreakerConfig] = None,
                 boot_backoff_s: float = 0.5,
                 revive_on_dispatch: bool = False,
                 clock=time.monotonic) -> None:
        from repro.api.artifacts import as_report
        self.app_dirs = dict(apps)
        self.budget_mb = budget_mb
        # each value may be the report object or a saved artifact path
        self.reports = {app: as_report(rep)
                        for app, rep in (reports or {}).items()}
        self.timeout_s = timeout_s
        self.shared_base = shared_base
        self.base_min_apps = base_min_apps
        # chaos hook (repro.pool.chaos), forwarded to every zygote;
        # None keeps every path exactly as before
        self.fault_hook = fault_hook
        # crash-recovery hardening: zygote boots back off exponentially
        # in the ForkServer; the per-app breaker demotes a flapping app
        # to cold-path-only after breaker.max_failures boot failures
        self.breaker_cfg = breaker or BreakerConfig()
        self.boot_backoff_s = boot_backoff_s
        # opt-in: let dispatch() attempt one (backoff-gated) zygote
        # restart when it finds the zygote dead, instead of waiting for
        # the next rewarm tick.  Off by default: the historical
        # contract is dead zygote -> cold start, rewarm revives.
        self.revive_on_dispatch = revive_on_dispatch
        self._clock = clock
        self.breakers: dict[str, CircuitBreaker] = {
            app: CircuitBreaker(self.breaker_cfg, clock=clock)
            for app in self.app_dirs}
        self.recoveries: dict[str, int] = {
            "zygote_restarts": 0, "base_reboots": 0,
            "breaker_trips": 0}
        self.base: Optional[BaseZygote] = None
        self.shared: Optional[SharedHotSet] = None
        self.base_swaps = 0
        self.servers: dict[str, ForkServer] = {}
        self.skipped: list[str] = []
        # apps whose zygote failed to boot in start(); they serve cold
        # until a rewarm/dispatch revive gets them a warm zygote
        self.boot_failed: list[str] = []
        self.last_summary: Optional[dict] = None
        self.dispatches: dict[str, dict[str, int]] = {
            app: {"pool": 0, "cold": 0, "fallback": 0}
            for app in self.app_dirs}

    # ----------------------------------------------------------- lifecycle
    def _compute_shared(self) -> SharedHotSet:
        return compute_shared_hot_set(self.reports,
                                      min_apps=self.base_min_apps)

    def _app_preload(self, app: str) -> list[str]:
        """The app zygote's pre-import set: its full hot set standalone,
        only the private delta when forking from the shared base."""
        rep = self.reports.get(app)
        hot = hot_set_from_report(rep) if rep is not None else []
        if self.shared is not None:
            return self.shared.delta(app, hot)
        return hot

    def ensure_base(self) -> Optional[BaseZygote]:
        """Boot (or re-boot after a crash) the shared base zygote."""
        if not self.shared_base:
            return None
        if self.base is not None and self.base.alive:
            return self.base
        reboot = self.base is not None  # crashed, not first boot
        self.shared = self._compute_shared()
        base = self.base or BaseZygote(
            preload=self.shared.modules,
            search_paths=shared_search_paths(self.app_dirs),
            timeout_s=self.timeout_s, fault_hook=self.fault_hook,
            boot_backoff_s=self.boot_backoff_s, clock=self._clock)
        # restart goes through the ForkServer boot-backoff gate, so a
        # base that keeps dying cannot hot-loop interpreter boots —
        # ForkServerBackoff propagates and the caller serves cold
        base.restart(preload=self.shared.modules)
        self.base = base
        if reboot:
            self.recoveries["base_reboots"] += 1
        return base

    def start(self) -> dict:
        try:
            self.ensure_base()
        except ForkServerError:
            # no base: per-app zygotes boot standalone (base=None) and
            # ensure_base() retries on the next dispatch/rewarm
            pass
        budget_full = False
        for app, app_dir in self.app_dirs.items():
            if budget_full or (self.budget_mb is not None
                               and self.used_mb() >= self.budget_mb):
                self.skipped.append(app)
                continue
            fs = ForkServer(app_dir, preload=self._app_preload(app),
                            timeout_s=self.timeout_s, base=self.base,
                            fault_hook=self.fault_hook,
                            boot_backoff_s=self.boot_backoff_s,
                            clock=self._clock)
            try:
                fs.start()
            except ForkServerError as exc:
                # a zygote that cannot boot must not take the whole
                # fleet down: record breaker evidence, register the
                # (dead) server so dispatch()/rewarm() retry it
                # through the backoff gate, and serve the app cold
                # meanwhile.  Dead zygotes charge no budget memory.
                self._record_boot_failure(app, exc)
                self.boot_failed.append(app)
            self.servers[app] = fs
            if self.budget_mb is not None and self.used_mb() > \
                    self.budget_mb:
                # measured RSS blew the budget: take this zygote back
                # down, and stop admitting — apps are in priority order,
                # so paying a full boot+kill cycle per remaining app
                # just to confirm the budget is exhausted wastes seconds
                fs.stop()
                del self.servers[app]
                self.skipped.append(app)
                budget_full = True
        boot = {"zygotes": sorted(a for a, fs in self.servers.items()
                                  if fs.alive),
                "skipped": list(self.skipped),
                "used_mb": round(self.used_mb(), 1),
                **self._base_info()}
        if self.boot_failed:
            boot["boot_failed"] = list(self.boot_failed)
        return boot

    def _base_info(self) -> dict:
        if not self.shared_base:
            return {}
        return {"shared_base": {
            "modules": list(self.shared.modules) if self.shared else [],
            "rss_mb": round(self.base_rss_mb(), 1),
            "swaps": self.base_swaps,
        }}

    def stop(self) -> None:
        for fs in self.servers.values():
            fs.stop()
        if self.base is not None:
            self.base.stop()

    def __enter__(self) -> "ZygoteFleet":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def base_rss_mb(self) -> float:
        return (self.base.rss_kb() / 1024.0
                if self.base is not None else 0.0)

    def incremental_mb(self, app: str) -> float:
        """What ``app``'s zygote adds to fleet memory.  Standalone
        zygotes are charged full RSS.  Spawned-from-base zygotes are
        charged their private pages when the kernel reports a real
        shared/private split (``smaps_rollup``), else the RSS increment
        over the base — the CoW-blind conservative estimate."""
        fs = self.servers.get(app)
        if fs is None or not fs.alive:
            return 0.0
        mem = fs.memory_kb()
        if self.base is None:
            return mem["rss_kb"] / 1024.0
        if mem["pss_kb"] > 0:  # real smaps split available
            return mem["private_kb"] / 1024.0
        return max(mem["rss_kb"] / 1024.0 - self.base_rss_mb(), 0.0)

    def used_mb(self) -> float:
        """Fleet-resident memory the budget charges: base (once) plus
        each zygote's incremental cost in two-tier mode; plain RSS sums
        otherwise."""
        if self.base is not None:
            return self.base_rss_mb() + sum(
                self.incremental_mb(app) for app in self.servers)
        return sum(fs.rss_kb() for fs in self.servers.values()) / 1024.0

    # ------------------------------------------------------------ serving
    def dispatch(self, app: str, *, handler: Optional[str] = None,
                 invocations: int = 1, seed: int = 0,
                 trace: Optional[dict] = None,
                 live_profile: Optional[dict] = None) -> dict:
        """Serve one request: fork from the app's zygote if it is
        resident and alive, else a fresh-process cold start.  Returns
        runner-format metrics plus ``path`` ("pool" | "cold") and
        ``fallback`` (True when a live zygote failed mid-exec).

        With tracing enabled this wraps the whole call in a
        ``dispatch`` span (child of the ``trace`` context, or a fresh
        trace root for standalone dispatches) and folds the zygote
        child's fork/import/invoke spans — shipped back on the exec
        reply — into the process tracer."""
        if app not in self.app_dirs:
            raise KeyError(f"unknown app {app!r}")
        from repro.obs.tracing import get_tracer
        tracer = get_tracer()
        with tracer.span("dispatch", ctx=trace, app=app) as sp:
            if self.fault_hook is not None:
                # chaos site "dispatch": base-zygote kills land here,
                # mid-burst, independent of any one app's protocol
                self.fault_hook("dispatch", app=app, base=self.base)
            if self.revive_on_dispatch and self.shared_base \
                    and self.base is not None and not self.base.alive:
                # the shared base died (chaos kill, OOM): app zygotes
                # survive their parent, but respawns need a live base —
                # reboot it now rather than on the next zygote crash
                try:
                    self.ensure_base()
                except ForkServerError:
                    pass  # gated/failed: retried on a later dispatch
            fs = self.servers.get(app)
            br = self.breakers.get(app)
            degraded = br is not None and br.open
            fallback = False
            if degraded:
                sp.set("degraded", "crash_loop")
            elif fs is not None and not fs.alive \
                    and self.revive_on_dispatch:
                self._try_revive(app, fs)
                degraded = br is not None and br.open
            if not degraded and fs is not None and fs.alive:
                try:
                    m = fs.exec(invocations=invocations, handler=handler,
                                seed=seed, trace=sp.ctx(),
                                live_profile=live_profile)
                    tracer.record_dicts(m.pop("spans", None))
                    self.dispatches[app]["pool"] += 1
                    sp.set("path", "pool")
                    _m_dispatches(app, "pool")
                    if br is not None:
                        br.record_success()
                    return {**m, "path": "pool", "fallback": False}
                except ForkServerTimeout:
                    # wedged handler: the zygote was already killed;
                    # retrying the same request cold would likely wedge
                    # again, so it sheds upward ("timeout" reason)
                    sp.set("path", "timeout")
                    _m_dispatches(app, "timeout")
                    raise
                except ForkServerError:
                    fallback = True
                    self.dispatches[app]["fallback"] += 1
                    _m_dispatches(app, "fallback")
            from repro.benchsuite.harness import run_instance
            try:
                with tracer.span("cold_start", ctx=sp.ctx(), app=app,
                                 subprocess=True):
                    if self.fault_hook is not None:
                        self.fault_hook("cold_start", app=app)
                    m = run_instance(self.app_dirs[app],
                                     invocations=invocations,
                                     handler=handler, seed=seed,
                                     timeout_s=self.timeout_s)
            except Exception as exc:
                if degraded:
                    # circuit-broken AND the cold fallback failed:
                    # nowhere left to serve this request from
                    sp.set("path", "crash_loop")
                    _m_dispatches(app, "crash_loop")
                    raise CrashLoopShed(
                        f"app {app!r} is circuit-broken after "
                        f"{br.failures} zygote boot failures and its "
                        f"cold start failed: {exc}") from exc
                raise
            self.dispatches[app]["cold"] += 1
            sp.set("path", "cold")
            _m_dispatches(app, "cold")
            out = {**m, "path": "cold", "fallback": fallback}
            if degraded:
                out["degraded"] = "crash_loop"
                _m_degraded(app, "crash_loop")
            return out

    def _try_revive(self, app: str, fs: ForkServer) -> bool:
        """One bounded crash-recovery attempt on the dispatch path
        (``revive_on_dispatch=True`` only).  Never raises: a gated or
        failed boot just means this request serves cold.  Genuine boot
        failures feed the app's circuit breaker; ``ForkServerBackoff``
        does not (it is the gate working, not new evidence)."""
        br = self.breakers.get(app)
        try:
            if self.shared_base:
                self.ensure_base()  # re-fork needs a live parent
                fs.base = self.base
            fs.restart(preload=self._app_preload(app))
        except ForkServerBackoff:
            return False
        except ForkServerError as exc:
            self._record_boot_failure(app, exc)
            return False
        self.recoveries["zygote_restarts"] += 1
        if br is not None:
            br.record_success()
        return True

    def _record_boot_failure(self, app: str, exc: Exception) -> None:
        br = self.breakers.get(app)
        if br is None:
            return
        if br.record_failure():
            self.recoveries["breaker_trips"] += 1
            from repro.obs.metrics import default_registry
            default_registry().counter(
                "repro_breaker_trips_total",
                "per-app circuit-breaker trips (app demoted to "
                "cold-path-only after consecutive zygote boot "
                "failures)", labels=("app",)).labels(app=app).inc()

    def replay(self, trace: Trace, *, limit: Optional[int] = None,
               seed0: int = 500, adaptive=None) -> list[dict]:
        """Time-compressed replay: every request dispatches immediately
        (arrival gaps cost nothing; the point is real init latencies
        down the pool vs cold paths).  Returns per-app rows; the full
        schema-versioned ``fleet_summary`` payload of the run lands in
        ``self.last_summary``.

        ``adaptive`` is an optional
        :class:`repro.core.adaptive.AdaptiveLoop` (see
        :meth:`make_adaptive_loop`): every arrival feeds the drift
        detector in *trace time*, sampled dispatches carry the child
        live profiler, and a confirmed-drift re-optimization runs
        between requests — the replay is single-threaded, so the
        defer-set/base hot-swap is shed-free by construction."""
        from repro.obs.tracing import get_tracer
        tracer = get_tracer()
        per_app: dict[str, dict[str, list[float]]] = {}
        n = 0
        for i, req in enumerate(trace):
            if limit is not None and i >= limit:
                break
            lp_cfg = None
            if adaptive is not None:
                lp_cfg = adaptive.observe_request(req.app, req.handler,
                                                  t=req.t)
            with tracer.span("request", app=req.app,
                             handler=req.handler or "") as root:
                m = self.dispatch(req.app, handler=req.handler,
                                  seed=seed0 + i, trace=root.ctx(),
                                  live_profile=lp_cfg)
                root.set("path", m["path"])
            if adaptive is not None:
                adaptive.observe_exec(req.app, m)
            st = per_app.setdefault(
                req.app, {"pool": [], "cold": [], "e2e": []})
            st[m["path"]].append(m["init_ms"])
            st["e2e"].append(m["e2e_cold_ms"])
            n += 1
        if adaptive is not None:
            adaptive.flush(t=trace.duration_s)
        rows = []
        for app, paths in sorted(per_app.items()):
            pool, cold = paths["pool"], paths["cold"]
            rows.append({
                "app": app,
                "requests": len(pool) + len(cold),
                "pool_starts": len(pool),
                "cold_starts": len(cold),
                "cold_ratio": round(len(cold)
                                    / max(len(pool) + len(cold), 1), 4),
                # null, not NaN: these rows land verbatim in the
                # strict-JSON fleet_summary artifact
                "pool_init_ms": round(statistics.fmean(pool), 1)
                if pool else None,
                "cold_init_ms": round(statistics.fmean(cold), 1)
                if cold else None,
                "p50_ms": round(percentile_ms(paths["e2e"], 0.50), 2),
                "p99_ms": round(percentile_ms(paths["e2e"], 0.99), 2),
                "sheds": 0,
                "shed_reasons": {},
                "flushed": 0,
                "queue_wait_p99_ms": 0.0,
            })
        self.last_summary = self._summary_payload(trace.name, per_app,
                                                  rows, n)
        if adaptive is not None:
            self.last_summary["adaptive"] = adaptive.summary()
        return rows

    def _summary_payload(self, trace_name: str,
                         per_app: dict[str, dict[str, list[float]]],
                         rows: list[dict], n: int) -> dict:
        """``fleet_summary`` payload for one synchronous real replay
        (no queueing: dispatch blocks, so sheds/waits are zero — the
        daemon's threaded loop fills those in its own summary)."""
        e2e = [x for paths in per_app.values() for x in paths["e2e"]]
        cold = sum(len(p["cold"]) for p in per_app.values())
        pool = sum(len(p["pool"]) for p in per_app.values())
        return make_fleet_summary_payload(
            source="replay-real",
            requests=n,
            served=n,
            cold_starts=cold,
            p50_ms=round(percentile_ms(e2e, 0.50), 2) if e2e else 0.0,
            p99_ms=round(percentile_ms(e2e, 0.99), 2) if e2e else 0.0,
            sheds=0,
            shed_reasons={},
            flushed=0,
            queue_wait_p50_ms=0.0,
            queue_wait_p99_ms=0.0,
            per_app=rows,
            policy="zygote-fleet",
            trace=trace_name,
            budget_mb=round(self.budget_mb, 1)
            if self.budget_mb is not None else None,
            duration_s=None,
            pool_starts=pool,
            memory_gb_s=None,
            rewarm_ticks=0,
            queue=None,
            zygotes=sorted(self.servers),
            skipped=list(self.skipped),
            used_mb=round(self.used_mb(), 1),
            **self._base_info(),
        )

    # ------------------------------------------------------ adaptive hook
    def make_adaptive_loop(self, config=None, clock=None,
                           fault_hook=None):
        """Wire an :class:`repro.core.adaptive.AdaptiveLoop` to this
        fleet: in-process regeneration analyzes against each app's
        ``libs`` dir, apply goes through :meth:`rewarm` (shed-free
        preload/restart under the per-app protocol lock), and — in
        two-tier mode — a successful round recomputes and hot-swaps the
        shared base via :meth:`maybe_swap_base`.  Deployed reports seed
        the live profiler's baselines (preloaded hot modules never show
        up in child-side import records) and the hit-rate/new-module
        drift signals."""
        from repro.core.adaptive import AdaptiveLoop

        def regenerate(app, profiler):
            app_dir = self.app_dirs.get(app)
            if app_dir is None:
                return None
            return profiler.regenerate(
                app, os.path.join(app_dir, "libs"))

        def hot_sets(app):
            rep = self.reports.get(app)
            if rep is None:
                return (), ()
            return (hot_set_from_report(rep),
                    tuple(rep.defer_targets))

        loop = AdaptiveLoop(
            regenerate_fn=regenerate, apply_fn=self.rewarm,
            swap_fn=self.maybe_swap_base if self.shared_base else None,
            hot_sets_fn=hot_sets, config=config,
            clock=clock or time.monotonic,
            fault_hook=(fault_hook if fault_hook is not None
                        else self.fault_hook))
        for app, rep in self.reports.items():
            loop.profiler.set_baseline(app, rep)
        return loop

    def rewarm(self, report) -> dict:
        """``SlimStartController.rewarm_fn`` for a whole fleet: after a
        re-profile, re-preload the re-profiled app's zygote (rebooting
        it if it died).  An app the budget excluded stays excluded — a
        re-profile is not a budget grant.

        ``report`` is anything :func:`repro.api.as_report` accepts: the
        :class:`OptimizationReport` itself (adaptive loop) or the path
        of a saved versioned report artifact (CLI / CI redeploy)."""
        from repro.api.artifacts import as_report
        report = as_report(report)
        app = report.application
        if app not in self.app_dirs:
            raise KeyError(f"rewarm for unknown app {app!r}")
        self.reports[app] = report
        fs = self.servers.get(app)
        if fs is None:
            return {"ok": True, "app": app, "skipped": True,
                    "preloaded": [], "errors": []}
        br = self.breakers.get(app)
        if br is not None and br.open:
            # circuit-broken: don't burn a boot attempt every tick —
            # the half-open probe after cooldown_s retries for us
            return {"ok": False, "app": app, "skipped": True,
                    "degraded": "crash_loop",
                    "error": f"breaker open after {br.failures} "
                             f"consecutive boot failures"}
        # two-tier crash recovery: a dead zygote re-forks from the
        # base, and a dead *base* is rebooted first so the re-fork has
        # a parent to come from
        was_dead = not fs.alive
        try:
            if self.shared_base and was_dead:
                self.ensure_base()
                fs.base = self.base
            out = fs.rewarm(report)
        except ForkServerBackoff:
            raise  # gated, not a fresh failure: no breaker evidence
        except ForkServerError as exc:
            if was_dead:  # a boot failure, not a preload failure
                self._record_boot_failure(app, exc)
            raise
        if was_dead and out.get("restarted"):
            self.recoveries["zygote_restarts"] += 1
            if br is not None:
                br.record_success()
        return {"app": app, "skipped": False, **out}

    def prewarm_app(self, app: str, report=None) -> dict:
        """Warm-handoff target side: boot ``app``'s zygote *now*,
        ahead of placement flipping to this node, optionally adopting
        the departing owner's report artifact so the zygote pre-imports
        the proven hot set instead of re-learning it.  ``report`` is a
        :class:`~repro.api.artifacts.ReportArtifact` wire payload
        (dict) or anything :func:`repro.api.as_report` accepts.

        A prewarm that cannot boot (budget exhausted, breaker open,
        boot backoff gating) returns ``{"warm": False, ...}`` instead
        of raising — the handoff still happens, just cold."""
        if app not in self.app_dirs:
            raise KeyError(f"prewarm for unknown app {app!r}")
        if report is not None:
            from repro.api.artifacts import ReportArtifact, as_report
            try:
                rep = (ReportArtifact.from_payload(dict(report)).report
                       if isinstance(report, dict)
                       else as_report(report))
            except Exception:
                pass  # bad shipped artifact: warm from what we know
            else:
                self.reports[app] = rep
        br = self.breakers.get(app)
        if br is not None and br.open:
            return {"ok": False, "app": app, "warm": False,
                    "reason": "breaker_open"}
        fs = self.servers.get(app)
        if fs is not None and fs.alive:
            return {"ok": True, "app": app, "warm": True,
                    "already": True}
        if (self.budget_mb is not None
                and self.used_mb() >= self.budget_mb):
            return {"ok": False, "app": app, "warm": False,
                    "reason": "budget"}
        try:
            if self.shared_base and (self.base is None
                                     or not self.base.alive):
                self.ensure_base()
            if fs is None:
                fs = ForkServer(self.app_dirs[app],
                                preload=self._app_preload(app),
                                timeout_s=self.timeout_s,
                                base=self.base,
                                fault_hook=self.fault_hook,
                                boot_backoff_s=self.boot_backoff_s,
                                clock=self._clock)
                fs.start()
                self.servers[app] = fs
            else:
                if self.shared_base:
                    fs.base = self.base
                fs.restart(preload=self._app_preload(app))
        except ForkServerBackoff as exc:
            return {"ok": False, "app": app, "warm": False,
                    "reason": "backoff", "error": str(exc)}
        except ForkServerError as exc:
            self._record_boot_failure(app, exc)
            return {"ok": False, "app": app, "warm": False,
                    "reason": "boot_failed", "error": repr(exc)}
        if app in self.skipped:
            self.skipped.remove(app)
        if app in self.boot_failed:
            self.boot_failed.remove(app)
        if br is not None:
            br.record_success()
        return {"ok": True, "app": app, "warm": fs.alive,
                "already": False}

    def rewarm_from_dir(self, reports_dir: str) -> dict:
        """Daemon rewarm tick: re-load every ``<app>.json`` report
        artifact under ``reports_dir`` (e.g. regenerated by an external
        ``python -m repro profile`` / ``ci-check --out`` run) and
        re-preload the matching zygotes.  Apps without a saved report
        are untouched; per-app rewarm failures are reported, never
        raised — a stale zygote beats a dead serve loop.

        In two-tier mode the tick then recomputes the cross-app shared
        hot set from the refreshed reports and, when it changed (or the
        base died), **hot-swaps the base**: a new base boots alongside
        the old one and every app zygote is re-forked onto it one at a
        time under its own protocol lock, so in-flight execs finish on
        the old tier and queued dispatches land on the new — nothing is
        shed."""
        out: dict[str, dict] = {}
        for app in sorted(self.app_dirs):
            path = os.path.join(reports_dir, f"{app}.json")
            if not os.path.exists(path):
                continue
            try:
                out[app] = self.rewarm(path)
            except Exception as exc:
                out[app] = {"ok": False, "app": app,
                            "error": repr(exc)}
        if self.shared_base:
            try:
                out["_base"] = self.maybe_swap_base()
            except Exception as exc:
                out["_base"] = {"ok": False, "error": repr(exc)}
        return out

    def maybe_swap_base(self) -> dict:
        """Recompute the shared hot set; hot-swap the base if it grew,
        shrank, or died.  Old app zygotes keep serving until their
        replacement is spawned (per-app lock handoff), so the swap
        drops no in-flight or queued work."""
        if not self.shared_base:
            return {"ok": True, "swapped": False}
        fresh = self._compute_shared()
        base_dead = self.base is None or not self.base.alive
        unchanged = (self.shared is not None
                     and fresh.modules == self.shared.modules)
        if unchanged and not base_dead:
            self.shared = fresh  # deltas may still have moved
            return {"ok": True, "swapped": False,
                    "modules": list(fresh.modules)}
        old_base = self.base  # dead or alive: stop() also cleans rundir
        new_base = BaseZygote(
            preload=fresh.modules,
            search_paths=shared_search_paths(self.app_dirs),
            timeout_s=self.timeout_s, fault_hook=self.fault_hook,
            boot_backoff_s=self.boot_backoff_s, clock=self._clock)
        new_base.start()
        if base_dead:
            self.recoveries["base_reboots"] += 1
        self.shared = fresh
        errors: dict[str, str] = {}
        for app, fs in self.servers.items():
            try:
                fs.rebase(new_base, preload=self._app_preload(app))
            except ForkServerBackoff as exc:
                errors[app] = str(exc)  # gated: retry next tick
            except ForkServerError as exc:
                errors[app] = str(exc)
                self._record_boot_failure(app, exc)
        self.base = new_base
        self.base_swaps += 1
        from repro.obs.metrics import default_registry
        default_registry().counter(
            "repro_base_swaps_total",
            "shared-base zygote hot-swaps (rewarm tick)").inc()
        if old_base is not None:
            old_base.stop()
        return {"ok": not errors, "swapped": True,
                "modules": list(fresh.modules), "errors": errors}
