"""Warm-pool subsystem: amortizing initialization across instances.

Six pieces (see each module's docstring and this package's README.md):

* :mod:`repro.pool.forkserver` — profile-guided zygote that pre-imports
  the measured hot set and forks handler instances copy-on-write; in
  two-tier mode a single ``BaseZygote`` holds the cross-app shared hot
  set and per-app zygotes are forked from it;
* :mod:`repro.pool.sharing`    — computes that cross-app shared hot
  set (and each app's private delta) by intersecting deployed
  ``optimization_report`` artifacts;
* :mod:`repro.pool.policies`   — keep-alive / pool-sizing policies,
  including the profile-guided one fed by ``OptimizationReport``;
* :mod:`repro.pool.trace`      — synthetic invocation traces (poisson,
  diurnal, bursty, handler-skewed) plus Azure Functions-style
  multi-app traces (per-minute counts, heavy-tailed app popularity),
  replayable in simulation and against the real harness;
* :mod:`repro.pool.simulator`  — single-app trace-driven simulator
  reporting cold-start ratio, p50/p99 latency and memory GB-seconds;
* :mod:`repro.pool.fleet`      — multi-app fleet manager: one zygote
  per app under a shared memory budget, prewarm/evict arbitration
  (simulated ``FleetManager`` and real-process ``ZygoteFleet``);
* :mod:`repro.pool.chaos`      — seeded fault injection across the
  serving path (``FaultPlan`` / ``FaultInjector``), paired with the
  crash-recovery hardening in the fleet: boot backoff, per-app
  circuit breakers, drain accounting.
"""

from repro.pool.chaos import (
    FaultEvent,
    FaultInjector,
    FaultPlan,
    chaos_report_payload,
)
from repro.pool.daemon import (
    FleetDaemon,
    RealFleetBackend,
    SimFleetBackend,
)
from repro.pool.fleet import (
    BreakerConfig,
    CircuitBreaker,
    CrashLoopShed,
    FleetManager,
    FleetSummary,
    QueueConfig,
    ZygoteFleet,
    fleet_sweep,
)
from repro.pool.forkserver import (
    BaseZygote,
    ForkServer,
    ForkServerBackoff,
    ForkServerError,
    ForkServerTimeout,
)
from repro.pool.policies import (
    FixedSizePolicy,
    HistogramPolicy,
    IdleTimeoutPolicy,
    KeepAlivePolicy,
    ProfileGuidedPolicy,
    default_policies,
    hot_set_from_report,
)
from repro.pool.sharing import (
    SharedHotSet,
    compute_shared_hot_set,
    intersect_hot_sets,
    shared_search_paths,
)
from repro.pool.simulator import (
    AppProfile,
    FleetReport,
    FleetSimulator,
    PercentilePool,
    sweep,
)
from repro.pool.trace import (
    AzureRow,
    Request,
    Trace,
    azure_synthetic_rows,
    azure_trace,
    bursty_trace,
    diurnal_trace,
    handler_skewed_trace,
    load_azure_csv,
    poisson_trace,
    standard_traces,
    trace_from_azure_rows,
    write_azure_csv,
)

__all__ = [
    "AppProfile",
    "AzureRow",
    "BaseZygote",
    "BreakerConfig",
    "CircuitBreaker",
    "CrashLoopShed",
    "FaultEvent",
    "FaultInjector",
    "FaultPlan",
    "FixedSizePolicy",
    "FleetDaemon",
    "FleetManager",
    "FleetReport",
    "FleetSimulator",
    "FleetSummary",
    "ForkServer",
    "ForkServerBackoff",
    "ForkServerError",
    "ForkServerTimeout",
    "HistogramPolicy",
    "IdleTimeoutPolicy",
    "KeepAlivePolicy",
    "PercentilePool",
    "ProfileGuidedPolicy",
    "QueueConfig",
    "RealFleetBackend",
    "Request",
    "SharedHotSet",
    "SimFleetBackend",
    "Trace",
    "ZygoteFleet",
    "azure_synthetic_rows",
    "azure_trace",
    "bursty_trace",
    "chaos_report_payload",
    "compute_shared_hot_set",
    "default_policies",
    "diurnal_trace",
    "fleet_sweep",
    "handler_skewed_trace",
    "hot_set_from_report",
    "intersect_hot_sets",
    "load_azure_csv",
    "poisson_trace",
    "shared_search_paths",
    "standard_traces",
    "sweep",
    "trace_from_azure_rows",
    "write_azure_csv",
]
