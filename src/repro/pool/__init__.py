"""Warm-pool subsystem: amortizing initialization across instances.

Four pieces (see each module's docstring):

* :mod:`repro.pool.forkserver` — profile-guided zygote that pre-imports
  the measured hot set and forks handler instances copy-on-write;
* :mod:`repro.pool.policies`   — keep-alive / pool-sizing policies,
  including the profile-guided one fed by ``OptimizationReport``;
* :mod:`repro.pool.trace`      — synthetic invocation traces (poisson,
  diurnal, bursty, handler-skewed) replayable in simulation and against
  the real harness;
* :mod:`repro.pool.simulator`  — trace-driven fleet simulator reporting
  cold-start ratio, p50/p99 latency and memory GB-seconds per policy.
"""

from repro.pool.forkserver import ForkServer, ForkServerError
from repro.pool.policies import (
    FixedSizePolicy,
    HistogramPolicy,
    IdleTimeoutPolicy,
    KeepAlivePolicy,
    ProfileGuidedPolicy,
    default_policies,
    hot_set_from_report,
)
from repro.pool.simulator import AppProfile, FleetReport, FleetSimulator, sweep
from repro.pool.trace import (
    Request,
    Trace,
    bursty_trace,
    diurnal_trace,
    handler_skewed_trace,
    poisson_trace,
    standard_traces,
)

__all__ = [
    "AppProfile",
    "FixedSizePolicy",
    "FleetReport",
    "FleetSimulator",
    "ForkServer",
    "ForkServerError",
    "HistogramPolicy",
    "IdleTimeoutPolicy",
    "KeepAlivePolicy",
    "ProfileGuidedPolicy",
    "Request",
    "Trace",
    "bursty_trace",
    "default_policies",
    "diurnal_trace",
    "handler_skewed_trace",
    "hot_set_from_report",
    "poisson_trace",
    "standard_traces",
    "sweep",
]
