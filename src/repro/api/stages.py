"""Composable pipeline stages for the SLIMSTART workflow.

The paper's Fig. 4 loop — deploy → profile → analyze → optimize →
re-measure — plus the warm-pool extensions become five reusable stages
over one shared :class:`RunContext`:

    ProfileStage   run N profiled cold instances into the sink
    AnalyzeStage   merge shards → OptimizationReport (saved as a
                   versioned artifact, see :mod:`repro.api.artifacts`)
    OptimizeStage  AST deferred-import rewrite of a fresh deployment
                   variant (profile-guided or static-reachability)
    WarmStage      boot a profile-guided zygote and measure fork-pool
                   starts against it
    ReplayStage    re-measure baseline vs optimized cold starts, or
                   replay an invocation trace through a real zygote
    ServeStage     drive the fleet daemon (bounded queues, rewarm
                   timer) over a trace; emit a fleet_summary artifact

A stage is anything with a ``name`` and ``run(ctx)`` (see
:class:`Stage`); the :class:`~repro.api.facade.SlimStart` facade chains
them.  The module-level helpers (``profile_app``, ``analyze_sink``,
``apply_defer_targets``, ...) are the stage bodies, importable on their
own — ``repro.benchsuite.pipeline`` re-exports them for legacy callers.
"""

from __future__ import annotations

import os
import shutil
import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Optional, Protocol, runtime_checkable

from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import (
    ColdStartStats,
    measure_cold_starts,
    measure_pool_starts,
    run_instance,
)
from repro.core.optimizer.ast_transform import optimize_file, restore_file
from repro.core.optimizer.static_baseline import StaticReachability
from repro.core.profiler.cct import CCT
from repro.core.profiler.collector import read_shards
from repro.core.profiler.import_timer import ImportTimer
from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import (
    AnalyzerConfig,
    ModuleMapper,
    UtilizationAnalyzer,
)


# ---------------------------------------------------------------------------
# Shared context
# ---------------------------------------------------------------------------

@dataclass
class RunContext:
    """Everything the stages read and write for one app's workflow.

    Paths follow the benchsuite layout: the deployed baseline lives in
    ``<root>/apps/<app>``, profile shards in ``<root>/profiles/<app>``,
    the versioned report artifact in ``<root>/reports/<app>.json`` and
    the optimized deployment copy in ``<root>/variants/<app>/<variant>``.
    """

    app: str
    root: str
    variant: str = "slimstart"
    app_dir: str = ""
    sink: str = ""
    report_path: str = ""
    variant_dir: str = ""
    report: Optional[OptimizationReport] = None
    apply_summary: dict = field(default_factory=dict)
    stats: dict[str, ColdStartStats] = field(default_factory=dict)
    results: dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        self.app_dir = self.app_dir or os.path.join(
            self.root, "apps", self.app)
        self.sink = self.sink or os.path.join(
            self.root, "profiles", self.app)
        self.report_path = self.report_path or os.path.join(
            self.root, "reports", f"{self.app}.json")
        self.variant_dir = self.variant_dir or os.path.join(
            self.root, "variants", self.app, self.variant)

    @classmethod
    def for_app(cls, app: str, root: Optional[str] = None,
                variant: str = "slimstart") -> "RunContext":
        return cls(app=app, root=root or build_suite(), variant=variant)

    def require_report(self) -> OptimizationReport:
        """The in-memory report, loading the saved artifact on demand."""
        if self.report is None:
            from repro.api.artifacts import load_report
            if not os.path.exists(self.report_path):
                raise FileNotFoundError(
                    f"no report for {self.app!r}: run ProfileStage + "
                    f"AnalyzeStage first (looked in {self.report_path})")
            self.report = load_report(self.report_path)
        return self.report


@runtime_checkable
class Stage(Protocol):
    """One step of the workflow: mutate the context, record results."""

    name: str

    def run(self, ctx: RunContext) -> None: ...


# ---------------------------------------------------------------------------
# Profiling + analysis helpers (stage bodies)
# ---------------------------------------------------------------------------

def profile_app(app_dir: str, sink: str, *, instances: int = 4,
                invocations: int = 150, seed0: int = 1000,
                sample_interval: float = 0.002) -> None:
    """Run ``instances`` profiled cold instances (sample aggregation
    across invocations, paper TC-1 strategy 2)."""
    os.makedirs(sink, exist_ok=True)
    for i in range(instances):
        run_instance(app_dir, invocations=invocations, seed=seed0 + i,
                     profile=True, sink=sink,
                     sample_interval=sample_interval)


def _merge_import_timers(dicts: list[dict]) -> ImportTimer:
    """Mean-merge per-module init times across instances."""
    sums: dict[str, dict] = {}
    counts: dict[str, int] = {}
    for d in dicts:
        for name, rec in d.items():
            if name not in sums:
                sums[name] = dict(rec)
                counts[name] = 1
            else:
                sums[name]["self_s"] += rec["self_s"]
                sums[name]["cumulative_s"] += rec["cumulative_s"]
                counts[name] += 1
    for name, rec in sums.items():
        rec["self_s"] /= counts[name]
        rec["cumulative_s"] /= counts[name]
    return ImportTimer.from_dict(sums)


def analyze_sink(app_name: str, sink: str, libs_dir: str,
                 config: AnalyzerConfig | None = None) -> OptimizationReport:
    """Merge profile shards and produce the optimization report."""
    records = [r for r in read_shards(sink) if r.get("app")]
    if not records:
        raise RuntimeError(f"no profile shards in {sink}")
    timer = _merge_import_timers([r["init_records"] for r in records])
    cct = CCT()
    for r in records:
        cct.merge(CCT.from_dict(r["cct"]))
    cct.escalate()
    e2e = statistics.fmean(r["e2e_cold_s"] for r in records)
    mapper = ModuleMapper((libs_dir,))
    analyzer = UtilizationAnalyzer(timer, cct, mapper, e2e_s=e2e,
                                   config=config)
    return OptimizationReport.from_analyzer(app_name, analyzer)


# ---------------------------------------------------------------------------
# Deployment rewrite helpers (stage bodies)
# ---------------------------------------------------------------------------

def _deployment_py_files(deploy_dir: str):
    libs_dir = os.path.join(deploy_dir, "libs")
    yield os.path.join(deploy_dir, "handler.py"), "handler", False
    for dirpath, _dirs, files in os.walk(libs_dir):
        for fn in files:
            if not fn.endswith(".py") or fn.endswith(".orig"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, libs_dir)[:-3]
            parts = rel.split(os.sep)
            is_pkg = parts[-1] == "__init__"
            if is_pkg:
                parts = parts[:-1]
            yield path, ".".join(parts), is_pkg


def apply_defer_targets(deploy_dir: str,
                        targets_by_module: dict[str, list[str]] | None = None,
                        global_targets: list[str] | None = None) -> dict:
    """Rewrite a deployment in place.

    ``global_targets`` (SLIMSTART): every file is rewritten against the
    full target list.  ``targets_by_module`` (static baseline): each
    module only defers its own provably-dead imports.
    """
    summary = {"files_changed": 0, "deferred": 0, "skipped": 0}
    for path, module_name, is_pkg in _deployment_py_files(deploy_dir):
        if global_targets is not None:
            targets = global_targets
        else:
            targets = (targets_by_module or {}).get(module_name, [])
        if not targets:
            continue
        res = optimize_file(path, targets, module_name=module_name)
        if res.changed:
            summary["files_changed"] += 1
        summary["deferred"] += len(res.deferred)
        summary["skipped"] += len(res.skipped)
    return summary


def fresh_variant(base_dir: str, variant_dir: str) -> str:
    """(Re)copy the deployed baseline into a variant directory."""
    if os.path.isdir(variant_dir):
        shutil.rmtree(variant_dir)
    os.makedirs(os.path.dirname(variant_dir), exist_ok=True)
    shutil.copytree(base_dir, variant_dir)
    return variant_dir


def restore_deployment(deploy_dir: str) -> dict:
    """Undo :func:`apply_defer_targets`: restore every ``.orig`` backup
    under ``deploy_dir`` (handler + vendored libs)."""
    restored = 0
    for dirpath, _dirs, files in os.walk(deploy_dir):
        for fn in files:
            if fn.endswith(".orig"):
                if restore_file(os.path.join(dirpath, fn[:-5])):
                    restored += 1
    return {"restored": restored}


def static_defer_targets(app_dir: str) -> dict[str, list[str]]:
    """FaaSLight-style static reachability defer set (per module)."""
    libs_dir = os.path.join(app_dir, "libs")
    static = StaticReachability([libs_dir])
    static.add_module(os.path.join(app_dir, "handler.py"), "handler")
    return static.unreachable_imports("handler")


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------

@dataclass
class ProfileStage:
    """Run profiled cold instances into the context's sink."""

    instances: int = 4
    invocations: int = 150
    seed0: int = 1000
    sample_interval: float = 0.002
    fresh: bool = True
    name: str = "profile"

    def run(self, ctx: RunContext) -> None:
        if self.fresh and os.path.isdir(ctx.sink):
            shutil.rmtree(ctx.sink)
        profile_app(ctx.app_dir, ctx.sink, instances=self.instances,
                    invocations=self.invocations, seed0=self.seed0,
                    sample_interval=self.sample_interval)
        ctx.results[self.name] = {"instances": self.instances,
                                  "invocations": self.invocations,
                                  "sink": ctx.sink}


@dataclass
class AnalyzeStage:
    """Merge profile shards into the report; save the versioned artifact."""

    config: Optional[AnalyzerConfig] = None
    save: bool = True
    name: str = "analyze"

    def run(self, ctx: RunContext) -> None:
        libs_dir = os.path.join(ctx.app_dir, "libs")
        ctx.report = analyze_sink(ctx.app, ctx.sink, libs_dir,
                                  config=self.config)
        out = {"defer_targets": list(ctx.report.defer_targets),
               "qualifies": ctx.report.qualifies}
        if self.save:
            from repro.api.artifacts import save_report
            meta = dict(ctx.results.get("profile") or {})
            meta.pop("sink", None)
            save_report(ctx.report, ctx.report_path, meta=meta)
            out["report_path"] = ctx.report_path
        ctx.results[self.name] = out


@dataclass
class OptimizeStage:
    """Apply deferred-import rewrites to a fresh deployment variant.

    ``mode="profile"`` uses the report's defer targets (the paper's
    tool); ``mode="static"`` uses FaaSLight-style static reachability
    and needs no profile at all.
    """

    mode: str = "profile"
    name: str = "optimize"

    def run(self, ctx: RunContext) -> None:
        if self.mode not in ("profile", "static"):
            raise ValueError(f"unknown OptimizeStage mode {self.mode!r}")
        fresh_variant(ctx.app_dir, ctx.variant_dir)
        if self.mode == "static":
            ctx.apply_summary = apply_defer_targets(
                ctx.variant_dir,
                targets_by_module=static_defer_targets(ctx.app_dir))
        else:
            report = ctx.require_report()
            ctx.apply_summary = apply_defer_targets(
                ctx.variant_dir, global_targets=report.defer_targets)
        ctx.results[self.name] = {"mode": self.mode,
                                  "variant_dir": ctx.variant_dir,
                                  **ctx.apply_summary}


@dataclass
class WarmStage:
    """Boot a profile-guided zygote; measure fork-pool starts from it."""

    n: int = 5
    invocations: int = 1
    use_variant: bool = False
    name: str = "warm"

    def run(self, ctx: RunContext) -> None:
        from repro.pool.policies import hot_set_from_report
        report = ctx.require_report()
        app_dir = (ctx.variant_dir if self.use_variant
                   and os.path.isdir(ctx.variant_dir) else ctx.app_dir)
        stats = measure_pool_starts(
            app_dir, n=self.n, invocations=self.invocations,
            preload=hot_set_from_report(report))
        ctx.stats["pool"] = stats
        ctx.results[self.name] = stats.summary()


@dataclass
class ReplayStage:
    """Re-measure the optimization (paper's last Fig. 4 arrow).

    Without a trace: ``n_cold`` fresh cold starts of the baseline and
    the optimized variant, recording the measured init/e2e speedups.
    With a trace (a :class:`repro.pool.trace.Trace`): replay it through
    a real single-app :class:`~repro.pool.fleet.ZygoteFleet` backed by
    the optimized variant, recording pool vs cold dispatch rows.
    """

    n_cold: int = 5
    invocations: int = 1
    trace: Optional[Any] = None
    limit: Optional[int] = None
    name: str = "replay"

    def run(self, ctx: RunContext) -> None:
        if self.trace is not None:
            self._replay_trace(ctx)
            return
        base = measure_cold_starts(ctx.app_dir, n=self.n_cold,
                                   invocations=self.invocations)
        target = (ctx.variant_dir if os.path.isdir(ctx.variant_dir)
                  else ctx.app_dir)
        opt = measure_cold_starts(target, n=self.n_cold,
                                  invocations=self.invocations)
        ctx.stats["baseline"] = base
        ctx.stats["optimized"] = opt
        ctx.results[self.name] = {
            "init_speedup": base.init_mean / max(opt.init_mean, 1e-9),
            "e2e_speedup": base.e2e_mean / max(opt.e2e_mean, 1e-9),
            "base_init_ms": base.init_mean,
            "opt_init_ms": opt.init_mean,
        }

    def _replay_trace(self, ctx: RunContext) -> None:
        from repro.pool.fleet import ZygoteFleet
        target = (ctx.variant_dir if os.path.isdir(ctx.variant_dir)
                  else ctx.app_dir)
        reports = {}
        if ctx.report is not None or os.path.exists(ctx.report_path):
            reports[ctx.app] = ctx.require_report()
        with ZygoteFleet({ctx.app: target}, reports=reports) as fleet:
            rows = fleet.replay(self.trace, limit=self.limit)
        ctx.results[self.name] = {"trace": self.trace.name, "rows": rows}


@dataclass
class ServeStage:
    """Serve a trace through the fleet daemon — the continuous loop
    (bounded queues with backpressure, optional rewarm timer) run
    one-shot inside a pipeline, emitting the same schema-versioned
    ``fleet_summary`` artifact ``python -m repro fleet serve`` does.

    ``sim=True`` drives a :class:`~repro.pool.fleet.FleetManager` from
    the app's measured stats when earlier stages produced them
    (``ctx.stats["baseline"]`` / ``ctx.stats["pool"]``), falling back
    to generic latencies; ``sim=False`` boots a real single-app
    :class:`~repro.pool.fleet.ZygoteFleet` on the optimized variant
    (or the baseline deployment when no variant exists).
    """

    trace: Optional[Any] = None  # Trace object; None = synthetic poisson
    sim: bool = True
    queue_depth: int = 16
    max_concurrency: int = 4
    shed_policy: str = "reject-new"
    rewarm_interval_s: float = 0.0
    rate_per_s: float = 2.0
    duration_s: float = 60.0
    budget_mb: float = 512.0
    save: bool = True
    name: str = "serve"

    def _sim_profile(self, ctx: RunContext):
        from repro.pool.simulator import AppProfile
        cold = ctx.stats.get("baseline") or ctx.stats.get("optimized")
        pool = ctx.stats.get("pool")
        if cold is not None:
            return AppProfile.from_stats(cold, pool)
        return AppProfile(app=ctx.app, cold_init_ms=400.0,
                          warm_init_ms=40.0, invoke_ms=30.0,
                          rss_mb=128.0, zygote_rss_mb=96.0)

    def run(self, ctx: RunContext) -> None:
        from repro.pool.daemon import (
            FleetDaemon, RealFleetBackend, SimFleetBackend,
        )
        from repro.pool.fleet import FleetManager, QueueConfig, ZygoteFleet
        from repro.pool.policies import ProfileGuidedPolicy
        from repro.pool.trace import poisson_trace

        trace = self.trace or poisson_trace(
            ctx.app, rate_per_s=self.rate_per_s,
            duration_s=self.duration_s, name="poisson")
        queue = QueueConfig(depth=self.queue_depth,
                            max_concurrency=self.max_concurrency,
                            shed_policy=self.shed_policy)
        have_report = (ctx.report is not None
                       or os.path.exists(ctx.report_path))
        if self.sim:
            policy = ProfileGuidedPolicy()
            if have_report:
                policy.add_report(ctx.require_report())
            manager = FleetManager({ctx.app: self._sim_profile(ctx)},
                                   policy, budget_mb=self.budget_mb,
                                   queue=queue)
            backend = SimFleetBackend(
                manager, reports_dir=os.path.dirname(ctx.report_path))
        else:
            target = (ctx.variant_dir if os.path.isdir(ctx.variant_dir)
                      else ctx.app_dir)
            reports = ({ctx.app: ctx.require_report()} if have_report
                       else {})
            fleet = ZygoteFleet({ctx.app: target},
                                budget_mb=self.budget_mb,
                                reports=reports)
            backend = RealFleetBackend(
                fleet, queue=queue,
                reports_dir=os.path.dirname(ctx.report_path))
        summary_path = None
        if self.save:
            summary_path = os.path.join(ctx.root, "fleet",
                                        f"{ctx.app}.summary.json")
        daemon = FleetDaemon(backend,
                             rewarm_interval_s=self.rewarm_interval_s,
                             summary_path=summary_path)
        daemon.start(trace.name)
        payload = daemon.run_trace(trace)
        if summary_path:
            payload["artifact_path"] = summary_path
        ctx.results[self.name] = payload
