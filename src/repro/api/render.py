"""Small text-rendering helpers shared by the CLI and the benchmarks.

One implementation of the column-aligned table every surface prints —
``benchmarks/common.py`` re-exports these so the bench scripts and
``python -m repro`` cannot drift apart.
"""

from __future__ import annotations

from typing import Sequence


def fmt_cell(v: object) -> str:
    if isinstance(v, float):
        return f"{v:.2f}"
    return str(v)


def table(rows: Sequence[dict], cols: Sequence[str],
          title: str = "") -> str:
    """Render list-of-dict ``rows`` as a column-aligned text table."""
    out = [f"== {title} =="] if title else []
    widths = {c: max(len(c), *(len(fmt_cell(r.get(c))) for r in rows))
              for c in cols} if rows else {c: len(c) for c in cols}
    out.append("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        out.append("  ".join(fmt_cell(r.get(c)).ljust(widths[c])
                             for c in cols))
    return "\n".join(out)
