"""Small text-rendering helpers shared by the CLI and the benchmarks.

One implementation of the column-aligned table every surface prints —
``benchmarks/common.py`` re-exports these so the bench scripts and
``python -m repro`` cannot drift apart.  Also home of the CLI-reference
markdown generator behind ``python -m repro docs``: it walks the live
argparse tree, so ``docs/cli.md`` can never drift from the real CLI
(CI regenerates and diffs it).
"""

from __future__ import annotations

import argparse
from typing import Iterator, Sequence


def fmt_cell(v: object) -> str:
    if isinstance(v, float):
        return f"{v:.2f}"
    return str(v)


def table(rows: Sequence[dict], cols: Sequence[str],
          title: str = "") -> str:
    """Render list-of-dict ``rows`` as a column-aligned text table."""
    out = [f"== {title} =="] if title else []
    widths = {c: max(len(c), *(len(fmt_cell(r.get(c))) for r in rows))
              for c in cols} if rows else {c: len(c) for c in cols}
    out.append("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        out.append("  ".join(fmt_cell(r.get(c)).ljust(widths[c])
                             for c in cols))
    return "\n".join(out)


# ---------------------------------------------------------------------------
# CLI reference generation (python -m repro docs)
# ---------------------------------------------------------------------------

def _subparser_actions(parser: argparse.ArgumentParser
                       ) -> list[argparse._SubParsersAction]:
    return [a for a in parser._actions
            if isinstance(a, argparse._SubParsersAction)]


def _walk_commands(parser: argparse.ArgumentParser, prefix: tuple[str, ...]
                   = ()) -> Iterator[tuple[tuple[str, ...],
                                           argparse.ArgumentParser, str]]:
    """Yield ``(command path, parser, help)`` depth-first, in the order
    subcommands were registered (deterministic: pure code order)."""
    for spa in _subparser_actions(parser):
        helps = {ca.dest: (ca.help or "") for ca in spa._choices_actions}
        for name, sub in spa.choices.items():
            path = prefix + (name,)
            yield path, sub, helps.get(name, "")
            yield from _walk_commands(sub, path)


def _escape_md(text: str) -> str:
    return (text or "").replace("|", "\\|").replace("\n", " ").strip()


def _default_repr(action: argparse.Action) -> str:
    if action.default is None or action.default is argparse.SUPPRESS:
        return ""
    if isinstance(action.default, bool):
        return ""  # store_true flags: the default is the absence
    return f"`{action.default}`"


def _option_cell(action: argparse.Action) -> str:
    opts = ", ".join(f"`{o}`" for o in action.option_strings)
    if action.nargs == 0:
        return opts
    metavar = action.metavar or action.dest.upper()
    return f"{opts} `{metavar}`"


def cli_reference_markdown(parser: argparse.ArgumentParser) -> str:
    """Render the whole subcommand tree as one markdown page."""
    lines = [
        "# `python -m repro` — CLI reference",
        "",
        "<!-- GENERATED FILE: regenerate with `python -m repro docs` "
        "(CI fails on drift; see .github/workflows/ci.yml). -->",
        "",
        _escape_md(parser.description or ""),
        "",
        "Exit codes: `0` ok / check passed, `1` ci-check divergence, "
        "`2` usage or artifact errors.",
    ]
    for path, sub, help_text in _walk_commands(parser):
        cmd = " ".join(path)
        lines += ["", f"## `python -m repro {cmd}`", ""]
        desc = sub.description or help_text
        if desc:
            lines += [_escape_md(desc), ""]
        positionals = [a for a in sub._actions
                       if not a.option_strings
                       and not isinstance(a, argparse._SubParsersAction)]
        options = [a for a in sub._actions
                   if a.option_strings and "-h" not in a.option_strings]
        if positionals:
            lines += ["| argument | description |", "|---|---|"]
            for a in positionals:
                name = a.metavar or a.dest
                lines.append(f"| `{name}` | {_escape_md(a.help)} |")
            lines.append("")
        if options:
            lines += ["| option | default | description |", "|---|---|---|"]
            for a in options:
                lines.append(f"| {_option_cell(a)} | {_default_repr(a)} "
                             f"| {_escape_md(a.help)} |")
            lines.append("")
        spas = _subparser_actions(sub)
        if spas:
            subs = ", ".join(f"[`{cmd} {n}`](#python--m-repro-"
                             f"{'-'.join(path + (n,))})"
                             for spa in spas for n in spa.choices)
            lines += [f"Subcommands: {subs}", ""]
    while lines and lines[-1] == "":
        lines.pop()
    return "\n".join(lines) + "\n"
