"""Public SLIMSTART API: versioned artifacts, stages, and the facade.

Three layers, one import::

    from repro import api

* **Artifacts** — every file the workflow exchanges is schema-versioned
  JSON with atomic writes and a v1 migration path
  (:mod:`repro.api.artifact` machinery, :mod:`repro.api.artifacts`
  kinds).  Typed helpers: :func:`save_report` / :func:`load_report`,
  :func:`save_trace` / :func:`load_trace`, :func:`save_stats` /
  :func:`load_stats`, :func:`save_bench_result` /
  :func:`load_bench_result`; :func:`as_report` normalizes
  report-or-path arguments for ``rewarm``-style hooks.
* **Stages** — :class:`ProfileStage` → :class:`AnalyzeStage` →
  :class:`OptimizeStage` → :class:`WarmStage` → :class:`ReplayStage`
  over one :class:`RunContext` (:mod:`repro.api.stages`).
* **Facade** — :class:`SlimStart` chains stages;
  ``python -m repro`` exposes the same workflow as a CLI.
"""

from repro.api.artifact import (
    Artifact,
    ArtifactError,
    atomic_write_json,
    load_any,
    peek,
    registered_kinds,
)
from repro.api.artifacts import (
    BenchResultArtifact,
    ChaosReportArtifact,
    ClusterSummaryArtifact,
    ColdStartStatsArtifact,
    DriftReportArtifact,
    FleetSummaryArtifact,
    ReportArtifact,
    SharedHotSetArtifact,
    TraceArtifact,
    TraceEventsArtifact,
    as_report,
    load_bench_result,
    load_chaos_report,
    load_cluster_summary,
    load_drift_report,
    load_fleet_summary,
    load_report,
    load_report_meta,
    load_shared_hot_set,
    load_stats,
    load_trace,
    load_trace_events,
    save_bench_result,
    save_chaos_report,
    save_cluster_summary,
    save_drift_report,
    save_fleet_summary,
    save_report,
    save_shared_hot_set,
    save_stats,
    save_trace,
    save_trace_events,
)
from repro.api.facade import SlimStart
from repro.api.stages import (
    AnalyzeStage,
    OptimizeStage,
    ProfileStage,
    ReplayStage,
    RunContext,
    ServeStage,
    Stage,
    WarmStage,
    analyze_sink,
    apply_defer_targets,
    fresh_variant,
    profile_app,
    restore_deployment,
    static_defer_targets,
)

__all__ = [
    "AnalyzeStage",
    "Artifact",
    "ArtifactError",
    "BenchResultArtifact",
    "ChaosReportArtifact",
    "ClusterSummaryArtifact",
    "ColdStartStatsArtifact",
    "DriftReportArtifact",
    "FleetSummaryArtifact",
    "OptimizeStage",
    "ProfileStage",
    "ReplayStage",
    "ReportArtifact",
    "RunContext",
    "ServeStage",
    "SharedHotSetArtifact",
    "SlimStart",
    "Stage",
    "TraceArtifact",
    "TraceEventsArtifact",
    "WarmStage",
    "analyze_sink",
    "apply_defer_targets",
    "as_report",
    "atomic_write_json",
    "fresh_variant",
    "load_any",
    "load_bench_result",
    "load_chaos_report",
    "load_cluster_summary",
    "load_drift_report",
    "load_fleet_summary",
    "load_report",
    "load_report_meta",
    "load_shared_hot_set",
    "load_stats",
    "load_trace",
    "load_trace_events",
    "peek",
    "profile_app",
    "registered_kinds",
    "restore_deployment",
    "save_bench_result",
    "save_chaos_report",
    "save_cluster_summary",
    "save_drift_report",
    "save_fleet_summary",
    "save_report",
    "save_shared_hot_set",
    "save_stats",
    "save_trace",
    "save_trace_events",
    "static_defer_targets",
]
