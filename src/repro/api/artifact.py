"""Schema-versioned artifact machinery for the SLIMSTART public API.

Every file the workflow passes between stages — optimization reports,
invocation traces, cold-start measurements, benchmark results — is an
*artifact*: a JSON document wrapped in a two-key envelope::

    {"kind": "optimization_report", "schema_version": 2, ...payload...}

The envelope buys three properties the raw ``to_dict()`` dumps of the
seed repo lacked:

* **versioning** — consumers (pool, fleet, serving, CI) declare which
  schema they understand; a file written by a newer producer fails
  loudly instead of being half-parsed;
* **migration** — a v1 (including legacy *unversioned*) file loads
  through a chain of ``migrate_v{N}`` hooks with a
  :class:`DeprecationWarning`, so old profiler output keeps working;
* **atomicity** — ``save`` writes a temp file in the destination
  directory and ``os.replace``\\ s it, so a crashed profiler run can
  never leave a truncated JSON for the fleet to load.

Subclass :class:`Artifact`, set ``kind`` / ``schema_version`` /
``required_keys`` (and optionally ``optional_keys``), implement
``to_payload`` / ``from_payload``, and add ``migrate_v{N}``
classmethods that lift a version-``N`` payload to ``N+1``.  Concrete
artifact types live in :mod:`repro.api.artifacts`.
"""

from __future__ import annotations

import json
import os
import tempfile
import warnings
from typing import Any, Callable, ClassVar, Optional

ENVELOPE_KEYS = ("kind", "schema_version")


class ArtifactError(ValueError):
    """A file failed to load as the requested artifact.

    Always carries the offending path so fleet operators see *which*
    report/trace is bad, not just that one is.
    """

    def __init__(self, path: str, detail: str) -> None:
        self.path = path
        self.detail = detail
        super().__init__(f"{path}: {detail}")


def atomic_write_json(path: str, obj: Any, *, indent: int = 2) -> None:
    """Serialize ``obj`` to ``path`` via temp-file + rename.

    The temp file lives in the destination directory so the final
    ``os.replace`` is atomic on POSIX (same filesystem); readers either
    see the old file or the complete new one, never a torn write.
    """
    dirname = os.path.dirname(os.path.abspath(path))
    os.makedirs(dirname, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=".artifact-", suffix=".tmp",
                               dir=dirname)
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(obj, fh, indent=indent)
            fh.write("\n")
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


# Registry of artifact kinds (filled by Artifact.__init_subclass__) so
# load_any() can dispatch a file to its class from the envelope alone.
_KINDS: dict[str, type["Artifact"]] = {}


class Artifact:
    """Base class: one schema-versioned JSON document kind."""

    kind: ClassVar[str] = ""
    schema_version: ClassVar[int] = 1
    # payload keys (envelope keys excluded) at the *latest* version
    required_keys: ClassVar[tuple[str, ...]] = ()
    optional_keys: ClassVar[tuple[str, ...]] = ()

    def __init_subclass__(cls, **kw: Any) -> None:
        super().__init_subclass__(**kw)
        if cls.kind:
            if cls.schema_version < 1:
                raise TypeError(f"{cls.__name__}: schema_version >= 1")
            _KINDS[cls.kind] = cls

    # ------------------------------------------------------------ payload
    def to_payload(self) -> dict:
        raise NotImplementedError

    @classmethod
    def from_payload(cls, payload: dict) -> "Artifact":
        raise NotImplementedError

    # ---------------------------------------------------------- save/load
    def save(self, path: str) -> str:
        """Atomically write the enveloped payload; returns ``path``."""
        payload = self.to_payload()
        clash = set(payload) & set(ENVELOPE_KEYS)
        if clash:
            raise ValueError(
                f"{type(self).__name__}.to_payload() uses reserved "
                f"envelope keys {sorted(clash)}")
        doc = {"kind": self.kind,
               "schema_version": self.schema_version, **payload}
        atomic_write_json(path, doc)
        return path

    @classmethod
    def load(cls, path: str):
        """Load + validate + (if needed) migrate an artifact file.

        Raises :class:`ArtifactError` naming ``path`` on every failure
        mode: unreadable/truncated JSON, wrong ``kind``, a version newer
        than this code understands, or missing/unknown payload keys.
        Unversioned files are treated as v1 legacy output and migrated
        with a :class:`DeprecationWarning`.
        """
        return cls._from_doc(path, cls._read_doc(path))

    @classmethod
    def _from_doc(cls, path: str, doc: dict):
        """The load path after the JSON is in hand (shared with
        :func:`load_any`, which already parsed the file once)."""
        version = cls._detect_version(path, doc)
        payload = {k: v for k, v in doc.items() if k not in ENVELOPE_KEYS}
        payload = cls._migrate(path, payload, version)
        cls._validate_keys(path, payload)
        try:
            return cls.from_payload(payload)
        except (KeyError, TypeError, ValueError) as exc:
            raise ArtifactError(
                path, f"malformed {cls.kind} payload: {exc!r}") from exc

    # ----------------------------------------------------------- plumbing
    @classmethod
    def _read_doc(cls, path: str) -> dict:
        try:
            with open(path) as fh:
                doc = json.load(fh)
        except OSError as exc:
            raise ArtifactError(path, f"cannot read: {exc}") from exc
        except ValueError as exc:
            raise ArtifactError(
                path, f"invalid/truncated JSON: {exc}") from exc
        if not isinstance(doc, dict):
            raise ArtifactError(
                path, f"expected a JSON object, got {type(doc).__name__}")
        return doc

    @classmethod
    def _detect_version(cls, path: str, doc: dict) -> int:
        kind = doc.get("kind")
        if kind is not None and kind != cls.kind:
            raise ArtifactError(
                path, f"kind mismatch: file is {kind!r}, "
                      f"expected {cls.kind!r}")
        version = doc.get("schema_version")
        if version is None:
            warnings.warn(
                f"{path}: unversioned (legacy v1) {cls.kind} file; "
                f"loading via the v1 migration path — re-save with "
                f"repro.api to upgrade it to "
                f"schema_version={cls.schema_version}",
                DeprecationWarning, stacklevel=3)
            return 1
        if not isinstance(version, int) or version < 1:
            raise ArtifactError(
                path, f"bad schema_version {version!r}")
        if version > cls.schema_version:
            raise ArtifactError(
                path, f"schema_version {version} is newer than this "
                      f"code understands (<= {cls.schema_version}); "
                      f"upgrade repro to load it")
        return version

    @classmethod
    def _migrate(cls, path: str, payload: dict, version: int) -> dict:
        for v in range(version, cls.schema_version):
            hook: Optional[Callable[[dict], dict]] = getattr(
                cls, f"migrate_v{v}", None)
            if hook is None:
                raise ArtifactError(
                    path, f"no migration from {cls.kind} v{v} to "
                          f"v{v + 1}")
            payload = hook(dict(payload))
        return payload

    @classmethod
    def _validate_keys(cls, path: str, payload: dict) -> None:
        keys = set(payload)
        missing = set(cls.required_keys) - keys
        unknown = keys - set(cls.required_keys) - set(cls.optional_keys)
        if missing or unknown:
            parts = []
            if missing:
                parts.append(f"missing keys {sorted(missing)}")
            if unknown:
                parts.append(f"unknown keys {sorted(unknown)}")
            raise ArtifactError(
                path, f"{cls.kind} v{cls.schema_version} schema "
                      f"violation: {'; '.join(parts)}")


def peek(path: str) -> tuple[Optional[str], Optional[int]]:
    """Read just the envelope: ``(kind, schema_version)``.

    ``(None, None)`` means a legacy unversioned file; raises
    :class:`ArtifactError` on unreadable/invalid JSON.
    """
    doc = Artifact._read_doc(path)
    return doc.get("kind"), doc.get("schema_version")


def load_any(path: str) -> Artifact:
    """Load a file as whatever registered artifact kind it declares."""
    doc = Artifact._read_doc(path)
    kind = doc.get("kind")
    if kind is None:
        raise ArtifactError(
            path, "no 'kind' in envelope; load legacy files through "
                  "their specific artifact class instead")
    cls = _KINDS.get(kind)
    if cls is None:
        raise ArtifactError(
            path, f"unknown artifact kind {kind!r} "
                  f"(registered: {sorted(_KINDS)})")
    return cls._from_doc(path, doc)


def registered_kinds() -> dict[str, type[Artifact]]:
    return dict(_KINDS)
