"""`SlimStart` — the one front door to the SLIMSTART workflow.

The facade chains :mod:`repro.api.stages` over a single
:class:`~repro.api.stages.RunContext`.  The two pipelines the seed repo
wired by hand (``SlimstartPipeline`` / ``StaticPipeline``) are now just
stage graphs::

    SlimStart.profile_guided("graph_bfs").run()     # profile→analyze→optimize
    SlimStart.static_baseline("graph_bfs").run()    # optimize(static) only

and arbitrary graphs compose the same way::

    SlimStart("graph_bfs", stages=[
        ProfileStage(instances=2, invocations=80),
        AnalyzeStage(),
        OptimizeStage(),
        WarmStage(n=5),                 # zygote + fork-pool measurement
        ReplayStage(n_cold=5),          # re-measure speedup
    ]).run()

``run()`` returns the shared context: the versioned report artifact
path, the optimized variant directory, per-stage results and timings.
The ``python -m repro`` CLI is a thin shell over this class.
"""

from __future__ import annotations

import time
from typing import Optional, Sequence

from repro.api.stages import (
    AnalyzeStage,
    OptimizeStage,
    ProfileStage,
    ReplayStage,
    RunContext,
    Stage,
    WarmStage,
)
from repro.core.profiler.utilization import AnalyzerConfig


class SlimStart:
    """Configurable stage-graph runner for one application."""

    def __init__(self, app: str, root: Optional[str] = None, *,
                 variant: str = "slimstart",
                 stages: Optional[Sequence[Stage]] = None) -> None:
        self.ctx = RunContext.for_app(app, root, variant=variant)
        if stages is None:
            stages = [ProfileStage(), AnalyzeStage(), OptimizeStage()]
        self.stages: list[Stage] = list(stages)

    # -------------------------------------------------------- composition
    def add(self, stage: Stage) -> "SlimStart":
        """Append a stage; returns self for chaining."""
        self.stages.append(stage)
        return self

    # ---------------------------------------------------------- execution
    def run(self) -> RunContext:
        timings: dict[str, float] = {}
        for stage in self.stages:
            t0 = time.perf_counter()
            stage.run(self.ctx)
            timings[stage.name] = time.perf_counter() - t0
        self.ctx.results["timings_s"] = timings
        return self.ctx

    # -------------------------------------------------------- constructors
    @classmethod
    def profile_guided(cls, app: str, root: Optional[str] = None, *,
                       instances: int = 4, invocations: int = 150,
                       config: Optional[AnalyzerConfig] = None,
                       measure: bool = False,
                       n_cold: int = 5) -> "SlimStart":
        """The paper's tool: profile → analyze → optimize
        (→ re-measure when ``measure``)."""
        stages: list[Stage] = [
            ProfileStage(instances=instances, invocations=invocations),
            AnalyzeStage(config=config),
            OptimizeStage(mode="profile"),
        ]
        if measure:
            stages.append(ReplayStage(n_cold=n_cold))
        return cls(app, root, stages=stages)

    @classmethod
    def static_baseline(cls, app: str, root: Optional[str] = None, *,
                        variant: str = "static") -> "SlimStart":
        """FaaSLight-style static-reachability baseline (no profiling)."""
        return cls(app, root, variant=variant,
                   stages=[OptimizeStage(mode="static")])

    @classmethod
    def warm_pool(cls, app: str, root: Optional[str] = None, *,
                  instances: int = 4, invocations: int = 150,
                  n: int = 5) -> "SlimStart":
        """Profile → analyze → boot a hot-set zygote and measure
        fork-pool starts (no source rewrite)."""
        return cls(app, root, stages=[
            ProfileStage(instances=instances, invocations=invocations),
            AnalyzeStage(),
            WarmStage(n=n),
        ])
