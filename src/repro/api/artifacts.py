"""Concrete schema-versioned artifacts of the SLIMSTART workflow.

Six kinds cover everything the stages exchange on disk:

====================  ===========================  =======
kind                  wraps                         latest
====================  ===========================  =======
optimization_report   OptimizationReport            2
trace                 repro.pool.trace.Trace        1
cold_start_stats      ColdStartStats (harness)      1
bench_result          benchmark payload dicts       2
fleet_summary         fleet serve/replay rollups    1
shared_hot_set        repro.pool.sharing plan       1
trace_events          repro.obs spans + metrics     1
====================  ===========================  =======

``optimization_report`` v1 is the seed repo's unversioned
``OptimizationReport.to_dict()`` dump; v2 wraps the same fields in the
envelope and adds an optional ``meta`` section (profiling parameters,
free-form provenance).  ``bench_result`` v1 is the seed's raw payload
JSON under ``benchmarks/results/``.

Prefer the typed helpers (:func:`save_report` / :func:`load_report`,
...) over the classes: they take and return the domain objects the rest
of the codebase already speaks.
"""

from __future__ import annotations

import os
from typing import Any, Optional, Union

from repro.api.artifact import Artifact, ArtifactError
from repro.benchsuite.harness import ColdStartStats
from repro.core.profiler.import_timer import ModuleInitRecord
from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import (
    InefficiencyFinding,
    LibraryStats,
)
from repro.pool.sharing import SharedHotSet
from repro.pool.trace import Request, Trace

ReportLike = Union[OptimizationReport, "ReportArtifact", str, os.PathLike]


# ---------------------------------------------------------------------------
# optimization_report (v2; v1 = legacy unversioned to_dict dump)
# ---------------------------------------------------------------------------

class ReportArtifact(Artifact):
    kind = "optimization_report"
    schema_version = 2
    required_keys = ("application", "e2e_s", "total_init_s", "qualifies",
                     "stats", "findings", "defer_targets")
    optional_keys = ("meta",)

    def __init__(self, report: OptimizationReport,
                 meta: Optional[dict] = None) -> None:
        self.report = report
        self.meta = dict(meta or {})

    @classmethod
    def migrate_v1(cls, payload: dict) -> dict:
        # v1 -> v2: same fields, explicit (empty) provenance section
        payload.setdefault("meta", {})
        return payload

    def to_payload(self) -> dict:
        # OptimizationReport.to_dict() is exactly the v2 payload minus
        # the provenance section (and, enveloped-less, the v1 format)
        return {**self.report.to_dict(), "meta": self.meta}

    @classmethod
    def from_payload(cls, payload: dict) -> "ReportArtifact":
        meta = payload.get("meta") or {}
        rep = OptimizationReport(
            application=payload["application"],
            e2e_s=payload["e2e_s"],
            total_init_s=payload["total_init_s"],
            qualifies=payload["qualifies"],
            defer_targets=list(payload["defer_targets"]),
        )
        rep.stats = [
            LibraryStats(
                name=s["package"],
                utilization=s["utilization"],
                init_s=s["init_s"],
                init_share=s["init_share"],
                runtime_samples=s["runtime_samples"],
                file=s["file"],
            )
            for s in payload["stats"]
        ]
        rep.findings = [
            InefficiencyFinding(
                package=f["package"],
                kind=f["kind"],
                utilization=f["utilization"],
                init_s=f["init_s"],
                init_share=f["init_share"],
                file=f["file"],
                import_chain=[
                    ModuleInitRecord(
                        name=r["module"], filename="",
                        importer_file=r.get("importer_file"),
                        importer_lineno=r.get("importer_lineno", 0))
                    for r in f.get("call_path", [])
                ],
            )
            for f in payload["findings"]
        ]
        return cls(rep, meta=meta)


def save_report(report: OptimizationReport, path: str,
                meta: Optional[dict] = None) -> str:
    """Atomically save a report as a versioned artifact."""
    return ReportArtifact(report, meta=meta).save(path)


def load_report(path: str) -> OptimizationReport:
    """Load a versioned (or legacy v1) report artifact."""
    return ReportArtifact.load(path).report


def load_report_meta(path: str) -> dict:
    """The report artifact's ``meta`` section ({} for legacy files)."""
    return ReportArtifact.load(path).meta


def as_report(obj: ReportLike) -> OptimizationReport:
    """Normalize 'some form of report' into an :class:`OptimizationReport`.

    Accepts the report object itself, a :class:`ReportArtifact`, or a
    path to a saved artifact — the currency of ``rewarm``-style hooks
    that may be fed either an in-memory report (adaptive loop) or a
    deployed artifact file (CLI / CI).
    """
    if isinstance(obj, OptimizationReport):
        return obj
    if isinstance(obj, ReportArtifact):
        return obj.report
    if isinstance(obj, (str, os.PathLike)):
        return load_report(os.fspath(obj))
    raise TypeError(
        f"expected OptimizationReport, ReportArtifact or path, "
        f"got {type(obj).__name__}")


# ---------------------------------------------------------------------------
# trace (v1)
# ---------------------------------------------------------------------------

class TraceArtifact(Artifact):
    kind = "trace"
    schema_version = 1
    required_keys = ("name", "duration_s", "requests")
    optional_keys = ("meta",)

    def __init__(self, trace: Trace, meta: Optional[dict] = None) -> None:
        self.trace = trace
        self.meta = dict(meta or {})

    def to_payload(self) -> dict:
        return {
            "name": self.trace.name,
            "duration_s": self.trace.duration_s,
            "requests": [
                {"t": r.t, "app": r.app, "handler": r.handler}
                for r in self.trace.requests
            ],
            "meta": self.meta,
        }

    @classmethod
    def from_payload(cls, payload: dict) -> "TraceArtifact":
        reqs = [Request(t=r["t"], app=r["app"], handler=r.get("handler"))
                for r in payload["requests"]]
        return cls(Trace(payload["name"], reqs, payload["duration_s"]),
                   meta=payload.get("meta") or {})


def save_trace(trace: Trace, path: str,
               meta: Optional[dict] = None) -> str:
    return TraceArtifact(trace, meta=meta).save(path)


def load_trace(path: str) -> Trace:
    return TraceArtifact.load(path).trace


# ---------------------------------------------------------------------------
# cold_start_stats (v1)
# ---------------------------------------------------------------------------

class ColdStartStatsArtifact(Artifact):
    kind = "cold_start_stats"
    schema_version = 1
    required_keys = ("app", "n", "init_ms", "e2e_ms", "peak_rss_kb")
    optional_keys = ("meta",)

    def __init__(self, stats: ColdStartStats,
                 meta: Optional[dict] = None) -> None:
        self.stats = stats
        self.meta = dict(meta or {})

    def to_payload(self) -> dict:
        s = self.stats
        return {"app": s.app, "n": s.n, "init_ms": list(s.init_ms),
                "e2e_ms": list(s.e2e_ms),
                "peak_rss_kb": list(s.peak_rss_kb), "meta": self.meta}

    @classmethod
    def from_payload(cls, payload: dict) -> "ColdStartStatsArtifact":
        stats = ColdStartStats(
            app=payload["app"], n=payload["n"],
            init_ms=list(payload["init_ms"]),
            e2e_ms=list(payload["e2e_ms"]),
            peak_rss_kb=list(payload["peak_rss_kb"]))
        return cls(stats, meta=payload.get("meta") or {})


def save_stats(stats: ColdStartStats, path: str,
               meta: Optional[dict] = None) -> str:
    return ColdStartStatsArtifact(stats, meta=meta).save(path)


def load_stats(path: str) -> ColdStartStats:
    return ColdStartStatsArtifact.load(path).stats


# ---------------------------------------------------------------------------
# bench_result (v2; v1 = legacy raw payload JSON)
# ---------------------------------------------------------------------------

class BenchResultArtifact(Artifact):
    kind = "bench_result"
    schema_version = 2
    required_keys = ("name", "data")
    optional_keys = ("meta",)

    def __init__(self, name: str, data: Any,
                 meta: Optional[dict] = None) -> None:
        self.name = name
        self.data = data
        self.meta = dict(meta or {})

    @classmethod
    def migrate_v1(cls, payload: dict) -> dict:
        # v1 files *are* the raw benchmark payload (arbitrary keys):
        # wrap them whole under "data"
        return {"name": str(payload.get("figure")
                            or payload.get("table") or ""),
                "data": payload, "meta": {}}

    def to_payload(self) -> dict:
        return {"name": self.name, "data": self.data, "meta": self.meta}

    @classmethod
    def from_payload(cls, payload: dict) -> "BenchResultArtifact":
        return cls(payload["name"], payload["data"],
                   meta=payload.get("meta") or {})


def save_bench_result(name: str, data: Any, path: str,
                      meta: Optional[dict] = None) -> str:
    return BenchResultArtifact(name, data, meta=meta).save(path)


def load_bench_result(path: str) -> Any:
    return BenchResultArtifact.load(path).data


# ---------------------------------------------------------------------------
# fleet_summary (v1)
# ---------------------------------------------------------------------------

class FleetSummaryArtifact(Artifact):
    """Fleet-level rollup of one serve/replay run — the artifact both
    ``python -m repro fleet serve`` (on drain/shutdown) and
    ``fleet replay`` emit, and the nightly benchmark uploads.

    The payload is flat: totals (arrivals vs served, cold/pool starts,
    latency percentiles), backpressure accounting (``sheds`` — requests
    dropped by the bounded queue, ``flushed`` — requests still queued
    at drain, ``errors`` — real-mode dispatch failures, queue-wait
    percentiles, the ``queue`` config that produced them), the
    rewarm-tick count, and ``per_app`` breakdown rows.  Conservation:
    ``requests == served + sheds + flushed + errors + abandoned``
    (``errors`` and ``abandoned`` default to 0 when absent;
    ``abandoned`` counts in-flight dispatches whose worker never
    returned by the drain deadline).  ``shed_reasons`` (optional)
    breaks ``sheds`` out by cause — ``queue-full`` (reject-new),
    ``drop-oldest``, ``pool-saturated``, ``timeout`` (wedged handler),
    ``crash_loop`` (circuit-broken app whose cold fallback failed) —
    and must sum to ``sheds``.  ``degraded`` / ``degrade_reasons``
    count requests that WERE served but in a degraded mode (e.g.
    cold-only under an open circuit breaker).  ``source`` names the
    producer (``serve-sim`` / ``serve-real`` / ``replay-sim`` /
    ``replay-real`` / ``bench``).
    """

    kind = "fleet_summary"
    schema_version = 1
    required_keys = ("source", "requests", "served", "cold_starts",
                     "cold_start_ratio", "p50_ms", "p99_ms", "sheds",
                     "flushed", "queue_wait_p50_ms", "queue_wait_p99_ms",
                     "per_app")
    optional_keys = ("policy", "trace", "budget_mb", "duration_s",
                     "pool_starts", "errors", "abandoned", "degraded",
                     "degrade_reasons", "memory_gb_s",
                     "rewarm_ticks", "rewarm_errors", "queue",
                     "zygotes", "skipped", "used_mb", "shared_base_mb",
                     "base_gb_s", "shared_base", "shed_reasons",
                     "adaptive", "meta")

    def __init__(self, payload: dict, meta: Optional[dict] = None) -> None:
        self.data = dict(payload)
        if meta is not None:
            self.data["meta"] = {**self.data.get("meta", {}), **meta}

    def to_payload(self) -> dict:
        return dict(self.data)

    def save(self, path: str) -> str:
        # unlike the typed artifacts, this one wraps a raw payload
        # dict, so a producer bug would otherwise only surface at load
        # time on some other machine — validate at write time instead
        self._validate_keys(path, self.to_payload())
        return super().save(path)

    @classmethod
    def from_payload(cls, payload: dict) -> "FleetSummaryArtifact":
        return cls(payload)

    @property
    def meta(self) -> dict:
        return self.data.get("meta") or {}


def save_fleet_summary(payload: dict, path: str,
                       meta: Optional[dict] = None) -> str:
    """Atomically save a ``fleet_summary`` payload (see
    :meth:`repro.pool.fleet.FleetSummary.artifact_payload` and
    :meth:`repro.pool.daemon.FleetDaemon.summary` for producers)."""
    return FleetSummaryArtifact(payload, meta=meta).save(path)


def load_fleet_summary(path: str) -> dict:
    """Load a ``fleet_summary`` artifact; returns the payload dict."""
    return FleetSummaryArtifact.load(path).data


# ---------------------------------------------------------------------------
# shared_hot_set (v1)
# ---------------------------------------------------------------------------

class SharedHotSetArtifact(Artifact):
    """The fleet's two-tier pre-import plan (see
    :mod:`repro.pool.sharing`): which modules boot the shared
    :class:`~repro.pool.forkserver.BaseZygote` and what private delta
    each per-app zygote layers on top after forking from it.  Produced
    by intersecting the deployed ``optimization_report`` artifacts;
    consumed by ``fleet serve --shared-base`` boot and its rewarm
    tick's base hot-swap."""

    kind = "shared_hot_set"
    schema_version = 1
    required_keys = ("modules", "apps", "per_app_delta")
    optional_keys = ("min_apps", "counts", "meta")

    def __init__(self, shared: "SharedHotSet",
                 meta: Optional[dict] = None) -> None:
        self.shared = shared
        self.meta = dict(meta or {})

    def to_payload(self) -> dict:
        return {**self.shared.to_payload(), "meta": self.meta}

    @classmethod
    def from_payload(cls, payload: dict) -> "SharedHotSetArtifact":
        from repro.pool.sharing import SharedHotSet
        return cls(SharedHotSet.from_payload(payload),
                   meta=payload.get("meta") or {})


def save_shared_hot_set(shared: "SharedHotSet", path: str,
                        meta: Optional[dict] = None) -> str:
    """Atomically save a :class:`repro.pool.sharing.SharedHotSet` as a
    versioned ``shared_hot_set`` artifact."""
    return SharedHotSetArtifact(shared, meta=meta).save(path)


def load_shared_hot_set(path: str) -> "SharedHotSet":
    """Load a ``shared_hot_set`` artifact; returns the
    :class:`repro.pool.sharing.SharedHotSet`."""
    return SharedHotSetArtifact.load(path).shared


# ---------------------------------------------------------------------------
# trace_events (v1)
# ---------------------------------------------------------------------------

class TraceEventsArtifact(Artifact):
    """One observability capture: the spans recorded by
    :class:`repro.obs.tracing.Tracer` over a run plus a
    :meth:`repro.obs.metrics.MetricsRegistry.snapshot` taken at the
    same moment.  Produced by ``fleet replay --trace-out`` /
    ``fleet serve --trace-out``; consumed by ``python -m repro obs
    report`` (anatomy breakdown, flamegraph folding).

    ``spans`` is a list of span dicts (see
    :meth:`repro.obs.tracing.Span.to_dict`); ``metrics`` is the
    plain-JSON registry snapshot (``repro.metrics/1``); ``meta``
    carries provenance (source command, app set, dropped-span count).
    """

    kind = "trace_events"
    schema_version = 1
    required_keys = ("spans", "metrics")
    optional_keys = ("meta",)

    def __init__(self, spans: list, metrics: Optional[dict] = None,
                 meta: Optional[dict] = None) -> None:
        self.spans = [s.to_dict() if hasattr(s, "to_dict") else dict(s)
                      for s in spans]
        self.metrics = dict(metrics or {})
        self.meta = dict(meta or {})

    def to_payload(self) -> dict:
        return {"spans": self.spans, "metrics": self.metrics,
                "meta": self.meta}

    @classmethod
    def from_payload(cls, payload: dict) -> "TraceEventsArtifact":
        return cls(list(payload["spans"]),
                   metrics=payload.get("metrics") or {},
                   meta=payload.get("meta") or {})


def save_trace_events(spans: list, path: str,
                      metrics: Optional[dict] = None,
                      meta: Optional[dict] = None) -> str:
    """Atomically save spans (+ optional metrics snapshot) as a
    versioned ``trace_events`` artifact."""
    return TraceEventsArtifact(spans, metrics=metrics, meta=meta).save(path)


def load_trace_events(path: str) -> TraceEventsArtifact:
    """Load a ``trace_events`` artifact (spans stay plain dicts)."""
    return TraceEventsArtifact.load(path)


# ---------------------------------------------------------------------------
# chaos_report (v1)
# ---------------------------------------------------------------------------

class ChaosReportArtifact(Artifact):
    """One chaos run (see :mod:`repro.pool.chaos`): the fault plan and
    seed, every event actually injected (kind / site / app / matched
    occurrence), events that never fired (``pending``), the fleet's
    recovery counters (zygote restarts, base reboots, circuit-breaker
    trips), the conservation-invariant verdict (``requests == served +
    sheds + flushed + errors + abandoned``), and the run's full
    ``fleet_summary`` payload.  Produced by
    ``fleet replay --real --chaos <plan.json> [--chaos-report PATH]``;
    the nightly chaos job gates on ``invariant.holds``."""

    kind = "chaos_report"
    schema_version = 1
    required_keys = ("seed", "plan", "injected", "recoveries",
                     "invariant")
    optional_keys = ("injected_by_kind", "pending", "hook_calls",
                     "summary", "meta")

    def __init__(self, payload: dict,
                 meta: Optional[dict] = None) -> None:
        self.data = dict(payload)
        if meta is not None:
            self.data["meta"] = {**self.data.get("meta", {}), **meta}

    def to_payload(self) -> dict:
        return dict(self.data)

    def save(self, path: str) -> str:
        # raw-payload artifact (like fleet_summary): validate at write
        # time so a producer bug fails the chaos run, not a later load
        self._validate_keys(path, self.to_payload())
        return super().save(path)

    @classmethod
    def from_payload(cls, payload: dict) -> "ChaosReportArtifact":
        return cls(payload)

    @property
    def meta(self) -> dict:
        return self.data.get("meta") or {}


def save_chaos_report(payload: dict, path: str,
                      meta: Optional[dict] = None) -> str:
    """Atomically save a ``chaos_report`` payload (see
    :func:`repro.pool.chaos.chaos_report_payload` for the producer)."""
    return ChaosReportArtifact(payload, meta=meta).save(path)


def load_chaos_report(path: str) -> dict:
    """Load a ``chaos_report`` artifact; returns the payload dict."""
    return ChaosReportArtifact.load(path).data


# ---------------------------------------------------------------------------
# drift_report (v1)
# ---------------------------------------------------------------------------

class DriftReportArtifact(Artifact):
    """One adaptive-serving run's drift ledger (see
    :class:`repro.core.adaptive.AdaptiveLoop`): the noise-calibrated
    detector config actually applied, every closed window's verdict
    (Σ|Δp| vs eps_eff, defer-set hit rate, new hot modules, the max
    drift ``score`` and whether it ``fired``), the re-optimization
    actions taken (which apps got fresh in-process reports, whether the
    shared base was swapped), the live-profiler's per-app sample
    counts, and its measured overhead.  Produced by
    ``fleet replay --adaptive --drift-out PATH`` /
    ``fleet serve --adaptive --drift-out PATH``; rendered by
    ``python -m repro drift status``; the nightly adaptive-replay job
    uploads these."""

    kind = "drift_report"
    schema_version = 1
    required_keys = ("source", "config", "windows", "fires")
    optional_keys = ("actions", "final_score", "sampler_overhead_pct",
                     "apps", "errors", "meta")

    def __init__(self, payload: dict,
                 meta: Optional[dict] = None) -> None:
        self.data = dict(payload)
        if meta is not None:
            self.data["meta"] = {**self.data.get("meta", {}), **meta}

    def to_payload(self) -> dict:
        return dict(self.data)

    def save(self, path: str) -> str:
        # raw-payload artifact (like fleet_summary): validate at write
        # time so a producer bug fails the serving run, not a later load
        self._validate_keys(path, self.to_payload())
        return super().save(path)

    @classmethod
    def from_payload(cls, payload: dict) -> "DriftReportArtifact":
        return cls(payload)

    @property
    def meta(self) -> dict:
        return self.data.get("meta") or {}


def save_drift_report(payload: dict, path: str,
                      meta: Optional[dict] = None) -> str:
    """Atomically save a ``drift_report`` payload (see
    :meth:`repro.core.adaptive.AdaptiveLoop.drift_report_payload` for
    the producer)."""
    return DriftReportArtifact(payload, meta=meta).save(path)


def load_drift_report(path: str) -> dict:
    """Load a ``drift_report`` artifact; returns the payload dict."""
    return DriftReportArtifact.load(path).data


# ---------------------------------------------------------------------------
# cluster_summary (v1)
# ---------------------------------------------------------------------------

class ClusterSummaryArtifact(Artifact):
    """Cluster-level rollup of one multi-node run (see
    :mod:`repro.cluster`): global counts summed over nodes, *merged*
    latency percentiles (pooled raw samples, never averaged per-node
    percentiles — ``percentiles_merged`` says whether pools were
    available), the placement ``strategy`` and resulting app → node
    map, migrations and lost nodes from rebalances, and the
    ``conservation`` verdict — ``requests == served + sheds + flushed
    + errors + abandoned`` must hold per node, globally, and (when the
    router kept its own ledger) between the router's per-node routed
    counts and each node's reported ``requests``.  ``per_node`` keeps
    every node's counters for drill-down.  Produced by ``python -m
    repro cluster replay`` (simulator) and ``cluster route`` (real
    socket-fed nodes); the nightly cluster job gates on
    ``conservation.holds``."""

    kind = "cluster_summary"
    schema_version = 1
    required_keys = ("source", "strategy", "nodes", "requests",
                     "served", "cold_starts", "cold_start_ratio",
                     "p50_ms", "p99_ms", "sheds", "flushed", "errors",
                     "abandoned", "conservation", "per_node")
    optional_keys = ("percentiles_merged", "queue_wait_p50_ms",
                     "queue_wait_p99_ms", "placement", "migrations",
                     "lost_nodes", "memory_gb_s", "trace", "seed",
                     "node_budget_mb", "total_budget_mb", "duration_s",
                     "queue", "router", "ha", "handoffs", "meta")

    def __init__(self, payload: dict,
                 meta: Optional[dict] = None) -> None:
        self.data = dict(payload)
        if meta is not None:
            self.data["meta"] = {**self.data.get("meta", {}), **meta}

    def to_payload(self) -> dict:
        return dict(self.data)

    def save(self, path: str) -> str:
        # raw-payload artifact (like fleet_summary): validate at write
        # time so a producer bug fails the run that made it
        self._validate_keys(path, self.to_payload())
        return super().save(path)

    @classmethod
    def from_payload(cls, payload: dict) -> "ClusterSummaryArtifact":
        return cls(payload)

    @property
    def meta(self) -> dict:
        return self.data.get("meta") or {}


def save_cluster_summary(payload: dict, path: str,
                         meta: Optional[dict] = None) -> str:
    """Atomically save a ``cluster_summary`` payload (see
    :func:`repro.cluster.summary.make_cluster_summary_payload` for the
    one constructor)."""
    return ClusterSummaryArtifact(payload, meta=meta).save(path)


def load_cluster_summary(path: str) -> dict:
    """Load a ``cluster_summary`` artifact; returns the payload dict."""
    return ClusterSummaryArtifact.load(path).data


__all__ = [
    "Artifact",
    "ArtifactError",
    "BenchResultArtifact",
    "ChaosReportArtifact",
    "ClusterSummaryArtifact",
    "ColdStartStatsArtifact",
    "FleetSummaryArtifact",
    "ReportArtifact",
    "SharedHotSetArtifact",
    "TraceArtifact",
    "TraceEventsArtifact",
    "as_report",
    "load_bench_result",
    "load_chaos_report",
    "load_cluster_summary",
    "load_fleet_summary",
    "load_report",
    "load_report_meta",
    "load_shared_hot_set",
    "load_stats",
    "load_trace",
    "load_trace_events",
    "save_bench_result",
    "save_chaos_report",
    "save_cluster_summary",
    "save_fleet_summary",
    "save_report",
    "save_shared_hot_set",
    "save_stats",
    "save_trace",
    "save_trace_events",
]
