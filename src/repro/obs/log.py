"""Structured, leveled logging for the daemon and CLI.

One process-wide configuration (:func:`configure`) and per-component
:class:`Logger` handles.  Two output modes:

* text (default): ``2026-08-08T12:00:00Z INFO  fleet.daemon started apps=3``
* JSONL (``--log-json``): one object per line with ``ts``, ``level``,
  ``component``, ``event`` and the structured fields.

Both modes write whole lines under a lock so concurrent worker threads
never interleave.  Events below the configured level are dropped before
any formatting happens.
"""

from __future__ import annotations

import json
import sys
import threading
import time
from typing import IO, Optional

__all__ = ["configure", "get_logger", "Logger", "LEVELS"]

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}


class _Config:
    def __init__(self) -> None:
        self.threshold = LEVELS["info"]
        self.json_mode = False
        self.stream: Optional[IO[str]] = None  # None -> sys.stderr
        self.lock = threading.Lock()


_CONFIG = _Config()


def configure(*, level: str = "info", json_mode: bool = False,
              stream: Optional[IO[str]] = None) -> None:
    """Set process-wide log level / format / destination."""
    if level not in LEVELS:
        raise ValueError(
            f"unknown log level {level!r} (choose from "
            f"{sorted(LEVELS)})")
    _CONFIG.threshold = LEVELS[level]
    _CONFIG.json_mode = bool(json_mode)
    _CONFIG.stream = stream


def _emit(component: str, level: str, event: str, fields: dict) -> None:
    cfg = _CONFIG
    if LEVELS[level] < cfg.threshold:
        return
    ts = time.strftime("%Y-%m-%dT%H:%M:%S", time.gmtime()) \
        + f".{int((time.time() % 1) * 1000):03d}Z"
    if cfg.json_mode:
        rec = {"ts": ts, "level": level, "component": component,
               "event": event}
        rec.update(fields)
        line = json.dumps(rec, default=str, sort_keys=False)
    else:
        kv = " ".join(f"{k}={_short(v)}" for k, v in fields.items())
        line = f"{ts} {level.upper():<7} {component} {event}" \
            + (f" {kv}" if kv else "")
    stream = cfg.stream or sys.stderr
    with cfg.lock:
        try:
            stream.write(line + "\n")
            stream.flush()
        except (ValueError, OSError):
            pass  # stream closed during shutdown


def _short(value: object) -> str:
    if isinstance(value, float):
        return f"{value:.3f}"
    if isinstance(value, str) and (" " in value or not value):
        return json.dumps(value)
    if isinstance(value, (dict, list)):
        return json.dumps(value, default=str)
    return str(value)


class Logger:
    """Cheap per-component handle; all state lives in the config."""

    __slots__ = ("component",)

    def __init__(self, component: str):
        self.component = component

    def debug(self, event: str, **fields: object) -> None:
        _emit(self.component, "debug", event, fields)

    def info(self, event: str, **fields: object) -> None:
        _emit(self.component, "info", event, fields)

    def warning(self, event: str, **fields: object) -> None:
        _emit(self.component, "warning", event, fields)

    def error(self, event: str, **fields: object) -> None:
        _emit(self.component, "error", event, fields)


def get_logger(component: str) -> Logger:
    return Logger(component)
