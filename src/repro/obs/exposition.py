"""Prometheus exposition over HTTP (stdlib) or to a textfile.

:class:`MetricsServer` is a tiny ``http.server`` endpoint meant to sit
next to ``fleet serve``: daemon threads only, bind-to-port-0 supported
(the bound port is reported back so tests and the CI smoke can scrape
an ephemeral port), and the handler just renders the registry on each
GET — no caching, no state.

Routes::

    GET /metrics   text/plain; version=0.0.4 exposition
    GET /healthz   "ok"
"""

from __future__ import annotations

import os
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs.metrics import MetricsRegistry, default_registry

__all__ = ["MetricsServer", "write_metrics_textfile"]

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def write_metrics_textfile(path: str,
                           registry: Optional[MetricsRegistry] = None
                           ) -> str:
    """Atomically write the exposition to ``path`` (textfile-collector
    style); returns the rendered text."""
    reg = registry or default_registry()
    text = reg.render()
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w", encoding="utf-8") as fh:
        fh.write(text)
    os.replace(tmp, path)
    return text


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-obs/1"

    def do_GET(self) -> None:  # noqa: N802 - http.server API
        path = self.path.split("?", 1)[0]
        if path in ("/metrics", "/"):
            body = self.server.registry.render().encode("utf-8")  # type: ignore[attr-defined]
            ctype = CONTENT_TYPE
        elif path == "/healthz":
            body, ctype = b"ok\n", "text/plain; charset=utf-8"
        else:
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):  # quiet: scrapes are periodic
        return


class MetricsServer:
    """Background /metrics endpoint for a registry."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 host: str = "127.0.0.1", port: int = 0):
        self.registry = registry or default_registry()
        self.host = host
        self.port = port
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    def start(self) -> int:
        """Bind and serve in a daemon thread; returns the bound port."""
        if self._httpd is not None:
            return self.port
        httpd = ThreadingHTTPServer((self.host, self.port), _Handler)
        httpd.daemon_threads = True
        httpd.registry = self.registry  # type: ignore[attr-defined]
        self._httpd = httpd
        self.port = httpd.server_address[1]
        self._thread = threading.Thread(
            target=httpd.serve_forever, kwargs={"poll_interval": 0.2},
            name="repro-metrics", daemon=True)
        self._thread.start()
        return self.port

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def stop(self) -> None:
        httpd, self._httpd = self._httpd, None
        if httpd is not None:
            httpd.shutdown()
            httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    def __enter__(self) -> "MetricsServer":
        self.start()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()
