"""``repro obs top`` — live per-app fleet table from a /metrics scrape.

Scrapes a daemon's Prometheus endpoint (or a textfile written by
:func:`repro.obs.exposition.write_metrics_textfile`), folds the samples
into per-app rows and renders a refreshing table: requests, cold
ratio, shed rate, queue depth / in-flight gauges, queue-wait p99
(estimated from histogram buckets) — with fleet-wide footer lines for
base swaps and rewarm ticks.

Pure functions (:func:`rows_from_exposition`, :func:`render_table`)
carry all the logic so tests never need a live daemon; the scrape loop
is a thin shell with ``--iterations`` for bounded runs.
"""

from __future__ import annotations

import time
import urllib.request
from collections import defaultdict
from typing import Callable, Dict, List, Optional

from repro.obs.metrics import histogram_quantile, parse_exposition

__all__ = ["scrape", "rows_from_exposition", "render_table", "run_top"]

CLEAR = "\x1b[2J\x1b[H"


def scrape(url: str, timeout_s: float = 5.0) -> str:
    if url.startswith("file://") or "://" not in url:
        path = url[len("file://"):] if url.startswith("file://") else url
        with open(path, encoding="utf-8") as fh:
            return fh.read()
    if not url.startswith(("http://", "https://")):
        raise ValueError(f"unsupported metrics url: {url!r}")
    with urllib.request.urlopen(url, timeout=timeout_s) as resp:
        return resp.read().decode("utf-8", "replace")


def rows_from_exposition(text: str) -> dict:
    """Fold exposition text into ``{"apps": [row...], "fleet": {...}}``."""
    parsed = parse_exposition(text)
    apps: Dict[str, dict] = defaultdict(lambda: {
        "requests": 0.0, "served": 0.0, "sheds": 0.0, "errors": 0.0,
        "cold": 0.0, "pool": 0.0, "queued": 0.0, "in_flight": 0.0,
        "wait_buckets": []})
    fleet = {"base_swaps": 0.0, "rewarm_ticks": 0.0, "flushed": 0.0}
    for name, labels, value in parsed["samples"]:
        app = labels.get("app")
        if name == "repro_requests_total" and app:
            apps[app]["requests"] += value
        elif name == "repro_sheds_total" and app:
            apps[app]["sheds"] += value
        elif name == "repro_served_total" and app:
            apps[app]["served"] += value
        elif name == "repro_errors_total" and app:
            apps[app]["errors"] += value
        elif name == "repro_dispatch_total" and app:
            path = labels.get("path", "")
            if path in ("cold", "fallback"):
                apps[app]["cold"] += value
            elif path:
                apps[app]["pool"] += value
        elif name == "repro_queue_depth" and app:
            apps[app]["queued"] = value
        elif name == "repro_in_flight" and app:
            apps[app]["in_flight"] = value
        elif name == "repro_queue_wait_ms_bucket" and app:
            try:
                le = labels.get("le", "")
                bound = float("inf") if le == "+Inf" else float(le)
            except ValueError:
                continue
            apps[app]["wait_buckets"].append((bound, value))
        elif name == "repro_base_swaps_total":
            fleet["base_swaps"] += value
        elif name == "repro_rewarm_ticks_total":
            fleet["rewarm_ticks"] += value
        elif name == "repro_flushed_total":
            fleet["flushed"] += value
    rows: List[dict] = []
    for app in sorted(apps):
        a = apps[app]
        starts = a["cold"] + a["pool"]
        p99 = histogram_quantile(0.99, a["wait_buckets"])
        rows.append({
            "app": app,
            "requests": int(a["requests"]),
            "served": int(a["served"]),
            "cold%": f"{(a['cold'] / starts * 100):.1f}"
            if starts else "-",
            "shed%": f"{(a['sheds'] / a['requests'] * 100):.1f}"
            if a["requests"] else "-",
            "errors": int(a["errors"]),
            "queued": int(a["queued"]),
            "in_flight": int(a["in_flight"]),
            "wait_p99_ms": f"{p99:.1f}" if p99 is not None else "-",
        })
    return {"apps": rows, "fleet": fleet}


def render_table(folded: dict, *, clock: str = "") -> str:
    from repro.api.render import table

    cols = ["app", "requests", "served", "cold%", "shed%", "errors",
            "queued", "in_flight", "wait_p99_ms"]
    lines = []
    header = "repro fleet — live metrics"
    if clock:
        header += f"  ({clock})"
    lines.append(header)
    if folded["apps"]:
        lines.append(table(folded["apps"], cols))
    else:
        lines.append("  (no per-app series yet)")
    fl = folded["fleet"]
    lines.append(
        f"fleet: base_swaps={int(fl['base_swaps'])} "
        f"rewarm_ticks={int(fl['rewarm_ticks'])} "
        f"flushed={int(fl['flushed'])}")
    return "\n".join(lines)


def run_top(url: str, *, interval_s: float = 2.0, iterations: int = 0,
            clear: bool = True,
            write: Optional[Callable[[str], None]] = None) -> int:
    """Scrape/render loop.  ``iterations=0`` means run until ^C."""
    out = write or (lambda s: print(s, flush=True))
    count = 0
    while True:
        try:
            text = scrape(url)
        except (OSError, ValueError) as exc:
            out(f"obs top: scrape failed: {exc}")
            return 1
        clock = time.strftime("%H:%M:%S")
        body = render_table(rows_from_exposition(text), clock=clock)
        out((CLEAR if clear else "") + body)
        count += 1
        if iterations and count >= iterations:
            return 0
        try:
            time.sleep(interval_s)
        except KeyboardInterrupt:
            return 0
