"""Counters, gauges and histograms with Prometheus text exposition.

Design points:

* **Idempotent registration** — ``registry.counter(name, ...)`` returns
  the existing family when called again with a matching type and label
  set, so instrumented modules can look families up at call sites and
  survive a test-time :meth:`MetricsRegistry.reset`.
* **Mergeable histograms** — every histogram uses the same fixed
  log-scale (doubling) millisecond bucket bounds, so two snapshots
  merge by adding bucket counts; ``merge_snapshot`` is what lets a
  daemon fold worker-process snapshots into one exposition.
* **Plain-JSON snapshots** — :meth:`MetricsRegistry.snapshot` emits a
  dict safe for the daemon's JSONL ``stats`` reply and for the
  ``trace_events`` artifact.
* **Exposition both ways** — :meth:`MetricsRegistry.render` produces
  Prometheus text format 0.0.4; :func:`parse_exposition` /
  :func:`validate_exposition` read it back (used by ``repro obs top``
  and the CI scrape smoke).

Everything is guarded by per-family locks; a counter ``inc`` is a dict
lookup plus a locked float add (~1µs), cheap enough to leave always-on
in the serving path.
"""

from __future__ import annotations

import bisect
import math
import re
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "DEFAULT_BUCKETS_MS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "parse_exposition",
    "validate_exposition",
    "histogram_quantile",
]

# Log-scale (doubling) millisecond bounds: 0.25ms .. ~32s, +Inf implied.
DEFAULT_BUCKETS_MS: Tuple[float, ...] = tuple(
    0.25 * (2 ** i) for i in range(18))

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _escape(value: str) -> str:
    return (str(value).replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _fmt_labels(labels: Sequence[Tuple[str, str]],
                extra: Sequence[Tuple[str, str]] = ()) -> str:
    pairs = list(labels) + list(extra)
    if not pairs:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class _Child:
    __slots__ = ("_lock",)

    def __init__(self) -> None:
        self._lock = threading.Lock()


class _CounterChild(_Child):
    __slots__ = ("value",)

    def __init__(self) -> None:
        super().__init__()
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self.value += amount


class _GaugeChild(_Child):
    __slots__ = ("value",)

    def __init__(self) -> None:
        super().__init__()
        self.value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)


class _HistogramChild(_Child):
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Sequence[float]) -> None:
        super().__init__()
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        idx = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self.counts[idx] += 1
            self.sum += value
            self.count += 1


class _Family:
    kind = "untyped"
    _child_cls = _Child

    def __init__(self, name: str, help: str,
                 label_names: Sequence[str]) -> None:
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name: {name!r}")
        for ln in label_names:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"bad label name: {ln!r}")
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._lock = threading.Lock()
        self._children: Dict[Tuple[str, ...], _Child] = {}

    def _make_child(self) -> _Child:
        return self._child_cls()

    def labels(self, **labels: str):
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {tuple(sorted(labels))}")
        key = tuple(str(labels[ln]) for ln in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make_child())
        return child

    def _default(self):
        if self.label_names:
            raise ValueError(
                f"{self.name} has labels {self.label_names}; "
                "use .labels(...)")
        return self.labels()

    def series(self) -> List[Tuple[Tuple[str, ...], _Child]]:
        with self._lock:
            return sorted(self._children.items())


class Counter(_Family):
    kind = "counter"
    _child_cls = _CounterChild

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)


class Gauge(_Family):
    kind = "gauge"
    _child_cls = _GaugeChild

    def set(self, value: float) -> None:
        self._default().set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default().dec(amount)


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, name: str, help: str,
                 label_names: Sequence[str],
                 buckets: Sequence[float] = DEFAULT_BUCKETS_MS) -> None:
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        super().__init__(name, help, label_names)

    def _make_child(self) -> _HistogramChild:
        return _HistogramChild(self.buckets)

    def observe(self, value: float) -> None:
        self._default().observe(value)


class MetricsRegistry:
    """A named collection of metric families."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}

    def _get_or_create(self, cls, name: str, help: str,
                       labels: Sequence[str], **kw):
        fam = self._families.get(name)
        if fam is not None:
            if not isinstance(fam, cls) or \
                    fam.label_names != tuple(labels):
                raise ValueError(
                    f"metric {name!r} re-registered as {cls.kind} "
                    f"with labels {tuple(labels)} (was {fam.kind} "
                    f"{fam.label_names})")
            return fam
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = cls(name, help, labels, **kw)
                self._families[name] = fam
            elif not isinstance(fam, cls) or \
                    fam.label_names != tuple(labels):
                raise ValueError(f"metric {name!r} type/label clash")
            return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS_MS
                  ) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   buckets=buckets)

    def families(self) -> List[_Family]:
        with self._lock:
            return [self._families[k] for k in sorted(self._families)]

    def reset(self) -> None:
        with self._lock:
            self._families.clear()

    # -- snapshots -------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-JSON dump of every series (mergeable, artifact-safe)."""
        out: dict = {"schema": "repro.metrics/1", "families": []}
        for fam in self.families():
            entry: dict = {"name": fam.name, "kind": fam.kind,
                           "help": fam.help,
                           "labels": list(fam.label_names),
                           "series": []}
            if isinstance(fam, Histogram):
                entry["buckets"] = list(fam.buckets)
            for key, child in fam.series():
                row: dict = {"labels": list(key)}
                if isinstance(child, _HistogramChild):
                    row["counts"] = list(child.counts)
                    row["sum"] = child.sum
                    row["count"] = child.count
                else:
                    row["value"] = child.value  # type: ignore[attr-defined]
                entry["series"].append(row)
            out["families"].append(entry)
        return out

    def merge_snapshot(self, snap: dict) -> None:
        """Fold a :meth:`snapshot` into this registry.

        Counters and histogram buckets add; gauges take the incoming
        value (last writer wins).  Same-bounds histograms are required —
        the fixed log-scale default makes that the common case.
        """
        for entry in snap.get("families", []):
            kind = entry.get("kind")
            name = entry["name"]
            labels = tuple(entry.get("labels", ()))
            if kind == "counter":
                fam: _Family = self.counter(name, entry.get("help", ""),
                                            labels)
            elif kind == "gauge":
                fam = self.gauge(name, entry.get("help", ""), labels)
            elif kind == "histogram":
                fam = self.histogram(name, entry.get("help", ""), labels,
                                     buckets=entry.get(
                                         "buckets", DEFAULT_BUCKETS_MS))
            else:
                continue
            for row in entry.get("series", []):
                child = fam.labels(**dict(zip(labels, row["labels"])))
                if kind == "counter":
                    child.inc(float(row.get("value", 0.0)))
                elif kind == "gauge":
                    child.set(float(row.get("value", 0.0)))
                else:
                    counts = row.get("counts", [])
                    if len(counts) != len(child.counts):
                        raise ValueError(
                            f"histogram {name!r}: bucket count mismatch")
                    with child._lock:
                        for i, c in enumerate(counts):
                            child.counts[i] += int(c)
                        child.sum += float(row.get("sum", 0.0))
                        child.count += int(row.get("count", 0))

    # -- exposition ------------------------------------------------------
    def render(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: List[str] = []
        for fam in self.families():
            if fam.help:
                lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in fam.series():
                labels = list(zip(fam.label_names, key))
                if isinstance(child, _HistogramChild):
                    cum = 0
                    for bound, n in zip(
                            list(child.bounds) + [math.inf],
                            child.counts):
                        cum += n
                        lines.append(
                            f"{fam.name}_bucket"
                            f"{_fmt_labels(labels, [('le', _fmt_value(bound))])}"
                            f" {cum}")
                    lines.append(
                        f"{fam.name}_sum{_fmt_labels(labels)} "
                        f"{_fmt_value(child.sum)}")
                    lines.append(
                        f"{fam.name}_count{_fmt_labels(labels)} "
                        f"{child.count}")
                else:
                    lines.append(
                        f"{fam.name}{_fmt_labels(labels)} "
                        f"{_fmt_value(child.value)}")  # type: ignore
        return "\n".join(lines) + "\n"


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry used by built-in instrumentation."""
    return _DEFAULT


# ---------------------------------------------------------------------------
# Exposition parsing / validation (obs top + CI scrape smoke)
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[^\s]+)(?:\s+\d+)?$")
_LABEL_PAIR_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _unescape(value: str) -> str:
    return (value.replace('\\"', '"').replace("\\n", "\n")
            .replace("\\\\", "\\"))


def _parse_value(raw: str) -> float:
    if raw == "+Inf":
        return math.inf
    if raw == "-Inf":
        return -math.inf
    return float(raw)


def parse_exposition(text: str) -> dict:
    """Parse Prometheus text format into
    ``{"types": {name: kind}, "samples": [(name, labels, value)]}``.

    ``labels`` is a plain dict.  Raises ``ValueError`` on malformed
    lines so the CI smoke can fail loudly.
    """
    types: Dict[str, str] = {}
    samples: List[Tuple[str, Dict[str, str], float]] = []
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary",
                    "untyped"):
                raise ValueError(f"line {lineno}: bad TYPE line: {line!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: bad sample line: {line!r}")
        labels: Dict[str, str] = {}
        raw = m.group("labels")
        if raw:
            consumed = 0
            for pm in _LABEL_PAIR_RE.finditer(raw):
                labels[pm.group(1)] = _unescape(pm.group(2))
                consumed += 1
            if consumed != len([c for c in raw.split(",") if c.strip()]):
                raise ValueError(
                    f"line {lineno}: bad label set: {raw!r}")
        samples.append((m.group("name"), labels,
                        _parse_value(m.group("value"))))
    return {"types": types, "samples": samples}


def validate_exposition(text: str) -> List[str]:
    """Structural checks beyond parsing; returns a list of problems
    (empty == valid).  Checks: every sample's base name has a TYPE,
    histogram series have ``+Inf`` buckets, bucket counts are
    monotonically non-decreasing, and ``_count`` matches the ``+Inf``
    bucket.
    """
    problems: List[str] = []
    try:
        parsed = parse_exposition(text)
    except ValueError as exc:
        return [str(exc)]
    types = parsed["types"]

    def base_name(name: str) -> str:
        for suffix in ("_bucket", "_sum", "_count"):
            base = name[:-len(suffix)] if name.endswith(suffix) else None
            if base and types.get(base) == "histogram":
                return base
        return name

    hist: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
               Dict[str, object]] = {}
    for name, labels, value in parsed["samples"]:
        base = base_name(name)
        if base not in types:
            problems.append(f"sample {name!r} has no TYPE line")
            continue
        if types[base] == "histogram" and name == base + "_bucket":
            key = (base, tuple(sorted(
                (k, v) for k, v in labels.items() if k != "le")))
            entry = hist.setdefault(key, {"buckets": []})
            entry["buckets"].append(  # type: ignore[union-attr]
                (_parse_value(labels.get("le", "nan")), value))
        elif types[base] == "histogram" and name == base + "_count":
            key = (base, tuple(sorted(labels.items())))
            hist.setdefault(key, {"buckets": []})["count"] = value
    for (base, labels), entry in hist.items():
        buckets = sorted(entry["buckets"])  # type: ignore[arg-type]
        if not buckets or buckets[-1][0] != math.inf:
            problems.append(f"{base}{dict(labels)}: missing +Inf bucket")
            continue
        counts = [c for _, c in buckets]
        if any(b > a for a, b in zip(counts[1:], counts[:-1])):
            problems.append(
                f"{base}{dict(labels)}: bucket counts not monotonic")
        if "count" in entry and entry["count"] != counts[-1]:
            problems.append(
                f"{base}{dict(labels)}: _count != +Inf bucket")
    return problems


def histogram_quantile(q: float,
                       buckets: Iterable[Tuple[float, float]]
                       ) -> Optional[float]:
    """Estimate a quantile from cumulative ``(le, count)`` pairs.

    Returns the upper bound of the first bucket whose cumulative count
    reaches ``q * total`` (the classic conservative estimate); ``None``
    when the histogram is empty.
    """
    pairs = sorted(buckets)
    if not pairs:
        return None
    total = pairs[-1][1]
    if total <= 0:
        return None
    target = q * total
    prev_bound = 0.0
    for bound, cum in pairs:
        if cum >= target:
            if bound == math.inf:
                return prev_bound
            return bound
        prev_bound = bound
    return pairs[-1][0]
