"""Lightweight span tracing for the serving path.

A :class:`Span` is one timed region of one request: a name, a
``trace_id`` shared by every span of the request, its own ``span_id``,
an optional ``parent_id``, a start timestamp and a duration.  All
timestamps come from ``time.perf_counter()`` (CLOCK_MONOTONIC on
Linux), which is system-wide — spans recorded in a forked zygote child
land on the same clock as the daemon's, so a child's ``fork``/``import``
spans nest correctly inside the parent's ``dispatch`` span after the
round-trip over the exec protocol.

The :class:`Tracer` keeps finished spans in a bounded, thread-safe
ring buffer (oldest spans drop first; ``dropped`` counts them).  It is
**disabled by default**: ``tracer.span(...)`` returns a shared no-op
handle without allocating, so instrumentation left in hot paths costs
one attribute load and one branch (benchmarked in
``benchmarks/bench_profiler_overhead.py``).

Spans serialize to plain dicts (:meth:`Span.to_dict`) so they can ride
the zygote stdio/socket protocol as a ``spans`` field on exec replies
and round-trip through the ``trace_events`` artifact.
"""

from __future__ import annotations

import os
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

__all__ = [
    "Span",
    "Tracer",
    "get_tracer",
    "configure_tracing",
    "new_id",
    "now_ms",
    "span_dict",
    "spans_from_import_timer",
]


def now_ms() -> float:
    """Current monotonic time in milliseconds (system-wide clock)."""
    return time.perf_counter() * 1e3


def new_id() -> str:
    """8-byte random hex id (used for both trace and span ids)."""
    return os.urandom(8).hex()


@dataclass
class Span:
    """One finished timed region of one request."""

    name: str
    trace_id: str
    span_id: str
    parent_id: Optional[str] = None
    t_start_ms: float = 0.0
    duration_ms: float = 0.0
    attrs: Dict[str, object] = field(default_factory=dict)

    def to_dict(self) -> dict:
        d = {
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "t_start_ms": round(self.t_start_ms, 3),
            "duration_ms": round(self.duration_ms, 3),
        }
        if self.parent_id:
            d["parent_id"] = self.parent_id
        if self.attrs:
            d["attrs"] = self.attrs
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        return cls(
            name=str(d["name"]),
            trace_id=str(d["trace_id"]),
            span_id=str(d["span_id"]),
            parent_id=d.get("parent_id"),
            t_start_ms=float(d.get("t_start_ms", 0.0)),
            duration_ms=float(d.get("duration_ms", 0.0)),
            attrs=dict(d.get("attrs", {})),
        )


def span_dict(name: str, *, trace_id: str, parent_id: Optional[str],
              t_start_ms: float, duration_ms: float,
              span_id: Optional[str] = None, **attrs: object) -> dict:
    """Build a protocol-ready span dict without touching any tracer.

    Used inside zygote children, which record spans for the *parent's*
    tracer and ship them back on the exec reply.
    """
    return Span(name=name, trace_id=trace_id,
                span_id=span_id or new_id(), parent_id=parent_id,
                t_start_ms=t_start_ms, duration_ms=duration_ms,
                attrs=dict(attrs)).to_dict()


class _SpanHandle:
    """Context manager that records a span on exit.

    ``handle.ctx()`` gives the ``{"trace_id", "parent_id"}`` dict to
    hand to children (including across the zygote protocol).
    """

    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span

    @property
    def span_id(self) -> str:
        return self.span.span_id

    @property
    def trace_id(self) -> str:
        return self.span.trace_id

    def ctx(self) -> dict:
        return {"trace_id": self.span.trace_id,
                "parent_id": self.span.span_id}

    def set(self, key: str, value: object) -> "_SpanHandle":
        self.span.attrs[key] = value
        return self

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.end()

    def end(self) -> None:
        if self.span.duration_ms == 0.0:
            self.span.duration_ms = now_ms() - self.span.t_start_ms
        self._tracer.record(self.span)


class _NoopHandle:
    """Shared do-nothing handle returned when tracing is disabled."""

    __slots__ = ()
    span_id = ""
    trace_id = ""

    def ctx(self):  # noqa: D102 - mirrors _SpanHandle
        return None

    def set(self, key, value):
        return self

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return None

    def end(self):
        return None

    def __bool__(self):
        return False


_NOOP = _NoopHandle()


class Tracer:
    """Thread-safe bounded collector of finished spans."""

    def __init__(self, capacity: int = 65536, enabled: bool = False):
        self._buf: deque = deque(maxlen=max(1, int(capacity)))
        self._lock = threading.Lock()
        self.enabled = bool(enabled)
        self.dropped = 0

    @property
    def capacity(self) -> int:
        return self._buf.maxlen or 0

    def configure(self, *, enabled: Optional[bool] = None,
                  capacity: Optional[int] = None) -> "Tracer":
        with self._lock:
            if capacity is not None and capacity != self._buf.maxlen:
                self._buf = deque(self._buf, maxlen=max(1, int(capacity)))
            if enabled is not None:
                self.enabled = bool(enabled)
        return self

    # -- producing spans -------------------------------------------------
    def span(self, name: str, *, ctx: Optional[dict] = None,
             **attrs: object):
        """Open a span; returns a no-op handle when disabled.

        ``ctx`` is a ``{"trace_id", "parent_id"}`` dict from a parent
        handle's :meth:`_SpanHandle.ctx` (or off the wire).  Without
        one, the span starts a fresh trace as its root.
        """
        if not self.enabled:
            return _NOOP
        trace_id = parent_id = None
        if ctx:
            trace_id = ctx.get("trace_id")
            parent_id = ctx.get("parent_id")
        return _SpanHandle(self, Span(
            name=name, trace_id=trace_id or new_id(), span_id=new_id(),
            parent_id=parent_id, t_start_ms=now_ms(), attrs=dict(attrs)))

    def add(self, name: str, *, trace_id: str,
            parent_id: Optional[str] = None,
            span_id: Optional[str] = None, t_start_ms: float,
            duration_ms: float, attrs: Optional[dict] = None) -> str:
        """Record a span whose start/duration were measured elsewhere
        (e.g. queue wait derived from the enqueue timestamp)."""
        sid = span_id or new_id()
        if self.enabled:
            self.record(Span(name=name, trace_id=trace_id, span_id=sid,
                             parent_id=parent_id, t_start_ms=t_start_ms,
                             duration_ms=duration_ms,
                             attrs=dict(attrs or {})))
        return sid

    def record(self, span: Span) -> None:
        with self._lock:
            if len(self._buf) == self._buf.maxlen:
                self.dropped += 1
            self._buf.append(span)

    def record_dicts(self, dicts: Optional[Iterable[dict]]) -> None:
        """Record protocol span dicts (e.g. the ``spans`` reply field)."""
        if not dicts or not self.enabled:
            return
        for d in dicts:
            try:
                self.record(Span.from_dict(d))
            except (KeyError, TypeError, ValueError):
                continue

    # -- consuming spans -------------------------------------------------
    def snapshot(self) -> List[Span]:
        with self._lock:
            return list(self._buf)

    def drain(self) -> List[Span]:
        with self._lock:
            out = list(self._buf)
            self._buf.clear()
            return out

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0


_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    """The process-wide tracer used by all built-in instrumentation."""
    return _GLOBAL


def configure_tracing(*, enabled: Optional[bool] = None,
                      capacity: Optional[int] = None) -> Tracer:
    return _GLOBAL.configure(enabled=enabled, capacity=capacity)


def spans_from_import_timer(records, *, trace_id: str,
                            parent_id: Optional[str],
                            t_start_ms: float) -> List[dict]:
    """Convert :class:`~repro.core.profiler.import_timer.ImportTimer`
    records into per-module ``import:<mod>`` span dicts.

    The timer measures self/cumulative seconds and parent chains but not
    absolute timestamps, so every span inherits the import phase's start
    time; duration is the module's *cumulative* init and ``self_ms``
    rides along in attrs for flamegraph self-time attribution.  Module
    parent chains become span parent chains, so nested imports nest.
    """
    by_mod: Dict[str, str] = {}
    out: List[dict] = []
    for mod in records:
        by_mod[mod] = new_id()
    for mod, rec in records.items():
        parent = by_mod.get(getattr(rec, "parent", None) or "", parent_id)
        out.append(span_dict(
            f"import:{mod}", trace_id=trace_id, parent_id=parent,
            span_id=by_mod[mod], t_start_ms=t_start_ms,
            duration_ms=rec.cumulative_s * 1e3,
            module=mod, self_ms=round(rec.self_s * 1e3, 3)))
    return out
