"""Cold-start anatomy: turn a bag of spans into an attribution story.

The model: every request is one *trace* whose root span ("request")
measures end-to-end wall time.  Child spans (queue_wait, dispatch,
fork, import, import:<module>, invoke, cold_start, ...) partition that
time; whatever the children don't cover is the root's *self time* and
shows up as ``(unattributed)`` so the per-phase table always sums to
the measured end-to-end latency — the acceptance bar is that the
unattributed share stays small.

Outputs:

* :func:`phase_breakdown` — per-phase count / p50 / p99 / total self
  time / share-of-wall, plus overall attribution coverage.
* :func:`top_imports` — slowest ``import:*`` spans (per-module, keyed
  by cumulative init with self time alongside).
* :func:`folded_stacks` — ``root;child;leaf value`` lines compatible
  with Brendan Gregg's ``flamegraph.pl`` (values in microseconds of
  span *self* time).
* :func:`render_report` — the human table ``repro obs report`` prints.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Sequence

from repro.obs.tracing import Span

__all__ = [
    "build_traces",
    "phase_breakdown",
    "top_imports",
    "folded_stacks",
    "render_report",
    "UNATTRIBUTED",
]

UNATTRIBUTED = "(unattributed)"

# Stable presentation order for the well-known lifecycle phases; any
# other span name sorts after these, alphabetically.
_PHASE_ORDER = ["request", "enqueue", "queue_wait", "dispatch",
                "zygote_boot", "spawn_app", "preload", "fork", "import",
                "invoke", "cold_start", "engine_cold_start",
                "engine_serve", UNATTRIBUTED]


def _coerce(spans: Iterable) -> List[Span]:
    out = []
    for s in spans:
        out.append(s if isinstance(s, Span) else Span.from_dict(s))
    return out


class TraceTree:
    """One trace: its spans, child index and computed self times."""

    def __init__(self, trace_id: str, spans: List[Span]):
        self.trace_id = trace_id
        self.spans = spans
        self.by_id = {s.span_id: s for s in spans}
        self.children: Dict[str, List[Span]] = defaultdict(list)
        self.roots: List[Span] = []
        for s in spans:
            if s.parent_id and s.parent_id in self.by_id:
                self.children[s.parent_id].append(s)
            else:
                self.roots.append(s)

    def self_ms(self, span: Span) -> float:
        kids = sum(c.duration_ms for c in self.children[span.span_id])
        return max(0.0, span.duration_ms - kids)

    @property
    def root(self) -> Optional[Span]:
        # Prefer an explicit request root; else the longest top-level.
        named = [s for s in self.roots if s.name == "request"]
        pool = named or self.roots
        return max(pool, key=lambda s: s.duration_ms) if pool else None


def build_traces(spans: Iterable) -> List[TraceTree]:
    groups: Dict[str, List[Span]] = defaultdict(list)
    for s in _coerce(spans):
        groups[s.trace_id].append(s)
    return [TraceTree(tid, ss) for tid, ss in groups.items()]


def _percentile(values: Sequence[float], q: float) -> float:
    if not values:
        return 0.0
    vs = sorted(values)
    idx = min(len(vs) - 1, int(round(q * (len(vs) - 1))))
    return vs[idx]


def _phase_name(span: Span) -> str:
    # Per-module import spans roll up into the "import" phase for the
    # breakdown table; top_imports keeps them individual.
    if span.name.startswith("import:"):
        return "import"
    if span.name.startswith("preload:"):
        return "preload"
    return span.name


def phase_breakdown(spans: Iterable) -> dict:
    """Aggregate self time per phase across every complete trace.

    Returns ``{"phases": [row...], "requests": n,
    "wall_ms_total": t, "attributed_frac": f}`` where each row has
    ``phase, count, p50_ms, p99_ms, total_ms, share`` and rows sum
    (by construction, via the unattributed residual) to the wall time.
    """
    traces = [t for t in build_traces(spans) if t.root is not None]
    per_phase_self: Dict[str, List[float]] = defaultdict(list)
    per_phase_dur: Dict[str, List[float]] = defaultdict(list)
    wall_total = 0.0
    request_wall = 0.0
    n_requests = 0
    for tree in traces:
        root = tree.root
        wall_total += root.duration_ms
        is_request = root.name == "request"
        if is_request:
            n_requests += 1
            request_wall += root.duration_ms
        for s in tree.spans:
            if s is root:
                continue
            phase = _phase_name(s)
            per_phase_self[phase].append(tree.self_ms(s))
            per_phase_dur[phase].append(s.duration_ms)
        resid = tree.self_ms(root)
        if is_request:
            per_phase_self[UNATTRIBUTED].append(resid)
            per_phase_dur[UNATTRIBUTED].append(resid)
        else:
            # a non-request trace (zygote_boot / spawn_app) *is* its
            # own phase: its residual is that phase's self time, not
            # unexplained request latency
            per_phase_self[_phase_name(root)].append(resid)
            per_phase_dur[_phase_name(root)].append(root.duration_ms)

    def order(name: str):
        try:
            return (0, _PHASE_ORDER.index(name))
        except ValueError:
            return (1, name)

    rows = []
    for phase in sorted(per_phase_self, key=order):
        self_ms = per_phase_self[phase]
        durs = per_phase_dur[phase]
        rows.append({
            "phase": phase,
            "count": len(durs),
            "p50_ms": round(_percentile(durs, 0.50), 3),
            "p99_ms": round(_percentile(durs, 0.99), 3),
            "total_ms": round(sum(self_ms), 3),
            "share": round(sum(self_ms) / wall_total, 4)
            if wall_total else 0.0,
        })
    unattr = sum(per_phase_self.get(UNATTRIBUTED, []))
    return {
        "requests": n_requests,
        "traces": len(traces),
        "wall_ms_total": round(wall_total, 3),
        "request_wall_ms": round(request_wall, 3),
        "attributed_frac": round(1.0 - (unattr / wall_total), 4)
        if wall_total else 1.0,
        "phases": rows,
    }


def top_imports(spans: Iterable, n: int = 10) -> List[dict]:
    """Slowest modules by cumulative init across all traces."""
    agg: Dict[str, dict] = {}
    for s in _coerce(spans):
        if not s.name.startswith("import:"):
            continue
        mod = s.attrs.get("module") or s.name[len("import:"):]
        row = agg.setdefault(mod, {"module": mod, "count": 0,
                                   "cumulative_ms": 0.0, "self_ms": 0.0})
        row["count"] += 1
        row["cumulative_ms"] += s.duration_ms
        row["self_ms"] += float(s.attrs.get("self_ms", s.duration_ms))
    out = sorted(agg.values(), key=lambda r: -r["cumulative_ms"])[:n]
    for row in out:
        row["cumulative_ms"] = round(row["cumulative_ms"], 3)
        row["self_ms"] = round(row["self_ms"], 3)
    return out


def folded_stacks(spans: Iterable) -> List[str]:
    """``frame;frame;frame value`` lines for flamegraph.pl.

    One line per span, path from the trace root down, value = span
    self time in integer microseconds (zero-valued frames are kept out
    to match flamegraph.pl expectations).
    """
    counts: Dict[str, int] = defaultdict(int)
    for tree in build_traces(spans):
        for s in tree.spans:
            path: List[str] = []
            cur: Optional[Span] = s
            seen = set()
            while cur is not None and cur.span_id not in seen:
                seen.add(cur.span_id)
                path.append(cur.name.replace(";", ":"))
                cur = tree.by_id.get(cur.parent_id or "")
            us = int(round(tree.self_ms(s) * 1000))
            if us > 0:
                counts[";".join(reversed(path))] += us
    return [f"{path} {us}" for path, us in sorted(counts.items())]


def render_report(spans: Iterable, *, top_n: int = 10,
                  meta: Optional[dict] = None) -> str:
    """Human-readable cold-start anatomy report."""
    from repro.api.render import table

    breakdown = phase_breakdown(spans)
    lines: List[str] = []
    lines.append("cold-start anatomy")
    if meta:
        src = ", ".join(f"{k}={v}" for k, v in sorted(meta.items())
                        if not isinstance(v, (dict, list)))
        if src:
            lines.append(f"  source: {src}")
    n = breakdown["requests"]
    wall = breakdown["wall_ms_total"]
    req_wall = breakdown["request_wall_ms"]
    lines.append(
        f"  requests: {n} (of {breakdown['traces']} traces)   "
        f"wall: {wall:.1f} ms total"
        + (f" ({req_wall / n:.2f} ms/req)" if n else ""))
    lines.append(
        f"  attributed: {breakdown['attributed_frac'] * 100:.1f}% of "
        "end-to-end time is covered by child spans")
    lines.append("")
    lines.append(table(
        [{"phase": r["phase"], "count": r["count"],
          "p50 ms": f"{r['p50_ms']:.2f}",
          "p99 ms": f"{r['p99_ms']:.2f}",
          "total ms": f"{r['total_ms']:.1f}",
          "share": f"{r['share'] * 100:.1f}%"}
         for r in breakdown["phases"]],
        ["phase", "count", "p50 ms", "p99 ms", "total ms", "share"]))
    imports = top_imports(spans, n=top_n)
    if imports:
        lines.append("")
        lines.append(f"top {len(imports)} slowest imports "
                     "(cumulative module init):")
        lines.append(table(
            [{"module": r["module"], "count": r["count"],
              "cum ms": f"{r['cumulative_ms']:.2f}",
              "self ms": f"{r['self_ms']:.2f}"} for r in imports],
            ["module", "count", "cum ms", "self ms"]))
    return "\n".join(lines)
