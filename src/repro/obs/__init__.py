"""Observability: span tracing, metrics, exposition, structured logs.

Stdlib-only.  The subsystem is **off by default** — the tracer is a
no-op until :func:`configure_tracing` (or ``--trace-out`` on the CLI)
enables it, and metrics counters are cheap enough to stay always-on.

Layout::

    tracing.py     Span / Tracer (trace_id/span_id, monotonic clock,
                   thread-safe ring buffer) + protocol serialization
    metrics.py     Counter/Gauge/Histogram registry, Prometheus text
                   exposition, parser + format validator
    exposition.py  stdlib HTTP /metrics endpoint + textfile writer
    log.py         structured (JSONL or text) leveled logging
    anatomy.py     cold-start anatomy analysis over trace_events
    console.py     ``repro obs top`` live per-app fleet table
"""

from repro.obs.tracing import (  # noqa: F401
    Span,
    Tracer,
    configure_tracing,
    get_tracer,
)
from repro.obs.metrics import (  # noqa: F401
    MetricsRegistry,
    default_registry,
)

__all__ = [
    "Span",
    "Tracer",
    "configure_tracing",
    "get_tracer",
    "MetricsRegistry",
    "default_registry",
]
