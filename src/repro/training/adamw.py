"""Mixed-precision AdamW (pure JAX, no optax dependency).

Keeps fp32 master weights and fp32 first/second moments; the model
parameters stay in the model dtype (bf16) and are re-cast from the
masters every step.  All optimizer state shards exactly like its
parameter (same PartitionSpec), so TP/EP-sharded layers get sharded
optimizer state for free (ZeRO-style along the model axis).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    master: Any  # fp32 parameter copies
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, f32)
    # copy=True: for fp32 models .astype would alias the param buffer and
    # break (params, opt_state) double-donation in the fused train step
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(lambda p: jnp.array(p, dtype=f32, copy=True),
                            params),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(grads, state: AdamWState, params, *, lr=3e-4, b1=0.9,
                 b2=0.95, eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state).  Global-norm clipping included."""
    step = state.step + 1
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(f32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))

    c1 = 1.0 - b1 ** step.astype(f32)
    c2 = 1.0 - b2 ** step.astype(f32)

    def upd(g, m, v, master):
        g = g.astype(f32) * scale
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        master = master - lr * (mhat / (jnp.sqrt(vhat) + eps)
                                + weight_decay * master)
        return m, v, master

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    flat_ma = tdef.flatten_up_to(state.master)
    out = [upd(g, m, v, ma)
           for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
    mu = tdef.unflatten([o[0] for o in out])
    nu = tdef.unflatten([o[1] for o in out])
    master = tdef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(
        lambda ma, p: ma.astype(p.dtype), master, params)
    return new_params, AdamWState(step, master, mu, nu)
