"""Deterministic synthetic data pipeline.

A seeded token stream (mixture of Zipf-distributed unigrams and
repeated n-gram "phrases" so a real LM loss signal exists), packed into
fixed-length training sequences, with an async double-buffered host
prefetcher — the structure of a production input pipeline without an
external dataset dependency.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticCorpus:
    """Zipf unigrams + phrase bank => learnable next-token structure."""

    def __init__(self, vocab: int, seed: int = 0, phrase_bank: int = 512,
                 phrase_len: int = 8, phrase_prob: float = 0.5):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.phrases = self.rng.integers(
            0, vocab, (phrase_bank, phrase_len))
        self.phrase_prob = phrase_prob
        # Zipf over the vocab, renormalized
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.p = 1.0 / ranks
        self.p /= self.p.sum()

    def tokens(self, n: int) -> np.ndarray:
        out = np.empty(n, np.int32)
        i = 0
        while i < n:
            if self.rng.random() < self.phrase_prob:
                ph = self.phrases[self.rng.integers(len(self.phrases))]
                m = min(len(ph), n - i)
                out[i:i + m] = ph[:m]
                i += m
            else:
                m = min(int(self.rng.integers(4, 16)), n - i)
                out[i:i + m] = self.rng.choice(
                    self.vocab, size=m, p=self.p)
                i += m
        return out


def packed_batches(corpus: SyntheticCorpus, batch: int, seq: int
                   ) -> Iterator[dict]:
    """Yields {"tokens": (B, S), "labels": (B, S)} next-token pairs."""
    while True:
        flat = corpus.tokens(batch * (seq + 1))
        arr = flat.reshape(batch, seq + 1)
        yield {"tokens": arr[:, :-1].copy(),
               "labels": arr[:, 1:].copy()}


class Prefetcher:
    """Host-side async prefetch (double buffering) over an iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def make_pipeline(vocab: int, batch: int, seq: int, *, seed: int = 0,
                  prefetch: int = 2) -> Iterator[dict]:
    corpus = SyntheticCorpus(vocab, seed=seed)
    return Prefetcher(packed_batches(corpus, batch, seq), depth=prefetch)
