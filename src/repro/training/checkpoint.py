"""Sharded checkpoint save/restore with elastic re-shard.

Layout: one directory per step containing
  meta.msgpack      — pytree structure, shapes, dtypes, step, mesh shape
  arrays/<idx>.npy  — one file per leaf (host-gathered)

Restore accepts a *different* mesh than the one that saved: arrays are
loaded host-side and re-placed under the target sharding (elastic
scaling across pod counts).  Atomicity: writes go to ``<dir>.tmp`` and
are renamed on completion, so a crash mid-save never corrupts the
latest checkpoint; ``latest_step`` only sees committed directories.
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

try:
    import msgpack
except ImportError:  # pragma: no cover
    msgpack = None


def _tree_meta(tree) -> dict:
    leaves, treedef = jax.tree.flatten(tree)
    return {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype if not hasattr(l, "dtype")
                       else l.dtype) for l in leaves],
    }


def save_checkpoint(ckpt_dir: str | Path, step: int, tree,
                    extra: Optional[dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    (tmp / "arrays").mkdir(parents=True)

    leaves, _ = jax.tree.flatten(tree)
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / "arrays" / f"{i}.npy", arr)

    meta = _tree_meta(tree)
    meta["step"] = step
    meta["extra"] = extra or {}
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, step: int, target_tree,
                       shardings=None):
    """Restore into the structure of ``target_tree``.

    shardings: optional pytree of NamedSharding (same structure) — the
    elastic-rescale path: arrays saved under any mesh are re-placed
    under the *current* mesh/sharding via jax.device_put.
    """
    path = Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((path / "meta.json").read_text())
    leaves, treedef = jax.tree.flatten(target_tree)
    assert meta["n_leaves"] == len(leaves), \
        f"checkpoint has {meta['n_leaves']} leaves, target {len(leaves)}"
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(path / "arrays" / f"{i}.npy")
        want = np.shape(ref)
        assert tuple(arr.shape) == tuple(want), \
            f"leaf {i}: saved {arr.shape} != target {want}"
        arr = arr.astype(np.asarray(ref).dtype if not hasattr(ref, "dtype")
                         else ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), meta
