"""Step functions lowered by the launcher and the multi-pod dry-run.

``make_train_step`` builds the canonical fused step:
    grads = grad(loss); AdamW update; metrics
with optional microbatch gradient accumulation (scan over microbatches)
and optional int8 cross-pod gradient compression (see compress.py).

``make_serve_steps`` builds (prefill_fn, decode_fn) for the serving
shapes; decode is greedy (argmax) one-token generation against the
caller-provided KV/recurrent cache.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.model import (
    constrain_like_params, decode_step, loss_fn, prefill,
)
from repro.training.adamw import AdamWState, adamw_update


def make_train_step(cfg: ArchConfig, *, lr=3e-4, accum_steps: int = 1,
                    compress_fn=None):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).

    accum_steps > 1 splits the batch on the leading dim into
    microbatches and accumulates grads in fp32 via lax.scan — the
    activation-memory lever for the big train cells.
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        return grads, metrics

    def train_step(params, opt_state: AdamWState, batch):
        if accum_steps == 1:
            grads, metrics = grads_of(params, batch)
        else:
            def micro(i, b):
                return jax.tree.map(
                    lambda x: x.reshape((accum_steps, -1) + x.shape[1:])[i],
                    b)

            def body(carry, i):
                acc = carry
                g, m = grads_of(params, micro(i, batch))
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return acc, m

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(body, zero,
                                     jnp.arange(accum_steps))
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda x: x[-1], ms)
        if compress_fn is not None:
            grads = compress_fn(grads)
        new_params, new_opt = adamw_update(grads, opt_state, params, lr=lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return new_params, new_opt, metrics

    return train_step


def make_serve_steps(cfg: ArchConfig, cache_len: Optional[int] = None):
    """(prefill_fn, decode_fn) for serving.

    prefill_fn(params, batch)  -> (next_token, caches)
    decode_fn(params, token, pos, caches) -> (next_token, logits, caches)
    """

    def prefill_fn(params, batch):
        logits, caches, _ = prefill(
            cfg, params, batch["tokens"], cache_len=cache_len,
            patch_embeds=batch.get("patch_embeds"),
            enc_frames=batch.get("enc_frames"))
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    def decode_fn(params, token, pos, caches):
        logits, new_caches = decode_step(cfg, params, token, pos, caches)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt[:, None], logits, new_caches

    return prefill_fn, decode_fn
