"""Fault tolerance: straggler watchdog and checkpoint/restart loop.

The driver wraps every training step with a wall-clock deadline.  A
step exceeding ``soft_deadline`` is recorded as a straggler event (on a
real multi-host fleet this feeds the controller that re-slices the job
around slow hosts); exceeding ``hard_deadline`` or raising triggers the
restart path: reload the latest checkpoint and continue.  Elastic
restarts may come back on a different mesh — restore re-places arrays
under the new sharding (see checkpoint.restore_checkpoint).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class StragglerStats:
    steps: int = 0
    slow_steps: int = 0
    restarts: int = 0
    worst_step_s: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


class StepWatchdog:
    """Deadline accounting around synchronous steps."""

    def __init__(self, soft_deadline_s: float, hard_deadline_s:
                 Optional[float] = None):
        self.soft = soft_deadline_s
        self.hard = hard_deadline_s or (soft_deadline_s * 10)
        self.stats = StragglerStats()

    def run(self, fn: Callable, *args, **kw):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = time.perf_counter() - t0
        self.stats.steps += 1
        self.stats.worst_step_s = max(self.stats.worst_step_s, dt)
        if dt > self.soft:
            self.stats.slow_steps += 1
        if dt > self.hard:
            raise StragglerTimeout(
                f"step took {dt:.2f}s > hard deadline {self.hard:.2f}s")
        return out


class StragglerTimeout(RuntimeError):
    pass


class RestartableLoop:
    """Run a step loop with automatic restart-from-checkpoint.

    ``make_state()`` builds fresh state; ``save(step, state)`` /
    ``restore(step)`` persist it; ``step_fn(step, state)`` advances.
    Injected failures (tests) and StragglerTimeout both route through
    the restart path, bounded by ``max_restarts``.
    """

    def __init__(self, *, step_fn, make_state, save, restore,
                 latest, ckpt_every: int = 10, max_restarts: int = 3,
                 watchdog: Optional[StepWatchdog] = None):
        self.step_fn = step_fn
        self.make_state = make_state
        self.save = save
        self.restore = restore
        self.latest = latest
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.watchdog = watchdog or StepWatchdog(soft_deadline_s=60.0)

    def run(self, n_steps: int):
        restarts = 0
        last = self.latest()
        if last is not None:
            step, state = self.restore(last)
        else:
            step, state = 0, self.make_state()
        while step < n_steps:
            try:
                state = self.watchdog.run(self.step_fn, step, state)
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    self.save(step, state)
            except Exception:
                restarts += 1
                self.watchdog.stats.restarts = restarts
                if restarts > self.max_restarts:
                    raise
                last = self.latest()
                if last is None:
                    step, state = 0, self.make_state()
                else:
                    step, state = self.restore(last)
        return step, state, self.watchdog.stats
