"""Training substrate: optimizer, step functions, data, checkpoints."""

from repro.training.adamw import adamw_init, adamw_update  # noqa: F401
from repro.training.step import make_train_step, make_serve_steps  # noqa: F401
