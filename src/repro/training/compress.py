"""int8 gradient compression for the cross-pod all-reduce.

On the multi-pod mesh the gradient all-reduce crosses the slow inter-pod
links.  This module provides the standard remedy: per-tensor-scaled int8
quantization with error feedback.  Two modes:

* ``simulate_int8`` — SPMD-friendly: quantize -> dequantize around the
  (XLA-inserted) all-reduce.  Numerically identical traffic pattern to
  real int8 wire format when XLA reduces over the quantized values; used
  inside jit'd train steps and validated for convergence impact.
* ``shard_map_int8_allreduce`` — explicit manual-collective variant:
  under ``shard_map`` (manual over "pod", auto elsewhere) the int32
  psum really moves 4x fewer gradient bytes than fp32 across the pod
  axis (int8 payload packed in int32 accumulators).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

f32 = jnp.float32


def quantize_int8(x):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(f32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(f32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(f32) * scale


def make_error_feedback_compressor():
    """Stateful error-feedback int8 compressor: compress(grads, state)
    -> (grads_hat, new_state).  The residual (g - ĝ) is carried and
    added before the next quantization (Karimireddy et al.)."""

    def compress(grads, err_state):
        if err_state is None:
            err_state = jax.tree.map(
                lambda g: jnp.zeros(g.shape, f32), grads)

        def one(g, e):
            g32 = g.astype(f32) + e
            q, scale = quantize_int8(g32)
            ghat = dequantize_int8(q, scale)
            return ghat.astype(g.dtype), g32 - ghat

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(err_state)
        pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        ghat = tdef.unflatten([p[0] for p in pairs])
        err = tdef.unflatten([p[1] for p in pairs])
        return ghat, err

    return compress


def simulate_int8(grads):
    """Stateless quantize->dequantize (jit/SPMD path)."""
    def one(g):
        q, scale = quantize_int8(g)
        return dequantize_int8(q, scale).astype(g.dtype)
    return jax.tree.map(one, grads)


def shard_map_int8_allreduce(grads, mesh, axis: str = "pod"):
    """Explicit int8 all-reduce across ``axis`` via shard_map.

    Each pod quantizes its local gradient, the int32 psum crosses the
    pod links (4x fewer bytes than fp32; scales are psum'd separately as
    one fp32 scalar per tensor), and the result is dequantized with the
    max scale — a conservative shared-scale scheme.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    if axis not in mesh.shape:
        return grads
    npods = mesh.shape[axis]

    def reduce_one(g):
        def inner(gl):
            q, scale = quantize_int8(gl)
            scale_max = jax.lax.pmax(scale, axis)
            # requantize against the shared scale so the integer sum is
            # exact across pods
            q = jnp.clip(jnp.round(gl.astype(f32) / scale_max), -127, 127
                         ).astype(jnp.int32)
            qs = jax.lax.psum(q, axis)
            return (qs.astype(f32) * scale_max / npods).astype(gl.dtype)
        return shard_map(inner, mesh=mesh, in_specs=P(),
                         out_specs=P(), check_vma=False)(g)

    return jax.tree.map(reduce_one, grads)
