"""RG-LRU linear-recurrence Pallas kernel (TPU target).

Computes h_t = a_t * h_{t-1} + b_t (elementwise, diagonal recurrence —
the core of recurrentgemma's RG-LRU after gates are formed) over the
time axis, with the state carried in VMEM scratch across sequential
time tiles.  Grid: (batch, channel_blocks, time_blocks) — time
innermost/sequential; channels are vector lanes.

Unlike attention this is bandwidth-bound: the tile is (block_t x
block_r) and each element is read/written once, so block shapes only
need VPU lane alignment (block_r multiple of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, o_ref, state_ref, *, block_t):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # (bt, br)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t] = h.astype(o_ref.dtype)
        return h

    state_ref[...] = lax.fori_loop(0, block_t, step, state_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_r", "interpret"))
def rglru_scan(a, b, h0=None, *, block_t=128, block_r=128,
               interpret=False):
    """a, b: (B, S, R) decay/input; h0: (B, R) initial state or None.

    Returns h: (B, S, R) with h[:, t] = a[:, t] * h[:, t-1] + b[:, t].
    """
    B, S, R = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, R), a.dtype)
    bt = min(block_t, max(S, 8))
    br = min(block_r, max(R, 128))
    nt, nr = -(-S // bt), -(-R // br)
    pad_t, pad_r = nt * bt - S, nr * br - R
    if pad_t or pad_r:
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_r)))
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_r)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_r)))

    kernel = functools.partial(_kernel, block_t=bt)
    out = pl.pallas_call(
        kernel,
        grid=(B, nr, nt),
        in_specs=[
            pl.BlockSpec((1, bt, br), lambda bi, ir, it: (bi, it, ir)),
            pl.BlockSpec((1, bt, br), lambda bi, ir, it: (bi, it, ir)),
            pl.BlockSpec((1, br), lambda bi, ir, it: (bi, ir)),
        ],
        out_specs=pl.BlockSpec((1, bt, br),
                               lambda bi, ir, it: (bi, it, ir)),
        out_shape=jax.ShapeDtypeStruct((B, nt * bt, nr * br), a.dtype),
        scratch_shapes=[pltpu.VMEM((br,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return out[:, :S, :R]
