"""Pallas TPU kernels for the serving hot spots.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), a jit'd
wrapper in ops.py, and a pure-jnp oracle in ref.py.  On CPU the kernels
run in interpret mode (the body executes in Python) — the TPU is the
compilation target, the oracle the correctness contract.
"""

from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.decode_attention import decode_attention  # noqa: F401
from repro.kernels.rglru_scan import rglru_scan  # noqa: F401
from repro.kernels import ops, ref  # noqa: F401
