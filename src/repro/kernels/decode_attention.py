"""Single-token GQA decode attention Pallas kernel (TPU target).

Streams a long KV cache (32k-500k tokens) through VMEM in blocks.  The
query is one token per sequence; validity comes from an explicit
slot-position array (``kv_pos``, -1 = empty slot) so the same kernel
serves position-indexed global caches and ring-buffer local caches.

Grid: (batch, kv_head, kv_blocks) — kv innermost, online-softmax state
(acc/max/denominator for the G=H/K query heads of this kv head) carried
in VMEM scratch.  Block ~ (block_kv x hd) = 128x256 fp32 = 128 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, kvpos_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale, window, softcap, block_kv,
            n_kv_blocks):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)          # (bkv, hd)
    kv_pos = kvpos_ref[0]                        # (bkv,) int32
    q_pos = qpos_ref[0, 0]                       # scalar int32

    s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window is not None:
        valid &= kv_pos > q_pos - window
    s = jnp.where(valid[None, :], s, NEG_INF)    # (G, bkv)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "block_kv", "interpret"))
def decode_attention(q, k, v, q_pos, kv_pos, *, window=None, softcap=None,
                     block_kv=128, interpret=False):
    """One-token GQA attention over a cached KV.

    q: (B, K, G, hd) — the G query heads per kv head;
    k, v: (B, K, S, hd) cache; q_pos: (B,) int32 current positions;
    kv_pos: (B, S) int32 absolute positions per slot (-1 = empty).
    Returns (B, K, G, hd).
    """
    B, K, G, hd = q.shape
    S = k.shape[2]
    scale = hd ** -0.5
    bkv = min(block_kv, max(S, 8))
    nkv = -(-S // bkv)
    pad = nkv * bkv - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    qp = q_pos.reshape(B, 1, 1).astype(jnp.int32)

    kernel = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        block_kv=bkv, n_kv_blocks=nkv)

    out = pl.pallas_call(
        kernel,
        grid=(B, K, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, 1), lambda b, h, j: (b, 0, 0)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, bkv), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, q, k, v, kv_pos)
    return out
