"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``ref_*`` mirrors its kernel's exact semantics (masking rules,
softcap placement, fp32 accumulation) with straightforward jnp code.
Kernel tests sweep shapes/dtypes and assert allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_flash_attention(q, k, v, *, causal=True, window=None,
                        softcap=None):
    """q: (B, H, Sq, hd); k, v: (B, K, Skv, hd)."""
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * hd ** -0.5
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)


def ref_decode_attention(q, k, v, q_pos, kv_pos, *, window=None,
                         softcap=None):
    """q: (B, K, G, hd); k, v: (B, K, S, hd); q_pos: (B,);
    kv_pos: (B, S) (-1 = empty)."""
    hd = q.shape[-1]
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = (kv_pos >= 0) & (kv_pos <= q_pos[:, None])
    if window is not None:
        valid &= kv_pos > (q_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ref_rglru_scan(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t via jax associative scan (fp32)."""
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    b32 = b32.at[:, 0].add(a32[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, h1 = c1
        a2, h2 = c2
        return a1 * a2, h1 * a2 + h2

    _, h = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    return h.astype(a.dtype)
