"""Flash attention Pallas kernel (TPU target).

Blocked online-softmax attention with causal masking, GQA, sliding
windows (gemma local layers) and logit softcapping (gemma2).  Grid is
(batch, q_head, q_blocks, kv_blocks); the kv dimension is innermost so
the fp32 accumulator/max/denominator live in VMEM scratch across kv
steps (TPU executes the innermost grid dimension sequentially per
core).  Block shapes are MXU-aligned (q/kv tiles default 128) and sized
so q/k/v/acc tiles fit comfortably in VMEM:
  128x256 fp32 x 4 buffers ~= 512 KiB << 16 MiB.

Validated against ``ref.py`` in interpret mode (see tests/test_kernels).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, softcap, block_q, block_kv, seq_q,
            seq_kv, n_kv_blocks):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * block_q + lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = ikv * block_kv + lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)

    # skip fully-masked tiles (below the causal diagonal / outside window)
    run = k_pos[0, 0] < seq_kv  # tile begins inside the real sequence
    if causal:
        run = jnp.logical_and(run, ikv * block_kv <= iq * block_q
                              + block_q - 1)
    if window is not None:
        run = jnp.logical_and(
            run, (iq * block_q) - (ikv * block_kv + block_kv - 1) < window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = (k_pos < seq_kv) & (q_pos < seq_q)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ikv == n_kv_blocks - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_kv",
                     "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    block_q=128, block_kv=128, interpret=False):
    """q: (B, H, Sq, hd); k, v: (B, K, Skv, hd) with H % K == 0.

    Returns (B, H, Sq, hd) in q.dtype.
    """
    B, H, Sq, hd = q.shape
    _, K, Skv, _ = k.shape
    assert H % K == 0, (H, K)
    G = H // K
    scale = hd ** -0.5

    bq = min(block_q, max(Sq, 8))
    bkv = min(block_kv, max(Skv, 8))
    nq = -(-Sq // bq)
    nkv = -(-Skv // bkv)
    q_pad, kv_pad = nq * bq - Sq, nkv * bkv - Skv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_kv=bkv, seq_q=Sq, seq_kv=Skv,
        n_kv_blocks=nkv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
