"""jit'd model-layout wrappers around the Pallas kernels.

The model keeps GQA activations as (B, S, K, G, hd); these wrappers
transpose into kernel layout, invoke the kernel (interpret=True on CPU
so the kernel body is executed for validation; compiled on real TPU),
and transpose back.  They are drop-in replacements for the XLA-path
attention in ``repro.models.layers`` when ``cfg.attn_impl == "pallas"``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan


def _on_cpu():
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("causal", "window", "softcap"))
def attention_op(q, k, v, *, causal=True, window=None, softcap=None):
    """q: (B, S, K, G, hd); k, v: (B, T, K, hd) -> (B, S, K, G, hd)."""
    B, S, K, G, hd = q.shape
    qh = q.transpose(0, 2, 3, 1, 4).reshape(B, K * G, S, hd)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    o = flash_attention(qh, kh, vh, causal=causal, window=window,
                        softcap=softcap, interpret=_on_cpu())
    return o.reshape(B, K, G, S, hd).transpose(0, 3, 1, 2, 4)


@partial(jax.jit, static_argnames=("window", "softcap"))
def decode_attention_op(q, k, v, q_pos, kv_pos, *, window=None,
                        softcap=None):
    """q: (B, 1, K, G, hd); k, v: (B, T, K, hd) cache -> (B, 1, K, G, hd)."""
    B, _, K, G, hd = q.shape
    o = decode_attention(q[:, 0], k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), q_pos, kv_pos,
                         window=window, softcap=softcap,
                         interpret=_on_cpu())
    return o[:, None]


@jax.jit
def rglru_op(a, gated, h0=None):
    """Diagonal linear recurrence in model layout (B, S, R)."""
    return rglru_scan(a, gated, h0, interpret=_on_cpu())
