"""Unified LM covering the full architecture zoo.

One implementation drives all ten assigned architectures; family
behaviour comes from ``ArchConfig`` flags.  Layers are grouped into
*periods* (the repeating block pattern, e.g. gemma2's (local, global) or
recurrentgemma's (rglru, rglru, attn_local)); parameters for each period
position are stacked over ``n_periods`` and the stack is driven by
``jax.lax.scan`` so the lowered HLO contains one period regardless of
depth.  Layers that do not fill a whole period (gemma3: 62 = 10*6 + 2)
are unrolled as remainder layers.

Public surface:
  block_pattern_of(cfg)   -> per-period block kinds
  model_template(cfg)     -> pytree of ParamSpec (shapes + logical axes)
  init_params(cfg, key)   -> parameter pytree
  init_cache(cfg, B, len) -> decode-state pytree (KV / recurrent states)
  forward(cfg, params, tokens, ...)         -> (hidden, aux)
  loss_fn(cfg, params, batch)               -> (loss, metrics)
  prefill(cfg, params, tokens, ...)         -> (logits, cache)
  decode_step(cfg, params, token, pos, cache) -> (logits, new_cache)
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ArchConfig
from repro.models.layers import ParamSpec
from repro.models.partition import constrain

f32 = jnp.float32


# --------------------------------------------------------------------------
# block pattern / layer layout
# --------------------------------------------------------------------------

def block_pattern_of(cfg: ArchConfig) -> tuple[str, ...]:
    if cfg.block_pattern:
        return tuple(cfg.block_pattern)
    if cfg.window_pattern:
        return tuple("attn_local" if w == "local" else "attn_global"
                     for w in cfg.window_pattern)
    return ("attn_global",)


def layer_layout(cfg: ArchConfig) -> tuple[tuple[str, ...], int, int]:
    """(pattern, n_periods, n_remainder)."""
    pat = block_pattern_of(cfg)
    return pat, cfg.n_layers // len(pat), cfg.n_layers % len(pat)


def _has_mlp(cfg: ArchConfig, kind: str) -> bool:
    return cfg.d_ff > 0 or cfg.moe is not None


def _has_cross(cfg: ArchConfig) -> bool:
    return cfg.encoder_layers > 0


# --------------------------------------------------------------------------
# templates
# --------------------------------------------------------------------------

def block_template(cfg: ArchConfig, kind: str, *, encoder=False):
    D = cfg.d_model
    norm = lambda: ParamSpec((D,), ("embed",), init="zeros")
    t: dict[str, Any] = {"ln1": norm()}
    if kind.startswith("attn"):
        t["attn"] = L.attn_template(cfg)
        if cfg.sandwich_norm:
            t["ln1_post"] = norm()
    elif kind == "rglru":
        t["rglru"] = L.rglru_template(cfg)
    elif kind == "mlstm":
        t["mlstm"] = L.mlstm_template(cfg)
    elif kind == "slstm":
        t["slstm"] = L.slstm_template(cfg)
    else:
        raise ValueError(kind)
    if not encoder and _has_cross(cfg):
        t["ln_cross"] = norm()
        t["cross"] = L.attn_template(cfg, cross=True)
    if _has_mlp(cfg, kind):
        t["ln2"] = norm()
        if cfg.moe is not None and not encoder:
            t["moe"] = L.moe_template(cfg)
        else:
            t["mlp"] = L.mlp_template(cfg)
        if cfg.sandwich_norm:
            t["ln2_post"] = norm()
    return t


def _stack_specs(tmpl, n):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init,
                            s.scale), tmpl,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def model_template(cfg: ArchConfig):
    D, V = cfg.d_model, cfg.vocab
    pat, n_per, n_rem = layer_layout(cfg)
    t: dict[str, Any] = {
        "embed": ParamSpec((V, D), ("vocab", "embed"), scale=1.0),
        "final_norm": ParamSpec((D,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamSpec((D, V), ("embed", "vocab"))
    if cfg.learned_pos_embed:
        t["pos_embed"] = ParamSpec((cfg.learned_pos_embed, D),
                                   (None, "embed"), scale=0.02)
    if cfg.vision_tokens:
        t["vision_proj"] = ParamSpec((D, D), ("embed", "embed"))
    layers_t: dict[str, Any] = {}
    if n_per > 0:
        layers_t["scan"] = {
            f"pos{i}": _stack_specs(block_template(cfg, k), n_per)
            for i, k in enumerate(pat)}
    if n_rem:
        # remainder layers (gemma3: 62 = 10*6 + 2) are a second stacked
        # group scanned once — unstacked layers would take a different
        # GSPMD path for their grads/optimizer state (observed: full-size
        # fp32 replication)
        layers_t["rem_scan"] = {
            f"pos{j}": _stack_specs(block_template(cfg, pat[j]), 1)
            for j in range(n_rem)}
    t["layers"] = layers_t
    if cfg.encoder_layers:
        t["encoder"] = {
            "scan": {"pos0": _stack_specs(
                block_template(cfg, "attn_bidir", encoder=True),
                cfg.encoder_layers)},
            "final_norm": ParamSpec((D,), ("embed",), init="zeros"),
        }
    return t


def init_params(cfg: ArchConfig, key):
    tmpl = model_template(cfg)
    leaves, treedef = jax.tree.flatten(
        tmpl, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    dtype = cfg.jdtype
    return jax.tree.unflatten(
        treedef, [s.initializer(k, dtype) for s, k in zip(leaves, keys)])


def logical_axes(cfg: ArchConfig):
    """Pytree (mirroring params) of logical-axis tuples."""
    return jax.tree.map(lambda s: s.axes, model_template(cfg),
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(cfg: ArchConfig) -> int:
    tmpl = model_template(cfg)
    return sum(math.prod(s.shape) for s in jax.tree.leaves(
        tmpl, is_leaf=lambda x: isinstance(x, ParamSpec)))


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def _block_cache(cfg: ArchConfig, kind: str, B: int, cache_len: int):
    K, hd, D = cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    dt = cfg.jdtype
    if kind == "attn_global":
        S = cache_len
    elif kind == "attn_local":
        S = min(cfg.window_size, cache_len)
    if kind.startswith("attn"):
        quant = cfg.kv_quant == "int8" and kind == "attn_global"
        kv_dt = jnp.int8 if quant else dt
        c = {"k": jnp.zeros((B, S, K, hd), kv_dt),
             "v": jnp.zeros((B, S, K, hd), kv_dt),
             "pos": jnp.full((B, S), -1, jnp.int32)}
        if quant:
            c["k_scale"] = jnp.zeros((B, S, K), f32)
            c["v_scale"] = jnp.zeros((B, S, K), f32)
    elif kind == "rglru":
        R = cfg.rglru_dim or D
        c = {"h": jnp.zeros((B, R), f32),
             "conv": jnp.zeros((B, cfg.conv_width - 1, R), dt)}
    elif kind == "mlstm":
        nh = cfg.lru_heads or cfg.n_heads
        dh = D // nh
        c = {"C": jnp.zeros((B, nh, dh, dh), f32),
             "n": jnp.zeros((B, nh, dh), f32),
             "m": jnp.zeros((B, nh), f32)}
    elif kind == "slstm":
        nh = cfg.lru_heads or cfg.n_heads
        dh = D // nh
        c = {"c": jnp.zeros((B, nh, dh), f32),
             "n": jnp.full((B, nh, dh), 1e-6, f32),
             "h": jnp.zeros((B, nh, dh), f32),
             "m": jnp.zeros((B, nh, dh), f32)}  # per-unit stabilizer
    else:
        raise ValueError(kind)
    if _has_cross(cfg):
        c["cross_k"] = jnp.zeros((B, cfg.encoder_seq, K, hd), dt)
        c["cross_v"] = jnp.zeros((B, cfg.encoder_seq, K, hd), dt)
    return c


def init_cache(cfg: ArchConfig, batch: int, cache_len: int):
    pat, n_per, n_rem = layer_layout(cfg)

    def stack(c, n):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), c)

    cache: dict[str, Any] = {}
    if n_per > 0:
        cache["scan"] = {
            f"pos{i}": stack(_block_cache(cfg, k, batch, cache_len),
                             n_per)
            for i, k in enumerate(pat)}
    if n_rem:
        cache["rem_scan"] = {
            f"pos{j}": stack(_block_cache(cfg, pat[j], batch, cache_len),
                             1)
            for j in range(n_rem)}
    return cache


# --------------------------------------------------------------------------
# block application
# --------------------------------------------------------------------------

def _apply_block(p, cfg, kind, x, positions, *, cache=None, decode=False,
                 make_cache=0, enc_out=None):
    """One residual block.  Returns (x, new_cache, aux)."""
    aux = {}
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = dict(cache) if cache is not None else None
    if kind.startswith("attn"):
        if decode:
            kv_keys = [k for k in cache
                       if not k.startswith("cross")]
            y, kv = L.attn_decode(p["attn"], cfg, h, positions,
                                  {k: cache[k] for k in kv_keys},
                                  kind=kind)
            new_cache.update(kv)
        else:
            y, kv = L.attn_apply(p["attn"], cfg, h, positions, kind=kind,
                                 make_cache=make_cache)
            if make_cache:
                new_cache = kv
    elif kind == "rglru":
        if decode:
            y, st = L.rglru_decode(p["rglru"], cfg, h,
                                   {k: cache[k] for k in ("h", "conv")})
            new_cache.update(st)
        else:
            y, st = L.rglru_apply(p["rglru"], cfg, h,
                                  make_cache=bool(make_cache))
            if make_cache:
                new_cache = st
    elif kind == "mlstm":
        if decode:
            y, st = L.mlstm_decode(p["mlstm"], cfg, h,
                                   {k: cache[k] for k in ("C", "n", "m")})
            new_cache.update(st)
        else:
            y, st = L.mlstm_apply(p["mlstm"], cfg, h,
                                  make_cache=bool(make_cache))
            if make_cache:
                new_cache = st
    elif kind == "slstm":
        if decode:
            y, st = L.slstm_decode(p["slstm"], cfg, h,
                                   {k: cache[k]
                                    for k in ("c", "n", "h", "m")})
            new_cache.update(st)
        else:
            y, st = L.slstm_apply(p["slstm"], cfg, h,
                                  make_cache=bool(make_cache))
            if make_cache:
                new_cache = st
    if cfg.sandwich_norm and kind.startswith("attn"):
        y = L.rms_norm(y, p["ln1_post"], cfg.norm_eps)
    x = x + y

    if "cross" in p:
        h = L.rms_norm(x, p["ln_cross"], cfg.norm_eps)
        if decode:
            y, _ = L.attn_decode(
                p["cross"], cfg, h, positions, cache, kind="attn_cross",
                encoder_kv=(cache["cross_k"], cache["cross_v"]))
        else:
            ek = L.dot(enc_out, p["cross"]["wk"]).reshape(
                enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads,
                cfg.head_dim)
            ev = L.dot(enc_out, p["cross"]["wv"]).reshape(ek.shape)
            if cfg.qkv_bias:
                ek = ek + p["cross"]["bk"].reshape(ek.shape[-2:])
                ev = ev + p["cross"]["bv"].reshape(ev.shape[-2:])
            y, _ = L.attn_apply(p["cross"], cfg, h, positions,
                                kind="attn_cross", encoder_kv=(ek, ev))
            if make_cache:
                new_cache["cross_k"] = ek
                new_cache["cross_v"] = ev
        x = x + y

    if "mlp" in p or "moe" in p:
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            y, aux = L.moe_apply(p["moe"], cfg, h)
        else:
            y = L.mlp_apply(p["mlp"], h)
        if cfg.sandwich_norm:
            y = L.rms_norm(y, p["ln2_post"], cfg.norm_eps)
        x = x + y
    return x, new_cache, aux


def _zero_aux(cfg):
    if cfg.moe is None:
        return {}
    return {"expert_load": jnp.zeros((cfg.moe.n_experts,), f32),
            "moe_aux_loss": jnp.zeros((), f32)}


def constrain_like_params(cfg: ArchConfig, tree):
    """Pin a params-shaped pytree (e.g. grads, fp32 accumulators) to the
    parameter sharding — no-op outside a mesh context."""
    tmpl = model_template(cfg)
    return jax.tree.map(
        lambda arr, spec: constrain(arr, *spec.axes), tree, tmpl,
        is_leaf=lambda t: isinstance(t, ParamSpec))


def _constrain_block_params(cfg, kind, p):
    """Pin block params (and, via the transpose, their grads) to their
    logical sharding."""
    tmpl = block_template(cfg, kind)
    return jax.tree.map(
        lambda arr, spec: constrain(arr, *spec.axes), p, tmpl,
        is_leaf=lambda t: isinstance(t, ParamSpec))


def _decode_layers_inplace(cfg, params_scan, x, positions, caches_scan,
                           pattern, n):
    """Decode path: fori_loop with the full stacked caches as carry.

    Caches are updated with dynamic_update_index_in_dim so XLA keeps the
    multi-GB KV buffers in place through the while loop (a scan emitting
    new caches as ys would double-buffer them).
    """
    def at(tree, t):
        return jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, t, 0, keepdims=False),
            tree)

    def body(t, carry):
        x, caches = carry
        p_t = at(params_scan, t)
        for i, kind in enumerate(pattern):
            c_t = at(caches[f"pos{i}"], t)
            x, nc, _ = _apply_block(p_t[f"pos{i}"], cfg, kind, x,
                                    positions, cache=c_t, decode=True)
            # write back only entries the block actually changed —
            # re-writing static slices (whisper's cross K/V: ~2 GB per
            # layer) would force XLA to copy them every loop iteration
            grp = dict(caches[f"pos{i}"])
            for key, new in nc.items():
                if new is c_t[key] and cfg.decode_skip_static_writes:
                    continue
                grp[key] = lax.dynamic_update_index_in_dim(
                    grp[key], new.astype(grp[key].dtype), t, 0)
            caches = {**caches, f"pos{i}": grp}
        return (x, caches)

    return lax.fori_loop(0, n, body, (x, caches_scan))


def _scan_group(cfg, params_scan, caches_scan, pattern, x, positions, *,
                decode=False, make_cache=0, enc_out=None, remat=False):
    """Run one stacked layer group (the main periods or the remainder).

    Returns (x, new_caches, aux).  Training/prefill drive a lax.scan with
    per-block remat; decode drives the in-place fori_loop above.
    """
    n = jax.tree.leaves(params_scan)[0].shape[0]
    if decode and cfg.scan_layers:
        x, new_scan = _decode_layers_inplace(
            cfg, params_scan, x, positions, caches_scan, pattern, n)
        return x, new_scan, _zero_aux(cfg)

    # remat granularity is one *block*, not one period: a multi-block
    # period (gemma3: 6, recurrentgemma: 3) checkpointed as a unit would
    # keep the whole period's intermediates live during its backward
    blk = partial(_apply_block, decode=decode, make_cache=make_cache,
                  enc_out=enc_out)
    if remat:
        blk = jax.checkpoint(blk, static_argnums=(1, 2))

    def body(carry, per_layer):
        x = carry
        x = constrain(x, "batch", "seq", "embed")
        p_stk, c_stk = per_layer
        new_cs, aux_acc = {}, _zero_aux(cfg)
        for i, kind in enumerate(pattern):
            c = c_stk.get(f"pos{i}") if c_stk is not None else None
            x, nc, aux = blk(p_stk[f"pos{i}"], cfg, kind, x, positions,
                             cache=c)
            new_cs[f"pos{i}"] = nc if nc is not None else 0
            for k in aux_acc:
                aux_acc[k] = aux_acc[k] + aux.get(k, 0)
        return x, (new_cs, aux_acc)

    xs = (params_scan, caches_scan) if caches_scan is not None \
        else (params_scan, None)
    aux_tot = _zero_aux(cfg)
    if cfg.scan_layers:
        x, (new_scan, aux_stk) = lax.scan(body, x, xs)
        aux_tot = {k: aux_tot[k] + aux_stk[k].sum(0) for k in aux_tot}
    else:  # unrolled (perf-iteration comparison point)
        new_list = []
        for t in range(n):
            sl = jax.tree.map(lambda a: a[t], xs)
            x, (nc, aux) = body(x, sl)
            new_list.append(nc)
            aux_tot = {k: aux_tot[k] + aux[k] for k in aux_tot}
        new_scan = jax.tree.map(lambda *a: jnp.stack(a), *new_list) \
            if new_list and (make_cache or decode) else {}
    if not (make_cache or decode):
        new_scan = {}
    return x, new_scan, aux_tot


def _run_layers(cfg, params_l, x, positions, *, caches=None, decode=False,
                make_cache=0, enc_out=None, remat=False):
    """Drive the stacked layer groups.  Returns (x, new_caches, aux)."""
    pat, n_per, n_rem = layer_layout(cfg)
    aux_tot = _zero_aux(cfg)
    new_caches: dict[str, Any] = {}
    for group, pattern in (("scan", pat), ("rem_scan", pat[:n_rem])):
        if group not in params_l:
            continue
        c = caches.get(group) if caches else None
        x, new_c, aux = _scan_group(
            cfg, params_l[group], c, pattern, x, positions, decode=decode,
            make_cache=make_cache, enc_out=enc_out, remat=remat)
        if make_cache or decode:
            new_caches[group] = new_c
        aux_tot = {k: aux_tot[k] + aux.get(k, 0) for k in aux_tot}
    return x, new_caches, aux_tot


# --------------------------------------------------------------------------
# encoder (whisper stub frontend -> transformer encoder)
# --------------------------------------------------------------------------

def run_encoder(cfg, params, frames, *, remat=False):
    """frames: (B, encoder_seq, D) precomputed frame embeddings (stub)."""
    x = frames.astype(cfg.jdtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, p_stk):
        x = constrain(x, "batch", "seq", "embed")
        h = L.rms_norm(x, p_stk["ln1"], cfg.norm_eps)
        y, _ = L.attn_apply(p_stk["attn"], cfg, h, positions,
                            kind="attn_bidir")
        x = x + y
        h = L.rms_norm(x, p_stk["ln2"], cfg.norm_eps)
        return x + L.mlp_apply(p_stk["mlp"], h), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["encoder"]["scan"]["pos0"])
    return L.rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


# --------------------------------------------------------------------------
# model entry points
# --------------------------------------------------------------------------

def embed_tokens(cfg, params, tokens):
    x = params["embed"][tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _head(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", h, w, preferred_element_type=f32)
    return L.softcap(logits, cfg.final_softcap)


def forward(cfg: ArchConfig, params, tokens, *, patch_embeds=None,
            enc_frames=None, make_cache=0, remat=False):
    """Full-sequence forward.  Returns (hidden (B,S,D), caches, aux).

    pixtral: `patch_embeds` (B, vision_tokens, D) fill the first
    ``vision_tokens`` positions; `tokens` then has S - vision_tokens ids.
    whisper: `enc_frames` (B, encoder_seq, D) drive the encoder; tokens
    are decoder ids.
    """
    x = embed_tokens(cfg, params, tokens)
    if cfg.vision_tokens and patch_embeds is not None:
        vis = L.dot(patch_embeds.astype(x.dtype), params["vision_proj"])
        x = jnp.concatenate([vis, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.learned_pos_embed:
        x = x + params["pos_embed"][jnp.minimum(
            positions, cfg.learned_pos_embed - 1)]
    enc_out = None
    if cfg.encoder_layers:
        if enc_frames is None:  # text-only traffic on an enc-dec model
            enc_frames = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                   x.dtype)
        enc_out = run_encoder(cfg, params, enc_frames, remat=remat)
    x, caches, aux = _run_layers(
        cfg, params["layers"], x, positions, make_cache=make_cache,
        enc_out=enc_out, remat=remat)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, caches, aux


def loss_fn(cfg: ArchConfig, params, batch):
    """Next-token loss.  batch: tokens (B,S), labels (B,S) with -1 = pad.

    The head+CE runs in token chunks of ``cfg.loss_chunk`` (remat'd) so
    the (tokens, vocab) logits buffer never fully materializes.
    """
    h, _, aux = forward(cfg, params, batch["tokens"],
                        patch_embeds=batch.get("patch_embeds"),
                        enc_frames=batch.get("enc_frames"),
                        remat=cfg.remat == "block")
    labels = batch["labels"]
    if cfg.vision_tokens and batch.get("patch_embeds") is not None:
        pad = jnp.full((labels.shape[0], cfg.vision_tokens), -1,
                       labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    B, S, D = h.shape

    def ce(h_chunk, l_chunk):
        logits = _head(cfg, params, h_chunk)  # (B, s, V) fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(l_chunk, 0)[..., None], axis=-1)[..., 0]
        mask = (l_chunk >= 0).astype(f32)
        return ((lse - tgt) * mask).sum(), mask.sum()

    # chunk the head+CE along the *sequence* dim: (B, s_chunk, D) chunks
    # keep the layer-stack's (batch, seq) sharding, so no resharding is
    # needed and the fp32 logits buffer is (B, s_chunk, V) / n_devices
    chunk_s = 0
    if cfg.loss_chunk:
        chunk_s = min(S, max(cfg.loss_chunk // max(B, 1), 256))
    if chunk_s and S % chunk_s == 0 and chunk_s < S:
        n = S // chunk_s
        hc = h.reshape(B, n, chunk_s, D).swapaxes(0, 1)
        lc = labels.reshape(B, n, chunk_s).swapaxes(0, 1)
        (tot, cnt) = lax.scan(
            lambda c, xs: (tuple(a + b for a, b in
                                 zip(c, jax.checkpoint(ce)(*xs))), None),
            (jnp.zeros((), f32), jnp.zeros((), f32)), (hc, lc))[0]
    else:
        tot, cnt = ce(h, labels)
    loss = tot / jnp.maximum(cnt, 1.0)
    metrics = {"ce_loss": loss}
    if cfg.moe is not None:
        metrics["moe_aux_loss"] = aux["moe_aux_loss"]
        metrics["expert_load"] = aux["expert_load"]
        loss = loss + 0.01 * aux["moe_aux_loss"]
    metrics["loss"] = loss
    return loss, metrics


def prefill(cfg: ArchConfig, params, tokens, *, cache_len=None,
            patch_embeds=None, enc_frames=None):
    """Prefill: forward + decode-cache construction.  Returns
    (last-token logits (B, V), caches, aux)."""
    cache_len = cache_len or tokens.shape[1] + (cfg.vision_tokens or 0)
    h, caches, aux = forward(cfg, params, tokens,
                             patch_embeds=patch_embeds,
                             enc_frames=enc_frames, make_cache=cache_len)
    return _head(cfg, params, h[:, -1]), caches, aux


def decode_step(cfg: ArchConfig, params, token, pos, caches):
    """One decode step.  token: (B, 1) ids; pos: (B,) positions.

    Returns (logits (B, V), new_caches).
    """
    x = embed_tokens(cfg, params, token)
    if cfg.learned_pos_embed:
        x = x + params["pos_embed"][
            jnp.minimum(pos, cfg.learned_pos_embed - 1)][:, None]
    x, new_caches, _ = _run_layers(cfg, params["layers"], x, pos,
                                   caches=caches, decode=True)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _head(cfg, params, x[:, 0]), new_caches
