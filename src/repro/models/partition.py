"""Activation sharding constraints for model internals.

``constrain(x, *logical_names)`` applies
``jax.lax.with_sharding_constraint`` resolved against the *current* mesh
context — and degrades to a no-op when there is no mesh (CPU smoke
tests) or when a dim doesn't divide the mesh axis.  Model code can
therefore sprinkle constraints freely; they only bind under the
dry-run/launcher mesh.

``act_mode`` switches the sequence rule:
  "dp"  — activations sharded over batch only (default);
  "sp"  — sequence dim additionally sharded over the model axis
          (sequence parallelism for the long train/prefill cells; XLA
          inserts the all-gather/reduce-scatter pairs around attention).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()

ACT_RULES = {
    "batch": ("pod", "data"),
    "seq": "model",       # only active in "sp" mode
    "tokens": ("pod", "data", "model"),  # flattened (B*S) token dim
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "vocab": "model",
    "layers": None,
    None: None,
}


def act_mode() -> str:
    return getattr(_state, "mode", "dp")


@contextlib.contextmanager
def use_act_mode(mode: str):
    prev = act_mode()
    _state.mode = mode
    try:
        yield
    finally:
        _state.mode = prev


def _current_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:  # classic `with mesh:` context
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def constrain(x, *names: Optional[str]):
    """Best-effort sharding constraint by logical dim names."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    mode = act_mode()
    used: set[str] = set()
    spec = []
    for dim, name in zip(x.shape, names):
        if name == "seq" and mode != "sp":
            spec.append(None)
            continue
        axes = ACT_RULES.get(name)
        if axes is None:
            spec.append(None)
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        tup = tuple(a for a in tup if a in mesh.shape and a not in used)
        size = 1
        for a in tup:
            size *= mesh.shape[a]
        # drop leading axes until the dim divides
        while tup and (size <= 1 or dim % size != 0):
            size //= mesh.shape[tup[0]]
            tup = tup[1:]
        if not tup or size <= 1:
            spec.append(None)
            continue
        used.update(tup)
        spec.append(tup[0] if len(tup) == 1 else tup)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
