"""Architecture and shape configuration.

One unified ``ArchConfig`` drives every assigned architecture; family-
specific behaviour is expressed through flags (MoE, window patterns,
softcaps, recurrence mix, frontends) so a single scan-over-layers
implementation covers the zoo.  ``ShapeSpec`` describes the assigned
input shapes (train_4k / prefill_32k / decode_32k / long_500k).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    capacity_factor: float = 1.25
    router_noise: float = 0.0


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # "dense" | "moe" | "ssm" | "hybrid" | "vlm" | "audio"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # attention variants
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2/3: 30.0
    rope_theta: float = 10_000.0
    use_rope: bool = True  # whisper: learned absolute positions instead
    # sliding-window pattern: period list of "local"/"global" (None = all
    # global).  gemma2: ("local","global"); gemma3: ("local",)*5+("global",)
    window_pattern: Optional[tuple[str, ...]] = None
    window_size: int = 4096
    # recurrence pattern for hybrid/ssm families: period list drawn from
    # {"rglru", "mlstm", "slstm", "attn_local"}; None = pure attention.
    block_pattern: Optional[tuple[str, ...]] = None
    rglru_dim: int = 0  # RG-LRU recurrence width (recurrentgemma: d_model)
    conv_width: int = 4  # temporal conv in recurrent blocks
    lru_heads: int = 0  # xLSTM heads for matrix memory
    # MoE
    moe: Optional[MoEConfig] = None
    # embeddings / head
    tie_embeddings: bool = True
    scale_embed: bool = False  # gemma family: embeddings * sqrt(d_model)
    learned_pos_embed: int = 0  # >0: learned absolute positions (whisper)
    # frontends (stubs fed by input_specs)
    encoder_layers: int = 0  # whisper encoder depth
    encoder_seq: int = 0  # whisper: 1500 frames
    vision_tokens: int = 0  # pixtral: patch tokens prepended
    # gemma2/3 sandwich norms (pre+post norm around attn and mlp)
    sandwich_norm: bool = False
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    # remat policy for train: "none" | "block" (checkpoint each layer)
    remat: str = "block"
    # ---- execution knobs (perf-iteration surface, not architecture) ----
    scan_layers: bool = True  # scan over layer periods (small HLO)
    attn_impl: str = "xla"  # "xla" | "pallas"
    # chunked (online-softmax) attention kicks in above this seq length;
    # bounds the transient fp32 score buffer to (chunk_q x chunk_kv) per
    # head — the XLA-path analogue of the Pallas flash kernel
    attn_chunk_threshold: int = 2_048
    attn_chunk_q: int = 1_024
    attn_chunk_kv: int = 1_024
    mlstm_chunk: int = 256  # chunkwise-parallel mLSTM chunk length
    # MoE dispatch group size: the Switch-style dispatch/combine einsums
    # cost O(tokens * E * C * D) with C ∝ group, so smaller groups cut
    # the one-hot dispatch overhead linearly (at some routing-balance
    # granularity loss)
    moe_group: int = 4096
    # KV-cache quantization for long-context decode ("int8" halves the
    # dominant HBM term; scales are per (token, kv-head))
    kv_quant: Optional[str] = None
    # skip writing unchanged cache slices back through the decode loop
    # (whisper's static cross-K/V); False reproduces the naive engine
    decode_skip_static_writes: bool = True
    # cross-entropy is computed in vocab-preserving token chunks of this
    # size (0 = unchunked); bounds the (tokens, vocab) logits buffer.
    loss_chunk: int = 0

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # -------------------------------------------------------------- sizing
    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        D, H, K, hd, F, V = (self.d_model, self.n_heads, self.n_kv_heads,
                             self.head_dim, self.d_ff, self.vocab)
        per_layer = D * hd * (H + 2 * K) + H * hd * D  # qkvo
        if self.moe:
            e = self.moe
            per_layer += D * e.n_experts + 3 * e.n_experts * D * e.d_expert_ff
        elif F > 0:
            per_layer += 3 * D * F  # gated mlp
        if self.block_pattern:
            # crude: recurrent blocks add ~4*D*rglru_dim
            per_layer += 2 * D * max(self.rglru_dim, D)
        total = self.n_layers * per_layer
        total += V * D * (1 if self.tie_embeddings else 2)
        total += self.encoder_layers * (4 * D * D + 3 * D * F)
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if not self.moe:
            return self.param_count()
        e = self.moe
        dense_like = self.param_count() - self.n_layers * (
            3 * e.n_experts * self.d_model * e.d_expert_ff)
        return int(dense_like + self.n_layers * 3 * e.top_k
                   * self.d_model * e.d_expert_ff)

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch serve 500k contexts (no full-attention layer)?"""
        if self.block_pattern:
            return all(b in ("rglru", "mlstm", "slstm", "attn_local")
                       for b in self.block_pattern)
        return False

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """The assigned shape cells for this arch (skips per DESIGN.md §4)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        names.append("long_500k")
    return names
