"""ShapeDtypeStruct input stand-ins for every (arch, shape) cell.

``input_specs`` is the single source of truth used by the multi-pod
dry-run, the benchmarks, and the smoke tests (which call it with a
reduced config + small shape and then materialize).  Decode-state specs
are derived with ``jax.eval_shape`` over ``init_cache`` so they can
never drift from the model's cache layout.  No device allocation.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, ShapeSpec
from repro.models.model import init_cache

i32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _frontend_specs(cfg: ArchConfig, batch: int):
    s = {}
    if cfg.vision_tokens:
        s["patch_embeds"] = _sds((batch, cfg.vision_tokens, cfg.d_model),
                                 cfg.jdtype)
    if cfg.encoder_layers:
        s["enc_frames"] = _sds((batch, cfg.encoder_seq, cfg.d_model),
                               cfg.jdtype)
    return s


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Inputs for the step function this cell lowers.

    train  -> loss_fn/train_step batch:  tokens, labels (+frontends)
    prefill-> prefill(tokens, ...)
    decode -> decode_step(token, pos, caches): one new token against a
              KV/recurrent cache of seq_len (the assigned semantics).
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        text = S - (cfg.vision_tokens or 0)
        spec = {"tokens": _sds((B, text), i32),
                "labels": _sds((B, text), i32)}
        spec.update(_frontend_specs(cfg, B))
        return spec
    if shape.kind == "prefill":
        text = S - (cfg.vision_tokens or 0)
        spec = {"tokens": _sds((B, text), i32)}
        spec.update(_frontend_specs(cfg, B))
        return spec
    if shape.kind == "decode":
        caches = jax.eval_shape(partial(init_cache, cfg, B, S))
        return {"token": _sds((B, 1), i32),
                "pos": _sds((B,), i32),
                "caches": caches}
    raise ValueError(shape.kind)


def materialize(spec, seed: int = 0):
    """Turn an input_specs pytree into real (tiny) arrays for smoke tests.

    Token ids are uniform over the vocab-free range [0, 64); float leaves
    are standard normal.  Deterministic in ``seed``.
    """
    leaves, treedef = jax.tree.flatten(spec)
    key = jax.random.PRNGKey(seed)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            out.append(jax.random.randint(k, leaf.shape, 0, 64,
                                          dtype=leaf.dtype))
        else:
            out.append(jax.random.normal(k, leaf.shape,
                                         jnp.float32).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)
